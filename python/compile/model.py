"""L2: the jitted compute graphs the rust coordinator executes via PJRT.

Two graphs, both built on the L1 Pallas kernels:

  * ``generate_events(seed) -> (n, 8) f32`` — the event source. Uniform
    deviates from the counter-based PRNG kernel are shaped into physics-like
    columns: exponential transverse momenta, flat pseudorapidity/azimuth,
    near-constant muon masses. Column layout (shared with the rust side,
    see rust/src/framework/dataset.rs):
        [pt1, eta1, phi1, m1, pt2, eta2, phi2, m2]
  * ``analyze_events(cols) -> (mass (n,), hist (NBINS,))`` — the analysis
    step interleaved with basket decompression (paper Fig 2): dimuon
    invariant mass + spectrum histogram.

Shapes are fixed at lowering time (one artifact per block size); the rust
runtime picks the artifact matching its event-block size. Python never runs
after ``make artifacts``.
"""

import jax.numpy as jnp

from .kernels import physics, prng

NCOLS = 8
MUON_MASS = 0.1057  # GeV

# Transform parameters — shared with ref-based tests.
PT_SCALE = 30.0  # GeV, exponential tail
ETA_RANGE = 2.5  # |eta| < 2.5, tracker acceptance
PT_CLAMP = 0.999999  # avoid log(0)


def shape_columns(u):
    """Map (n, 8) uniforms onto physics-like columns (pure jnp)."""
    two_pi = 2.0 * jnp.pi

    def leg(up, ue, uf, um):
        pt = -PT_SCALE * jnp.log1p(-jnp.minimum(up, PT_CLAMP))
        eta = ETA_RANGE * (2.0 * ue - 1.0)
        phi = two_pi * uf - jnp.pi
        m = MUON_MASS * (1.0 + 0.01 * (um - 0.5))
        return pt, eta, phi, m

    p1 = leg(u[:, 0], u[:, 1], u[:, 2], u[:, 3])
    p2 = leg(u[:, 4], u[:, 5], u[:, 6], u[:, 7])
    return jnp.stack(p1 + p2, axis=1)


def generate_events(seed, n, tile=prng.TILE):
    """seed: (2,) uint32 -> (n, 8) f32 event columns."""
    u = prng.uniform(seed, n, NCOLS, tile=tile)
    return shape_columns(u)


def analyze_events(cols, tile=physics.TILE):
    """cols: (n, 8) f32 -> (mass (n,), hist (NBINS,) f32)."""
    mass, partials = physics.mass_hist(cols, tile=tile)
    return mass, jnp.sum(partials, axis=0)
