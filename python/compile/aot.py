"""AOT lowering: jit the L2 graphs, emit HLO *text* artifacts for rust.

HLO text (not ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Usage: ``cd python && python -m compile.aot --outdir ../artifacts``
Emits, per block size N in BLOCK_SIZES:
    gen_<N>.hlo.txt       generate_events: (2,)u32 -> (N,8)f32
    analyze_<N>.hlo.txt   analyze_events: (N,8)f32 -> ((N,)f32, (64,)f32)
plus ``meta.json`` describing shapes for the rust runtime.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import physics

# Block sizes the rust coordinator uses: 16384 for production pipelines,
# 4096 for tests/examples that want small files.
BLOCK_SIZES = (4096, 16384)


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> HLO text with a 1-tuple root."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gen(n: int) -> str:
    seed_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fn = lambda seed: (model.generate_events(seed, n),)
    return to_hlo_text(jax.jit(fn).lower(seed_spec))


def lower_analyze(n: int) -> str:
    cols_spec = jax.ShapeDtypeStruct((n, model.NCOLS), jnp.float32)
    fn = lambda cols: model.analyze_events(cols)
    return to_hlo_text(jax.jit(fn).lower(cols_spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--sizes", type=int, nargs="*", default=list(BLOCK_SIZES)
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    meta = {
        "ncols": model.NCOLS,
        "nbins": physics.NBINS,
        "hist_lo": physics.HIST_LO,
        "hist_hi": physics.HIST_HI,
        "blocks": sorted(args.sizes),
        "artifacts": {},
    }
    for n in args.sizes:
        for name, text in (
            (f"gen_{n}", lower_gen(n)),
            (f"analyze_{n}", lower_analyze(n)),
        ):
            path = os.path.join(args.outdir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            meta["artifacts"][name] = {
                "file": f"{name}.hlo.txt",
                "bytes": len(text),
            }
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.outdir, 'meta.json')}")

    # Plain-text twin of meta.json for the rust runtime (no JSON parser
    # in the dependency-free rust build).
    with open(os.path.join(args.outdir, "meta.txt"), "w") as f:
        f.write(f"ncols {model.NCOLS}\n")
        f.write(f"nbins {physics.NBINS}\n")
        f.write(f"hist_lo {physics.HIST_LO}\n")
        f.write(f"hist_hi {physics.HIST_HI}\n")
        f.write("blocks " + " ".join(str(n) for n in sorted(args.sizes)) + "\n")
    print(f"wrote {os.path.join(args.outdir, 'meta.txt')}")


if __name__ == "__main__":
    main()
