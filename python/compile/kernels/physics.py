"""L1 Pallas kernel: dimuon invariant-mass + histogram analysis.

This is the "processing of decompressed data" the paper interleaves with
parallel basket decompression (sec. 2.2 / Figure 2). The L3 coordinator
decompresses baskets on the task pool and feeds decoded column blocks to
this kernel through PJRT.

Input layout: a (n, 8) f32 column block
  [pt1, eta1, phi1, m1, pt2, eta2, phi2, m2]
Output: per-event invariant mass (n,) and per-tile partial histograms
(n_tiles, NBINS); L2 sums partials into the final (NBINS,) histogram.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * the histogram is computed as ones(1,t) @ one_hot(idx) — a matmul that
    maps onto the MXU systolic array — instead of the GPU-style
    scatter-add, which TPUs do not do well;
  * per-tile partials avoid cross-grid-step accumulation (no carried VMEM
    state), so grid steps stay independent and pipelineable;
  * everything stays f32: mass resolution near narrow resonances is the
    physics signal, bf16 would smear it.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048
NBINS = 64
HIST_LO = 0.0
HIST_HI = 160.0  # GeV; covers the J/psi..Z-like range of the toy spectrum


def _four_vector(pt, eta, phi, m):
    px = pt * jnp.cos(phi)
    py = pt * jnp.sin(phi)
    pz = pt * jnp.sinh(eta)
    e = jnp.sqrt(px * px + py * py + pz * pz + m * m)
    return px, py, pz, e


def _mass_hist_kernel(cols_ref, mass_ref, hist_ref):
    c = cols_ref[...]  # (tile, 8)
    px1, py1, pz1, e1 = _four_vector(c[:, 0], c[:, 1], c[:, 2], c[:, 3])
    px2, py2, pz2, e2 = _four_vector(c[:, 4], c[:, 5], c[:, 6], c[:, 7])
    e = e1 + e2
    px, py, pz = px1 + px2, py1 + py2, pz1 + pz2
    m2 = e * e - (px * px + py * py + pz * pz)
    mass = jnp.sqrt(jnp.maximum(m2, 0.0))
    mass_ref[...] = mass

    # Histogram as a one-hot matmul (MXU-friendly reduction).
    width = (HIST_HI - HIST_LO) / NBINS
    idx = jnp.clip(
        jnp.floor((mass - HIST_LO) / width), 0.0, float(NBINS - 1)
    ).astype(jnp.int32)
    bins = jax.lax.broadcasted_iota(jnp.int32, (mass.shape[0], NBINS), 1)
    onehot = (idx[:, None] == bins).astype(jnp.float32)  # (tile, NBINS)
    ones = jnp.ones((1, mass.shape[0]), dtype=jnp.float32)
    hist_ref[...] = jnp.dot(ones, onehot)  # (1, NBINS)


def mass_hist(cols, tile=TILE):
    """cols: (n, 8) f32 -> (mass (n,), partial_hist (n//tile, NBINS))."""
    n = cols.shape[0]
    if n % tile != 0:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    ntiles = n // tile
    return pl.pallas_call(
        _mass_hist_kernel,
        grid=(ntiles,),
        in_specs=[pl.BlockSpec((tile, 8), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((ntiles, NBINS), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT execution path; see DESIGN.md
    )(cols)
