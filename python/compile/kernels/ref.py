"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `uniform_ref` must match the PRNG
kernel *bit-exactly* (the rust pipeline's compression tests rely on a
deterministic byte stream), `mass_hist_ref` within float tolerance.
No pallas imports here — plain jax.numpy only.
"""

import jax.numpy as jnp

from .physics import HIST_HI, HIST_LO, NBINS
from .prng import GOLDEN, SPLIT, lowbias32


def uniform_ref(seed, n, ncols):
    """Reference (n, ncols) uniforms for a (2,) uint32 seed vector."""
    ctr = jnp.arange(n * ncols, dtype=jnp.uint32).reshape(n, ncols)
    x = ctr ^ (seed[0] * GOLDEN) ^ (seed[1] * SPLIT)
    x = lowbias32(x)
    return (x >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )


def _four_vector(pt, eta, phi, m):
    px = pt * jnp.cos(phi)
    py = pt * jnp.sin(phi)
    pz = pt * jnp.sinh(eta)
    e = jnp.sqrt(px * px + py * py + pz * pz + m * m)
    return px, py, pz, e


def mass_ref(cols):
    """Reference per-event invariant mass for an (n, 8) column block."""
    px1, py1, pz1, e1 = _four_vector(
        cols[:, 0], cols[:, 1], cols[:, 2], cols[:, 3]
    )
    px2, py2, pz2, e2 = _four_vector(
        cols[:, 4], cols[:, 5], cols[:, 6], cols[:, 7]
    )
    e = e1 + e2
    px, py, pz = px1 + px2, py1 + py2, pz1 + pz2
    m2 = e * e - (px * px + py * py + pz * pz)
    return jnp.sqrt(jnp.maximum(m2, 0.0))


def hist_ref(mass):
    """Reference histogram of the mass spectrum."""
    width = (HIST_HI - HIST_LO) / NBINS
    idx = jnp.clip(
        jnp.floor((mass - HIST_LO) / width), 0.0, float(NBINS - 1)
    ).astype(jnp.int32)
    return (
        (idx[:, None] == jnp.arange(NBINS)[None, :])
        .astype(jnp.float32)
        .sum(axis=0)
    )


def mass_hist_ref(cols):
    mass = mass_ref(cols)
    return mass, hist_ref(mass)
