"""L1 Pallas kernel: counter-based pseudo-random event-column generator.

The paper's Figure 6 benchmark "generates 1GB of pseudo-random numbers and
writes them out as a single column data file"; Figure 3's CMSSW streams
generate events before the output module writes them. This kernel is that
data source, as a counter-based (stateless, splittable) PRNG so every tile
of the output is independent — exactly the property the L3 coordinator
needs to generate event blocks from many threads without shared state.

Design (TPU adaptation, DESIGN.md §Hardware-Adaptation):
  * grid over row-tiles; each grid step materialises a (TILE, NCOLS) f32
    block in VMEM — no HBM round-trips inside a step;
  * the counter is derived from (program_id, iota) so there is no carried
    state between grid steps (trivially parallel on the grid);
  * mixing is `lowbias32`, a 3-round xorshift-multiply hash with good
    avalanche — integer ALU only, no MXU contention with the analysis
    kernel it overlaps with.

Must stay bit-identical to `ref.uniform_ref` (pytest enforces exact
equality, not allclose, since the pipeline's compression tests depend on a
deterministic byte stream).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Default tile height: 2048 rows x 8 cols x 4B = 64 KiB per block, far under
# VMEM (~16 MiB/core); room for double buffering and the analysis kernel.
TILE = 2048

# numpy scalars, not jnp arrays: jnp constants created at import time would
# be *captured* by the pallas kernel trace, which pallas_call rejects.
GOLDEN = np.uint32(0x9E3779B9)  # 2^32 / phi, decorrelates seed from counter
SPLIT = np.uint32(0x85EBCA6B)  # stream splitting constant (from murmur3)


def lowbias32(x):
    """3-round integer mixer (avalanche ~0.17% bias). Wraps on uint32."""
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return x


def _uniform_kernel(seed_ref, o_ref):
    """One grid step: fill a (tile, ncols) block with uniforms in [0, 1)."""
    tile = pl.program_id(0)
    n, c = o_ref.shape
    row = jax.lax.broadcasted_iota(jnp.uint32, (n, c), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (n, c), 1)
    # Global flat counter for this lane; independent of grid decomposition.
    ctr = (tile.astype(jnp.uint32) * np.uint32(n) + row) * np.uint32(c) + col
    x = ctr ^ (seed_ref[0] * GOLDEN) ^ (seed_ref[1] * SPLIT)
    x = lowbias32(x)
    # Top 24 bits -> [0, 1) exactly representable in f32.
    o_ref[...] = (x >> np.uint32(8)).astype(jnp.float32) * np.float32(
        1.0 / (1 << 24)
    )


def uniform(seed, n, ncols, tile=TILE):
    """(n, ncols) f32 uniforms in [0,1) from a (2,) uint32 seed vector.

    `n` must be a multiple of `tile`.
    """
    if n % tile != 0:
        raise ValueError(f"n={n} not a multiple of tile={tile}")
    return pl.pallas_call(
        _uniform_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((tile, ncols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ncols), jnp.float32),
        interpret=True,  # CPU-PJRT execution path; see DESIGN.md
    )(seed)
