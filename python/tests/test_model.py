"""L2 model checks: column shaping, physics ranges, lowering round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import physics, ref


def test_shape_columns_ranges():
    u = ref.uniform_ref(jnp.array([5, 0], dtype=jnp.uint32), 2048, 8)
    cols = np.asarray(model.shape_columns(u))
    assert cols.shape == (2048, 8)
    for leg in (0, 4):
        pt, eta, phi, m = (cols[:, leg + i] for i in range(4))
        assert (pt >= 0).all() and np.isfinite(pt).all()
        assert (np.abs(eta) <= model.ETA_RANGE + 1e-6).all()
        assert (phi >= -np.pi - 1e-6).all() and (phi < np.pi + 1e-6).all()
        assert np.allclose(m, model.MUON_MASS, rtol=0.01)


def test_generate_events_deterministic():
    s = jnp.array([42, 7], dtype=jnp.uint32)
    a = np.asarray(model.generate_events(s, 512, tile=128))
    b = np.asarray(model.generate_events(s, 512, tile=256))
    np.testing.assert_array_equal(a, b)


def test_analyze_events_shapes():
    s = jnp.array([1, 1], dtype=jnp.uint32)
    cols = model.generate_events(s, 512, tile=128)
    mass, hist = model.analyze_events(cols, tile=128)
    assert mass.shape == (512,)
    assert hist.shape == (physics.NBINS,)
    assert float(jnp.sum(hist)) == 512.0


def test_pt_distribution_is_exponential_like():
    s = jnp.array([9, 4], dtype=jnp.uint32)
    cols = np.asarray(model.generate_events(s, 16384, tile=2048))
    pt = cols[:, 0]
    # exponential with scale PT_SCALE: mean ~ PT_SCALE (clamp-truncated)
    assert abs(pt.mean() - model.PT_SCALE) / model.PT_SCALE < 0.05


@pytest.mark.parametrize("n", [4096])
def test_lowering_emits_hlo_text(n):
    text = aot.lower_gen(n)
    assert "HloModule" in text and "ROOT" in text
    text2 = aot.lower_analyze(n)
    assert "HloModule" in text2


@pytest.mark.parametrize("n", [4096])
def test_lowered_gen_matches_eager(n, tmp_path):
    """The lowered artifact computes the same thing jax computes eagerly."""
    from jax._src.lib import xla_client as xc

    s = jnp.array([8, 2], dtype=jnp.uint32)
    want = np.asarray(model.generate_events(s, n))
    fn = lambda seed: (model.generate_events(seed, n),)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), jnp.uint32))
    got = np.asarray(lowered.compile()(s)[0])
    # XLA may fuse transcendentals differently under AOT compile options;
    # allow last-ulp-level drift.
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
