"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The PRNG comparison is *exact* (bit-identical uint32 mixing); the physics
comparison is allclose. Hypothesis sweeps seeds, shapes and tiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import physics, prng, ref

TILES = [128, 256, 512]


def seeds(draw_seed, draw_stream):
    return jnp.array([draw_seed, draw_stream], dtype=jnp.uint32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    stream=st.integers(0, 2**32 - 1),
    ntiles=st.integers(1, 4),
    tile=st.sampled_from(TILES),
    ncols=st.sampled_from([1, 3, 8]),
)
def test_uniform_matches_ref_bitexact(seed, stream, ntiles, tile, ncols):
    s = seeds(seed, stream)
    n = ntiles * tile
    got = prng.uniform(s, n, ncols, tile=tile)
    want = ref.uniform_ref(s, n, ncols)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_uniform_range_and_spread():
    s = seeds(7, 1)
    u = np.asarray(prng.uniform(s, 4096, 8, tile=512))
    assert u.min() >= 0.0 and u.max() < 1.0
    # crude uniformity: mean ~0.5, std ~1/sqrt(12)
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.std() - 0.2887) < 0.01


def test_uniform_tile_decomposition_invariant():
    """Counter-based: the same n must give the same stream for any tile."""
    s = seeds(123, 9)
    a = np.asarray(prng.uniform(s, 1024, 8, tile=128))
    b = np.asarray(prng.uniform(s, 1024, 8, tile=512))
    np.testing.assert_array_equal(a, b)


def test_uniform_streams_differ():
    a = np.asarray(prng.uniform(seeds(1, 0), 512, 8, tile=128))
    b = np.asarray(prng.uniform(seeds(1, 1), 512, 8, tile=128))
    assert (a != b).mean() > 0.99


def test_uniform_rejects_ragged_n():
    with pytest.raises(ValueError):
        prng.uniform(seeds(0, 0), 100, 8, tile=64)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    ntiles=st.integers(1, 4),
    tile=st.sampled_from(TILES),
)
def test_mass_hist_matches_ref(seed, ntiles, tile):
    n = ntiles * tile
    u = ref.uniform_ref(seeds(seed, 0), n, 8)
    from compile import model

    cols = model.shape_columns(u)
    mass, partials = physics.mass_hist(cols, tile=tile)
    hist = jnp.sum(partials, axis=0)
    want_mass, want_hist = ref.mass_hist_ref(cols)
    # m^2 = E^2 - |p|^2 suffers catastrophic cancellation for high-pt
    # events, so tolerate ~1e-3 absolute; the shapes must still agree.
    # pt tails reach ~400 GeV, so E^2 ~ 1e5 and f32 eps on m^2 is ~1e-2;
    # for light pairs the induced mass error is O(eps_m2 / 2m).
    np.testing.assert_allclose(
        np.asarray(mass), np.asarray(want_mass), rtol=2e-3, atol=5e-2
    )
    # Binning must be exact *given the kernel's own mass* (boundary events
    # may legitimately flip bins between the two mass computations).
    np.testing.assert_allclose(
        np.asarray(hist), np.asarray(ref.hist_ref(mass))
    )
    assert float(jnp.sum(hist)) == n


def test_hist_counts_all_events():
    n = 2048
    u = ref.uniform_ref(seeds(3, 3), n, 8)
    from compile import model

    cols = model.shape_columns(u)
    _, partials = physics.mass_hist(cols, tile=256)
    assert float(jnp.sum(partials)) == n


def test_mass_is_nonnegative_and_finite():
    u = ref.uniform_ref(seeds(11, 2), 1024, 8)
    from compile import model

    cols = model.shape_columns(u)
    mass, _ = physics.mass_hist(cols, tile=256)
    m = np.asarray(mass)
    assert np.isfinite(m).all() and (m >= 0).all()


def test_mass_hist_rejects_ragged_n():
    with pytest.raises(ValueError):
        physics.mass_hist(jnp.zeros((100, 8), jnp.float32), tile=64)


def test_known_two_body_mass():
    """Back-to-back legs with equal pt and opposite phi: closed form."""
    pt, m = 40.0, 0.1057
    cols = jnp.array(
        [[pt, 0.0, 0.0, m, pt, 0.0, np.pi, m]], dtype=jnp.float32
    )
    cols = jnp.tile(cols, (128, 1))
    mass, _ = physics.mass_hist(cols, tile=128)
    e = np.sqrt(pt**2 + m**2)
    want = np.sqrt((2 * e) ** 2)  # momenta cancel exactly
    np.testing.assert_allclose(np.asarray(mass), want, rtol=1e-5)
