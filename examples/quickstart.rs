//! Quickstart — the paper's Figure 5 example, ported.
//!
//! Left side of Figure 5 (sequential `TFile`) vs right side
//! (`TBufferMerger` with worker threads): fill a one-branch tree with
//! `nEntries` integers, sequentially and in parallel, and verify both
//! files contain the same data.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::write::write_blocks;
use rootio_par::format::reader::FileReader;
use rootio_par::merger::{MergerConfig, TBufferMerger};
use rootio_par::serial::column::ColumnData;
use rootio_par::serial::schema::{ColumnType, Field, Schema};
use rootio_par::serial::value::Value;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::writer::{FlushMode, WriterConfig};

const N_ENTRIES: usize = 100_000;
const N_WORKERS: usize = 4;

/// Figure 5, left: sequential usage of TFile.
fn write_tree_sequential() -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let schema = Schema::new(vec![Field::new("n", ColumnType::I32)]);
    let block = vec![ColumnData::I32((0..N_ENTRIES as i32).collect())];
    write_blocks(
        be.clone(),
        schema,
        "mytree",
        WriterConfig {
            basket_entries: 4096,
            compression: Settings::new(Codec::Rzip, 4),
            flush: FlushMode::Serial,
            ..Default::default()
        },
        vec![block],
    )?;
    Ok(be)
}

/// Figure 5, right: parallel usage of TFile with TBufferMerger.
fn write_tree_parallel() -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let schema = Schema::new(vec![Field::new("n", ColumnType::I32)]);
    let merger = TBufferMerger::create(
        be.clone(),
        schema,
        MergerConfig {
            tree_name: "mytree".into(),
            queue_depth: N_WORKERS,
            writer: WriterConfig {
                basket_entries: 4096,
                compression: Settings::new(Codec::Rzip, 4),
                // workers pipeline their flushes when IMT is enabled
                flush: FlushMode::Pipelined,
                ..Default::default()
            },
        },
    )?;
    let per_worker = N_ENTRIES / N_WORKERS;
    std::thread::scope(|s| {
        for w in 0..N_WORKERS {
            // auto f = merger.GetFile();
            let mut f = merger.get_file();
            s.spawn(move || {
                // Fill(t, i * nEntriesPerWorker, nEntriesPerWorker)
                for i in 0..per_worker {
                    f.fill(vec![Value::I32((w * per_worker + i) as i32)]).unwrap();
                }
                // f->Write(): send content over the wire
                f.write().unwrap();
            });
        }
    });
    merger.close()?;
    Ok(be)
}

fn read_sorted(be: BackendRef) -> anyhow::Result<Vec<i32>> {
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
    let cols = reader.read_all()?;
    let mut vals: Vec<i32> = (0..reader.entries() as usize)
        .map(|i| match cols[0].get(i).unwrap() {
            Value::I32(v) => v,
            _ => unreachable!(),
        })
        .collect();
    vals.sort();
    Ok(vals)
}

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let seq = write_tree_sequential()?;
    let t_seq = t0.elapsed();

    let t1 = std::time::Instant::now();
    let par = write_tree_parallel()?;
    let t_par = t1.elapsed();

    let a = read_sorted(seq)?;
    let b = read_sorted(par)?;
    assert_eq!(a, b, "sequential and parallel files hold the same entries");
    assert_eq!(a.len(), N_ENTRIES);

    println!("quickstart OK: {N_ENTRIES} entries");
    println!("  sequential TFile write: {:>8.1} ms", t_seq.as_secs_f64() * 1e3);
    println!(
        "  TBufferMerger x{N_WORKERS}:      {:>8.1} ms ({:.2}x)",
        t_par.as_secs_f64() * 1e3,
        t_seq.as_secs_f64() / t_par.as_secs_f64()
    );
    Ok(())
}
