//! Quickstart — the paper's Figure 5 example, grown to a shared I/O
//! session.
//!
//! Four ways to write the same data:
//! 1. sequential `TFile` (Figure 5, left);
//! 2. `TBufferMerger` with worker threads into ONE file (Figure 5,
//!    right) — the workers' pipelined flushes share the merger's
//!    session budget;
//! 3. a shared [`Session`]: N writers, N files (and a two-trees-in-
//!    one-file variant), all drawing from one pool and one fair-share
//!    in-flight budget — the multi-output production shape;
//! 4. **adaptive cluster sizing**: the same pipelined writer with
//!    `WriterConfig::sizing = ClusterSizing::Adaptive(..)`, which
//!    resizes clusters *between* flushes from the measured
//!    stall/compress ratio and the session's admission-wait feedback.
//!    Narrow fast producers cut smaller clusters to keep the pool
//!    fed; compression-bound writers grow clusters to amortise
//!    per-basket overhead — with hysteresis and min/max clamps, and
//!    every decision recorded in a replayable trace. Cluster
//!    boundaries become schedule-dependent, but the decoded data is
//!    always entry-identical to a fixed-size write (the stress suite
//!    asserts exactly this); the chosen band is reported through
//!    `WriteReport::sizing`.
//! 5. **streaming reads through the read-ahead cache**: instead of
//!    materialising whole columns, `TreeReader::stream` walks the
//!    cluster list ahead of the consumer — one *coalesced* device read
//!    per cluster window (TTreeCache-style), per-basket decode tasks
//!    on the IMT pool, and decoded clusters handed out strictly in
//!    order. The prefetch window is sized adaptively from the
//!    fetch-stall/decode ratio (slow storage reads further ahead; fast
//!    storage keeps memory flat), and N streams attached to one
//!    `Session` split its read budget fair-share. `ReadOptions::
//!    prefetch` routes `coordinator::read::read_columns` through the
//!    same cache; `framework::dataset::scan_file` is the bounded-
//!    memory whole-file scan.
//! 6. **reading from unreliable storage**: the same streaming scan
//!    against a simulated remote object store ([`RemoteDevice`]:
//!    heavy-tailed first-byte latency, bounded request slots, seeded
//!    transient faults) through a [`ResilientBackend`] — per-request
//!    deadlines, retry with seeded backoff, hedged reads at ~p99 to
//!    cut the tail, and a circuit breaker that sheds only speculative
//!    read-ahead while consumer-demanded head reads keep probing. The
//!    prefetcher sees the breaker as `BackendHealth::Degraded` and
//!    shrinks to head-only fetching instead of failing; decoded data
//!    stays byte-identical to a fault-free serial read either way.
//! 7. **per-column adaptive codec selection**: set
//!    `WriterConfig::selection = CodecSelection::PerColumn(..)` and the
//!    writer attaches a tiny controller to each branch. It probes the
//!    candidate codec×level list on the column's first baskets, scores
//!    each candidate `ratio × throughput^speed_weight` from the
//!    measured flush results, commits the winner for that column, and
//!    re-probes if the data drifts. Noise floats commit to raw
//!    storage, narrow ints to the entropy coder, text to whichever
//!    earns its CPU — in one tree. Every basket records its own codec
//!    in the directory, so readers (and `hadd`) need no flag; the
//!    `WriteReport::selection` summary counts columns committed,
//!    probes and re-probes, and `TreeWriter::selector_trace` replays
//!    the per-branch decisions.
//! 8. **paged columnar layout (wire v3) + projection pushdown**: set
//!    `WriterConfig::layout = Layout::paged()` and each cluster is
//!    stored column-major as independently compressed per-column
//!    pages, with variable-length branches (`ColumnType::ListF32`)
//!    split into offset/element page pairs. A projected read
//!    (`ReadOptions::branches`) then fetches only the selected
//!    columns' page ranges — the `ReadReport` comes back with
//!    `bytes_selected`/`bytes_skipped` showing what the pushdown
//!    avoided reading; on the classic layout the same selection still
//!    decodes only the chosen branches but must fetch whole clusters.
//! 9. **dataset chains + zone-map predicate pushdown (wire v4)**: a
//!    [`Chain`] strings N same-schema files into one stream of row
//!    batches — the next file's clusters are primed while the current
//!    file drains, so crossing a file boundary never stalls the
//!    consumer. Every page seal records the page's min/max in the
//!    footer directory, and `Chain::scan_where` pushes a
//!    `branch op constant` predicate into each file's fetch plan:
//!    pages whose zone provably excludes every matching row are never
//!    fetched from the device (`pages_pruned`/`bytes_pruned` in the
//!    report), and the surviving rows are re-filtered exactly, so the
//!    result is row-identical to scanning everything and filtering.
//!    Files written before wire v4 have no zones and simply scan
//!    unpruned.
//! 10. **observability**: build the session with
//!    `SessionConfig::default().traced()` and every pool task, budget
//!    admission wait, coalesced device read, retry/hedge, basket
//!    decode, page seal, zone prune and chain file-advance lands in a
//!    sharded per-thread [`Recorder`] — no lock on the record path, and
//!    a disabled recorder costs one branch. `recorder.timeline_ascii`
//!    draws the per-thread schedule in the terminal,
//!    `recorder.to_chrome_json()` exports a Perfetto-loadable trace,
//!    and `session.metrics().snapshot()` folds every stats struct into
//!    one named counter/gauge/histogram registry (window latency,
//!    basket compress, device read percentiles). The same surface is on
//!    the CLI: `rootio trace bench --out trace.json` traces a real
//!    write+pruned-chain-scan pipeline, `rootio stats` dumps the
//!    registry as JSON, and `rootio summary` collects every
//!    `BENCH_fig*.json` + trace/stats snapshot into `BENCH_summary.json`
//!    and fails on a >2x regression vs `bench_baselines.json`. See also
//!    `cargo run --release --example trace_a_scan` (in rust/examples/)
//!    for the minimal runnable version.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use rootio_par::cache::{PrefetchOptions, Predicate, WindowConfig, WindowPolicy};
use rootio_par::framework::chain::Chain;
use rootio_par::compress::select::{CodecSelection, SelectConfig};
use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::write::{
    write_blocks, write_blocks_in_session, write_files, WriteJob,
};
use rootio_par::format::reader::FileReader;
use rootio_par::format::writer::FileWriter;
use rootio_par::merger::{MergerConfig, TBufferMerger};
use rootio_par::serial::column::ColumnData;
use rootio_par::serial::schema::{ColumnType, Field, Schema};
use rootio_par::serial::value::Value;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::remote::{RemoteConfig, RemoteDevice};
use rootio_par::storage::resilient::{
    HedgePolicy, ResilientBackend, ResilientConfig, RetryPolicy,
};
use rootio_par::storage::{Backend, BackendRef};
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::sink::FileSink;
use rootio_par::tree::sizer::{AdaptiveConfig, ClusterSizing};
use rootio_par::coordinator::read::{read_columns, ReadOptions};
use rootio_par::tree::writer::{FlushMode, Layout, TreeWriter, WriterConfig};

const N_ENTRIES: usize = 100_000;
const N_WORKERS: usize = 4;

fn schema() -> Schema {
    Schema::new(vec![Field::new("n", ColumnType::I32)])
}

fn writer_config() -> WriterConfig {
    WriterConfig {
        basket_entries: 4096,
        compression: Settings::new(Codec::Rzip, 4),
        flush: FlushMode::Pipelined,
        ..Default::default()
    }
}

/// Figure 5, left: sequential usage of TFile.
fn write_tree_sequential() -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let block = vec![ColumnData::I32((0..N_ENTRIES as i32).collect())];
    write_blocks(
        be.clone(),
        schema(),
        "mytree",
        WriterConfig { flush: FlushMode::Serial, ..writer_config() },
        vec![block],
    )?;
    Ok(be)
}

/// Figure 5, right: parallel usage of TFile with TBufferMerger. The
/// worker files all attach to the merger's session, so their pipelined
/// flushes share one pool and one in-flight budget.
fn write_tree_merger(session: &Session) -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let merger = TBufferMerger::create_in_session(
        be.clone(),
        schema(),
        MergerConfig {
            tree_name: "mytree".into(),
            queue_depth: N_WORKERS,
            writer: writer_config(),
        },
        None,
        session,
    )?;
    let per_worker = N_ENTRIES / N_WORKERS;
    std::thread::scope(|s| {
        for w in 0..N_WORKERS {
            // auto f = merger.GetFile();
            let mut f = merger.get_file();
            s.spawn(move || {
                // Fill(t, i * nEntriesPerWorker, nEntriesPerWorker)
                for i in 0..per_worker {
                    f.fill(vec![Value::I32((w * per_worker + i) as i32)]).unwrap();
                }
                // f->Write(): send content over the wire
                f.write().unwrap();
            });
        }
    });
    merger.close()?;
    Ok(be)
}

/// The session shape: N writers, N files, one shared budget. Each
/// output is byte-identical to the same writer run alone — the session
/// only coordinates scheduling and memory, never bytes.
fn write_many_files(session: &Session) -> anyhow::Result<Vec<BackendRef>> {
    let per_worker = N_ENTRIES / N_WORKERS;
    let backends: Vec<BackendRef> =
        (0..N_WORKERS).map(|_| Arc::new(MemBackend::new()) as BackendRef).collect();
    let jobs: Vec<WriteJob> = backends
        .iter()
        .enumerate()
        .map(|(w, be)| WriteJob {
            backend: be.clone(),
            schema: schema(),
            name: "mytree".into(),
            config: writer_config(),
            blocks: vec![vec![ColumnData::I32(
                (0..per_worker as i32).map(|i| (w * per_worker) as i32 + i).collect(),
            )]],
        })
        .collect();
    write_files(session, jobs)?;
    Ok(backends)
}

/// Adaptive cluster sizing: keep the default starting basket size and
/// let the writer's feedback controller pick the cluster size — the
/// `WriteReport` comes back with the band it actually used.
fn write_tree_adaptive(session: &Session) -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let cfg = WriterConfig {
        // ×8 clamp band either side of basket_entries; see
        // AdaptiveConfig for the thresholds/hysteresis knobs.
        sizing: ClusterSizing::Adaptive(AdaptiveConfig::around(4096)),
        ..writer_config()
    };
    let block = vec![ColumnData::I32((0..N_ENTRIES as i32).collect())];
    let rep = write_blocks_in_session(session, be.clone(), schema(), "mytree", cfg, vec![block])?;
    println!(
        "  adaptive writer: clusters {}..{} entries (last {}, +{} -{} steps, \
         stall {} ms)",
        rep.sizing.min_entries,
        rep.sizing.max_entries,
        rep.sizing.last_entries,
        rep.sizing.grows,
        rep.sizing.shrinks,
        rep.stall.as_millis(),
    );
    Ok(be)
}

/// Per-column codec selection: a mixed tree (noise floats, narrow-range
/// ints, text tags) where no global codec is right for every branch.
/// The selector probes each column's early baskets and commits one
/// codec per branch; the decoded data is identical to any global-codec
/// write, only the stored bytes and compression CPU move.
fn write_tree_per_column(session: &Session) -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let mixed = Schema::new(vec![
        Field::new("energy", ColumnType::F32),
        Field::new("adc", ColumnType::I32),
        Field::new("tag", ColumnType::U8),
    ]);
    let cfg = WriterConfig {
        // The fallback codec still applies until a column commits;
        // SelectConfig holds the candidate list, probe length, the
        // ratio-vs-speed weighting and the drift re-probe knobs.
        selection: CodecSelection::PerColumn(SelectConfig::default()),
        ..writer_config()
    };
    let block = vec![
        ColumnData::F32((0..N_ENTRIES).map(|i| (i as f32).sin() * 1e3).collect()),
        ColumnData::I32((0..N_ENTRIES).map(|i| (i % 4) as i32).collect()),
        ColumnData::U8((0..N_ENTRIES).map(|i| b"pixel strip "[i % 12]).collect()),
    ];
    let rep =
        write_blocks_in_session(session, be.clone(), mixed, "mixed", cfg, vec![block])?;
    println!(
        "  per-column selection: {}/{} columns committed after {} probes \
         ({} re-probes), ratio {:.2}",
        rep.selection.committed,
        rep.selection.columns,
        rep.selection.probes,
        rep.selection.reprobes,
        rep.compression_ratio(),
    );
    Ok(be)
}

/// Two trees, one file, written concurrently under the session: each
/// writer's sink registers its tree as it closes and the file commits
/// one deterministic (name-sorted) footer.
fn write_two_trees_one_file(session: &Session) -> anyhow::Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone())?);
    std::thread::scope(|s| {
        for name in ["electrons", "muons"] {
            let sink = FileSink::new(fw.clone(), 1);
            let mut w = TreeWriter::attached(schema(), sink, writer_config(), session);
            s.spawn(move || {
                for i in 0..10_000 {
                    w.fill(vec![Value::I32(i)]).unwrap();
                }
                let (sink, entries, _) = w.close().unwrap();
                sink.finish_tree(name.into(), schema(), entries).unwrap();
            });
        }
    });
    fw.finish_registered()?;
    Ok(be)
}

/// Streaming read: consume the tree cluster-by-cluster through the
/// prefetching read-ahead cache. Memory stays bounded by the window
/// (each in-flight cluster holds one session read-budget slot), and
/// the decoded values are identical to a serial `read_all`.
fn stream_scan(be: BackendRef, session: &Session) -> anyhow::Result<u64> {
    let reader = TreeReader::open(Arc::new(FileReader::open(be)?), "mytree")?;
    let mut stream = reader.stream_in_session(
        &PrefetchOptions {
            // Adaptive window (the default): grows under fetch stall,
            // shrinks on fast storage. WindowPolicy::Fixed(k) pins it.
            window: WindowPolicy::Adaptive(WindowConfig::default()),
            ..Default::default()
        },
        session,
    )?;
    let mut entries = 0u64;
    while let Some(cluster) = stream.next()? {
        // cluster.columns: one decoded chunk per branch, in order —
        // process and drop; the slot frees for the next window.
        entries += cluster.entries;
    }
    let st = stream.stats();
    println!(
        "  streaming scan: {} clusters, {} baskets in {} device reads \
         ({:.1}x coalesced), window {}..{}, stall {} ms",
        st.clusters,
        st.baskets,
        st.device_reads,
        st.coalescing_factor(),
        st.window.min_entries,
        st.window.max_entries,
        st.fetch_stall.as_millis(),
    );
    Ok(entries)
}

/// Reading from unreliable storage: stage the file on a simulated
/// remote object store (lognormal first-byte latency, every 40th
/// request faulting) and stream it through the resilience wrapper —
/// deadlines, retries, hedged reads, breaker. The consumer never sees
/// a fault; the stats show what the wrapper absorbed.
fn stream_remote_resilient(local: BackendRef, session: &Session) -> anyhow::Result<()> {
    // Copy the already-written file onto the remote store.
    let len = local.len()?;
    let mut bytes = vec![0u8; len as usize];
    local.read_at(0, &mut bytes)?;
    let remote = Arc::new(RemoteDevice::new(
        RemoteConfig {
            first_byte_p50: std::time::Duration::from_micros(300),
            first_byte_p99: std::time::Duration::from_millis(2),
            fault_every_nth: 40,
            ..RemoteConfig::default()
        },
        1.0, // sleep real (scaled) time; 0.0 would only account
    ));
    remote.preload(0, &bytes)?;

    // Deadline a bit past p99, hedge at p99, retry transient blips
    // with seeded jittered backoff. Hedge slots draw from the
    // session's shared budget (SessionConfig::max_hedged_reads).
    let resilient: BackendRef = Arc::new(ResilientBackend::in_session(
        remote,
        ResilientConfig {
            retry: RetryPolicy::default(),
            hedge: Some(HedgePolicy::at_p99(std::time::Duration::from_millis(2))),
            deadline: Some(std::time::Duration::from_millis(12)),
            ..Default::default()
        },
        session,
    ));
    let reader = TreeReader::open(Arc::new(FileReader::open(resilient)?), "mytree")?;
    let mut stream = reader.stream_in_session(&PrefetchOptions::default(), session)?;
    let mut entries = 0u64;
    while let Some(cluster) = stream.next()? {
        entries += cluster.entries;
    }
    assert_eq!(entries, N_ENTRIES as u64);
    let st = stream.stats();
    println!(
        "  remote resilient scan: {} clusters, {} retries, {} hedges \
         ({} won), {} deadline misses, {} degraded windows",
        st.clusters,
        st.retries,
        st.hedges,
        st.hedge_wins,
        st.deadline_misses,
        st.degraded_windows,
    );
    Ok(())
}

/// Paged layout + projection pushdown: an event tree with a
/// variable-length branch, written as per-column pages (wire v3), then
/// scanned twice — whole-tree and projected to two branches. The
/// projected scan's fetch plan only covers the selected columns'
/// pages; the report's byte split shows what pushdown skipped.
fn write_paged_and_project(session: &Session) -> anyhow::Result<BackendRef> {
    let events = Schema::new(vec![
        Field::new("pt", ColumnType::F32),
        Field::new("eta", ColumnType::F32),
        Field::new("ntrk", ColumnType::I32),
        // Variable-length: stored as an offset-page/element-page pair
        // per cluster chunk, so nested data pages like flat data.
        Field::new("hit_e", ColumnType::ListF32),
    ]);
    let be: BackendRef = Arc::new(MemBackend::new());
    let fw = Arc::new(FileWriter::create(be.clone())?);
    let sink = FileSink::new(fw.clone(), events.len());
    let cfg = WriterConfig {
        layout: Layout::paged(), // or Layout::Paged { page_entries: .. }
        ..writer_config()
    };
    let mut w = TreeWriter::attached(events.clone(), sink, cfg, session);
    for i in 0..N_ENTRIES {
        let hits: Vec<f32> = (0..i % 7).map(|k| (i + k) as f32 * 0.5).collect();
        w.fill(vec![
            Value::F32(i as f32 * 0.1),
            Value::F32((i % 50) as f32 * 0.01 - 0.25),
            Value::I32((i % 9) as i32),
            Value::ListF32(hits),
        ])?;
    }
    let (sink, entries, _) = w.close()?;
    let meta = sink.into_meta("events".into(), events, entries)?;
    fw.finish(&rootio_par::format::Directory { trees: vec![meta] })?;

    let reader = TreeReader::open(Arc::new(FileReader::open(be.clone())?), "events")?;
    let full = read_columns(
        &reader,
        &ReadOptions { prefetch: Some(PrefetchOptions::default()), ..Default::default() },
    )?;
    // Projection pushdown: fetch + decode only `pt` and `hit_e`.
    let projected = read_columns(
        &reader,
        &ReadOptions {
            branches: Some(vec![0, 3]),
            prefetch: Some(PrefetchOptions::default()),
            ..Default::default()
        },
    )?;
    assert_eq!(projected.columns.len(), 2);
    assert_eq!(projected.columns[0], full.columns[0]);
    assert_eq!(projected.columns[1], full.columns[3]);
    println!(
        "  paged projected scan: 2/4 branches, {} of {} stored KB selected \
         ({} KB skipped by pushdown)",
        projected.bytes_selected / 1024,
        (projected.bytes_selected + projected.bytes_skipped) / 1024,
        projected.bytes_skipped / 1024,
    );
    Ok(be)
}

/// Dataset chain + zone-map predicate pushdown: the production shape
/// where one dataset spans many files. Each file's page seals recorded
/// min/max zones in its footer; `scan_where` pushes the predicate into
/// every file's fetch plan, so the ~90% of pages that provably hold no
/// matching row are never read from the device — and the delivered
/// rows are exactly what a full scan plus a row filter would give.
fn chain_with_predicate() -> anyhow::Result<()> {
    let per_file = N_ENTRIES / 4;
    let files: Vec<BackendRef> = (0..4)
        .map(|f| -> anyhow::Result<BackendRef> {
            let be: BackendRef = Arc::new(MemBackend::new());
            let base = (f * per_file) as i32;
            let block = vec![ColumnData::I32(
                (0..per_file as i32).map(|i| base + i).collect(),
            )];
            write_blocks(be.clone(), schema(), "mytree", writer_config(), vec![block])?;
            Ok(be)
        })
        .collect::<anyhow::Result<_>>()?;

    let chain = Chain::new(files);
    let cutoff = N_ENTRIES as f64 * 0.9; // keep the top 10% of entries
    let mut rows = 0u64;
    let rep = chain.scan_where(
        Predicate::ge(0, cutoff),
        &PrefetchOptions::default(),
        |batch| rows += batch.rows() as u64,
    )?;
    assert_eq!(rows, rep.rows);
    let st = rep.prefetch;
    println!(
        "  chained predicate scan: {}/{} entries from {} files, {} pages pruned \
         ({} of {} stored KB never fetched)",
        rep.rows,
        rep.entries,
        rep.files,
        st.pages_pruned,
        st.bytes_pruned / 1024,
        (st.bytes_selected + st.bytes_pruned + st.bytes_skipped) / 1024,
    );
    Ok(())
}

/// Section 10: the same streaming scan, traced. The recorder rides in
/// the session config; afterwards the span buffer renders an ASCII
/// timeline and exports Chrome trace events, and the registry snapshot
/// reconciles the prefetch byte partition.
fn traced_scan(be: BackendRef) -> anyhow::Result<()> {
    let session = Session::new(SessionConfig::default().traced());
    let reader = TreeReader::open(Arc::new(FileReader::open(be)?), "mytree")?;
    let mut stream = reader.stream_in_session(&PrefetchOptions::fixed(4), &session)?;
    stream.read_all_columns()?;

    let rec = session.recorder();
    rec.check()?;
    println!(
        "  traced scan: {} spans, useful fraction {:.3}",
        rec.snapshot().len(),
        rec.useful_fraction()
    );
    // rec.to_chrome_json() is the Perfetto export; the registry snapshot
    // folds PrefetchStats/SessionStats into named counters + histograms.
    let mut snap = session.metrics().snapshot();
    snap.put_prefetch("prefetch", &stream.stats());
    snap.put_session(&session.stats());
    assert!(snap.counter("prefetch.stored_bytes").unwrap_or(0) > 0);
    Ok(())
}

fn read_sorted(be: BackendRef, tree: &str) -> anyhow::Result<Vec<i32>> {
    let reader = TreeReader::open(Arc::new(FileReader::open(be)?), tree)?;
    let cols = reader.read_all()?;
    let mut vals: Vec<i32> = (0..reader.entries() as usize)
        .map(|i| match cols[0].get(i).unwrap() {
            Value::I32(v) => v,
            _ => unreachable!(),
        })
        .collect();
    vals.sort();
    Ok(vals)
}

fn main() -> anyhow::Result<()> {
    rootio_par::imt::enable(N_WORKERS);
    // ONE session for every output the job opens: merger workers,
    // standalone writers, multi-tree files — one pool, one budget.
    let session = Session::new(SessionConfig::for_writers(N_WORKERS, 2));

    let t0 = std::time::Instant::now();
    let seq = write_tree_sequential()?;
    let t_seq = t0.elapsed();

    let t1 = std::time::Instant::now();
    let merged = write_tree_merger(&session)?;
    let t_merger = t1.elapsed();

    let t2 = std::time::Instant::now();
    let many = write_many_files(&session)?;
    let t_many = t2.elapsed();

    let two_trees = write_two_trees_one_file(&session)?;
    let adaptive = write_tree_adaptive(&session)?;

    // Mixed tree under per-column codec selection: readers stay
    // oblivious, each basket self-describes its codec.
    let mixed = write_tree_per_column(&session)?;
    let mixed_reader = TreeReader::open(Arc::new(FileReader::open(mixed)?), "mixed")?;
    assert_eq!(mixed_reader.entries(), N_ENTRIES as u64);
    assert_eq!(mixed_reader.read_all()?.len(), 3);

    // Paged v3 layout with a variable-length branch: projected scans
    // fetch only the selected columns' pages.
    write_paged_and_project(&session)?;

    // A multi-file dataset scanned as one chain, with a zone-map
    // predicate pushed into every file's fetch plan.
    chain_with_predicate()?;

    // Streaming scan of the sequential file through the read-ahead
    // cache: bounded memory, coalesced fetches, in-order clusters.
    assert_eq!(stream_scan(seq.clone(), &session)?, N_ENTRIES as u64);

    // The same scan from a flaky simulated remote store: the
    // resilience wrapper absorbs the faults, the data is identical.
    stream_remote_resilient(seq.clone(), &session)?;

    // The same scan once more, traced: spans + registry snapshot.
    traced_scan(seq.clone())?;

    let expect = read_sorted(seq, "mytree")?;
    assert_eq!(expect.len(), N_ENTRIES);
    assert_eq!(
        read_sorted(adaptive, "mytree")?,
        expect,
        "adaptive cluster sizes never change the data, only the cuts"
    );
    assert_eq!(read_sorted(merged, "mytree")?, expect, "merger file holds the same entries");
    let mut union: Vec<i32> = Vec::new();
    for be in many {
        union.extend(read_sorted(be, "mytree")?);
    }
    union.sort();
    assert_eq!(union, expect, "session-shared files hold the same entries");
    for tree in ["electrons", "muons"] {
        assert_eq!(read_sorted(two_trees.clone(), tree)?.len(), 10_000);
    }

    let st = session.stats();
    println!("quickstart OK: {N_ENTRIES} entries");
    println!("  sequential TFile write:   {:>8.1} ms", t_seq.as_secs_f64() * 1e3);
    println!(
        "  TBufferMerger x{N_WORKERS}:        {:>8.1} ms ({:.2}x)",
        t_merger.as_secs_f64() * 1e3,
        t_seq.as_secs_f64() / t_merger.as_secs_f64()
    );
    println!(
        "  session write_files x{N_WORKERS}:  {:>8.1} ms ({:.2}x)",
        t_many.as_secs_f64() * 1e3,
        t_seq.as_secs_f64() / t_many.as_secs_f64()
    );
    println!(
        "  session: {} writers opened, {} admissions ({} waited), budget {} clusters",
        st.writers_opened, st.admissions, st.admission_waits, st.budget_limit
    );
    rootio_par::imt::disable();
    Ok(())
}
