//! Parallel merge — the paper's §3.4 `hadd` scenario as an application.
//!
//! Produces N part-files (as a multi-process production would), then
//! merges them serially and with parallel input reading (`hadd -j`),
//! verifying the merged outputs are identical and the result contains
//! every input entry.
//!
//! Run: `cargo run --release --example parallel_merge [n_files]`

use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::experiments::util::synthesize_dataset;
use rootio_par::format::reader::FileReader;
use rootio_par::framework::dataset::DatasetKind;
use rootio_par::hadd::{hadd, HaddOptions};
use rootio_par::imt;
use rootio_par::runtime::Engine;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;

fn main() -> anyhow::Result<()> {
    let n_files: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(6);
    let engine = Engine::load_default().ok();
    let entries_per_file = 32_768;

    println!("producing {n_files} part-files x {entries_per_file} entries ...");
    let inputs: Vec<BackendRef> = (0..n_files)
        .map(|_| {
            synthesize_dataset(
                DatasetKind::Aod,
                entries_per_file,
                4096,
                Settings::new(Codec::Rzip, 4),
                engine.as_ref(),
            )
            .map(|(be, _)| be)
        })
        .collect::<Result<_, _>>()?;

    // serial merge
    imt::disable();
    let serial_out: BackendRef = Arc::new(MemBackend::new());
    let serial = hadd(serial_out.clone(), &inputs, &HaddOptions::default())?;

    // parallel merge (hadd -j)
    imt::enable(4);
    let par_out: BackendRef = Arc::new(MemBackend::new());
    let parallel = hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: None })?;
    imt::disable();

    println!(
        "serial   : {:>7.1} ms  ({} entries, {:.1} MB)",
        serial.wall.as_secs_f64() * 1e3,
        serial.entries,
        serial.stored_bytes as f64 / 1e6
    );
    println!(
        "hadd -j 4: {:>7.1} ms  ({:.2}x)",
        parallel.wall.as_secs_f64() * 1e3,
        serial.wall.as_secs_f64() / parallel.wall.as_secs_f64()
    );

    // verify: identical content, all entries present
    let read_all = |be: BackendRef| -> anyhow::Result<Vec<u32>> {
        let r = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
        let cols = r.read_all()?;
        Ok((0..r.entries() as usize)
            .map(|i| match cols[0].get(i).unwrap() {
                rootio_par::serial::value::Value::F32(v) => v.to_bits(),
                _ => unreachable!(),
            })
            .collect())
    };
    let a = read_all(serial_out)?;
    let b = read_all(par_out)?;
    assert_eq!(a, b, "serial and parallel hadd produce identical trees");
    assert_eq!(a.len(), n_files * entries_per_file);
    println!("parallel_merge OK: outputs identical ({} entries)", a.len());
    Ok(())
}
