//! End-to-end driver: the full three-layer system on a real workload.
//!
//! 1. **Generate** a physics dataset through the AOT-compiled
//!    JAX/Pallas PRNG graph (L1/L2) executed from rust via PJRT.
//! 2. **Write** it as a compressed columnar RNTF file with parallel
//!    per-branch compression (paper §3.1).
//! 3. **Read it back two ways**: per-column parallel read (Figure 1)
//!    and the basket-decompression pipeline *interleaved with PJRT
//!    analysis* (Figure 2), reporting speedups over serial.
//! 4. Print the dimuon mass spectrum computed by the Pallas kernel.
//!
//! This is the repo's headline-metric driver recorded in
//! EXPERIMENTS.md. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example analysis_pipeline`

use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::baskets::{self, PipelineOptions};
use rootio_par::coordinator::read::{read_columns, ReadOptions};
use rootio_par::experiments::util::synthesize_physics_file;
use rootio_par::format::reader::FileReader;
use rootio_par::imt;
use rootio_par::runtime::Engine;
use rootio_par::tree::reader::TreeReader;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()
        .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let entries = 262_144;
    let threads = imt::num_cpus().min(8);

    // --- 1+2: generate via PJRT, write compressed columnar file ------
    let t0 = std::time::Instant::now();
    let (be, wrep) =
        synthesize_physics_file(entries, Settings::new(Codec::Rzip, 4), Some(&engine))?;
    println!(
        "generated+wrote {} events ({:.1} MB raw -> {:.1} MB stored, ratio {:.2}) in {:.0} ms",
        wrep.entries,
        wrep.raw_bytes as f64 / 1e6,
        wrep.stored_bytes as f64 / 1e6,
        wrep.compression_ratio(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;

    // --- 3a: Figure 1 style parallel column read ---------------------
    imt::disable();
    let serial = read_columns(&reader, &ReadOptions { force_serial: true, ..Default::default() })?;
    imt::enable(threads);
    let parallel = read_columns(&reader, &ReadOptions::default())?;
    assert_eq!(serial.columns, parallel.columns);
    println!(
        "column read : serial {:.0} ms -> {} threads {:.0} ms ({:.2}x, {:.0} MB/s)",
        serial.wall.as_secs_f64() * 1e3,
        threads,
        parallel.wall.as_secs_f64() * 1e3,
        serial.wall.as_secs_f64() / parallel.wall.as_secs_f64(),
        parallel.throughput_mbps()
    );

    // --- 3b: Figure 2 style pipeline with interleaved PJRT analysis --
    imt::disable();
    let s = baskets::run(&reader, Some(&engine), &PipelineOptions { force_serial: true, ..Default::default() })?;
    imt::enable(threads);
    let p = baskets::run(&reader, Some(&engine), &PipelineOptions::default())?;
    imt::disable();
    assert_eq!(s.analyzed, p.analyzed);
    println!(
        "decomp+analyze: serial {:.0} ms -> {} threads {:.0} ms ({:.2}x), {} events analyzed",
        s.wall.as_secs_f64() * 1e3,
        threads,
        p.wall.as_secs_f64() * 1e3,
        s.wall.as_secs_f64() / p.wall.as_secs_f64(),
        p.analyzed
    );

    // --- 4: the physics result (computed by the Pallas kernel) -------
    let hist = p.hist.expect("analysis ran");
    let meta = engine.meta();
    let max = hist.iter().cloned().fold(1.0f32, f32::max);
    println!("\ndimuon mass spectrum [{:.0}, {:.0}] GeV:", meta.hist_lo, meta.hist_hi);
    for (i, &count) in hist.iter().enumerate().step_by(2) {
        let lo = meta.hist_lo + (meta.hist_hi - meta.hist_lo) * i as f64 / hist.len() as f64;
        println!("{lo:6.1} | {} {count}", "#".repeat((count / max * 48.0) as usize));
    }
    let total: f32 = hist.iter().sum();
    assert_eq!(total as u64, p.analyzed, "histogram counts every analyzed event");
    println!("\nanalysis_pipeline OK");
    Ok(())
}
