//! RECO production — the paper's Figure 3 scenario as an application.
//!
//! A CMSSW-like framework run: N streams generate RECO-shaped events
//! (48 wide columns) through the PJRT event generator and write them to
//! one output file. Three output configurations are compared at a fixed
//! stream count:
//!
//! * no output              (throughput ceiling)
//! * IMT off                (single-threaded output module)
//! * IMT on + TBufferMerger (the paper's contribution)
//!
//! Run: `cargo run --release --example reco_production [streams]`

use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::format::reader::FileReader;
use rootio_par::framework::dataset::DatasetKind;
use rootio_par::framework::{run, FrameworkConfig, OutputMode};
use rootio_par::imt;
use rootio_par::runtime::Engine;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;

fn main() -> anyhow::Result<()> {
    let streams: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(4);
    let engine = Engine::load_default().ok();
    if engine.is_none() {
        eprintln!("note: artifacts not built; using rust fallback generator");
    }
    let block = engine.as_ref().map(|e| e.meta().blocks[0]).unwrap_or(4096);
    let base = FrameworkConfig {
        streams,
        blocks_per_stream: 4,
        block,
        dataset: DatasetKind::Reco,
        output: OutputMode::None,
        compression: Settings::new(Codec::Rzip, 2),
        queue_depth: 2 * streams,
    };

    println!(
        "RECO production: {streams} streams x {} blocks x {block} events, {} branches\n",
        base.blocks_per_stream,
        base.dataset.n_branches()
    );
    let mut ceiling = 0.0f64;
    for (name, mode) in [
        ("no-output ", OutputMode::None),
        ("imt-off   ", OutputMode::SerialOutput),
        ("imt-on    ", OutputMode::ImtMerger),
    ] {
        if mode == OutputMode::ImtMerger {
            // paper: 1.5 threads per stream — the extra half is the pool
            imt::enable(((streams + 1) / 2).max(1));
        } else {
            imt::disable();
        }
        let be: BackendRef = Arc::new(MemBackend::new());
        let rep = run(&base_with(&base, mode), be.clone(), engine.as_ref(), None)?;
        imt::disable();
        if mode == OutputMode::None {
            ceiling = rep.events_per_sec();
        }
        println!(
            "{name}: {:>9.0} events/s  ({:>6.1} MB/s ingest, {:>5.1}% of ceiling)",
            rep.events_per_sec(),
            rep.throughput_mbps(),
            100.0 * rep.events_per_sec() / ceiling
        );
        if mode != OutputMode::None {
            let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
            assert_eq!(reader.entries(), rep.events);
        }
    }
    println!("\nreco_production OK");
    Ok(())
}

fn base_with(base: &FrameworkConfig, mode: OutputMode) -> FrameworkConfig {
    let mut cfg = base.clone();
    cfg.output = mode;
    cfg
}
