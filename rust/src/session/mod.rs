//! Shared I/O session: one pool, one completion domain, one budget —
//! for *many* writers.
//!
//! The pipelined write path (PR 2) scales one writer; real production
//! workflows (Riley & Jones, "Multi-threaded Output in CMS using
//! ROOT") run many concurrent output modules. Left to themselves, N
//! `TreeWriter`s each construct their own task group and bound only
//! their own in-flight clusters, so together they oversubscribe the
//! IMT pool and buffer N× the intended memory. A [`Session`] is the
//! shared substrate they attach to instead:
//!
//! * **one pool handle** — every writer's flush tasks land on the same
//!   [`imt::Pool`] (an explicit pool, or the global IMT pool bound
//!   lazily like `TaskGroup` always has);
//! * **one completion domain** — task groups are minted by
//!   [`Session::task_group`] and tracked, so [`Session::drain`] can
//!   join every writer's outstanding work at once;
//! * **one in-flight budget** — a [`imt::IoBudget`] caps clusters
//!   in flight *across all writers* with per-writer max-min fair
//!   admission (`max(1, limit / active_writers)`, clamped by each
//!   writer's own `max_inflight_clusters`), so a fat-basket writer
//!   cannot monopolise the slots and narrow writers never starve;
//! * **scratch-pool sizing** — each registered writer reserves
//!   head-room in the shared [`compress::pool`]
//!   ([`compress::pool::reserve_writer`]), whose eviction/high-water
//!   policy keeps resident scratch bounded under many-writer pressure.
//!
//! ```no_run
//! use rootio_par::session::{Session, SessionConfig};
//! let session = Session::new(SessionConfig::for_writers(4, 2));
//! // open every output of the job under `session`:
//! //   TreeWriter::attached(schema, sink, config, &session)
//! //   TBufferMerger::create_in_session(..., &session)
//! //   coordinator::write::write_files(&session, jobs)
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::compress;
use crate::error::Result;
use crate::imt::{BudgetStats, ClusterGuard, IoBudget, MemberBudget, Pool, TaskGroup};
use crate::metrics::{Recorder, Registry};

/// Session tuning.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Global cap on basket clusters in flight across every writer
    /// attached to the session (bounds buffered memory; producers that
    /// outrun the compressors block — helping the pool — and account
    /// the wait as stall).
    pub max_inflight_clusters: usize,
    /// Global cap on prefetched cluster windows in flight across every
    /// streaming reader attached to the session ([`crate::cache`]):
    /// fetched-or-decoded clusters not yet consumed. Bounds read-ahead
    /// memory the same way `max_inflight_clusters` bounds write-side
    /// buffering; readers split it max-min fair.
    pub max_inflight_read_windows: usize,
    /// Global cap on *hedged* duplicate reads in flight across every
    /// [`crate::storage::resilient::ResilientBackend`] attached to the
    /// session. Hedges are speculative extra device requests; this cap
    /// keeps a tail-latency spike from doubling device load.
    pub max_hedged_reads: usize,
    /// Span recorder threaded through every subsystem the session
    /// touches (pool task execution, budget admission waits, prefetch
    /// fetch/decode, resilient retries/hedges, writer flush stages).
    /// Defaults to [`Recorder::disabled`] — one branch on each hot
    /// path. Set an enabled recorder (or use
    /// [`SessionConfig::traced`]) to collect a pipeline-wide trace.
    pub recorder: Recorder,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            max_inflight_clusters: 16,
            max_inflight_read_windows: 16,
            max_hedged_reads: 4,
            recorder: Recorder::disabled(),
        }
    }
}

impl SessionConfig {
    /// Budget sized for `writers` concurrent writers at `per_writer`
    /// clusters each — the fair share works out to `per_writer` when
    /// all of them are attached.
    pub fn for_writers(writers: usize, per_writer: usize) -> Self {
        SessionConfig {
            max_inflight_clusters: (writers * per_writer).max(1),
            ..Default::default()
        }
    }

    /// Read budget sized for `readers` concurrent streaming readers at
    /// `per_reader` prefetched clusters each.
    pub fn for_readers(readers: usize, per_reader: usize) -> Self {
        SessionConfig {
            max_inflight_read_windows: (readers * per_reader).max(1),
            ..Default::default()
        }
    }

    /// Enable pipeline tracing with a fresh recorder.
    pub fn traced(mut self) -> Self {
        self.recorder = Recorder::new();
        self
    }
}

/// Aggregate session counters ([`Session::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Writers ever registered on this session.
    pub writers_opened: u64,
    /// Writers currently registered.
    pub active_writers: usize,
    /// Clusters currently in flight across all writers.
    pub in_flight_clusters: usize,
    /// The global in-flight cap.
    pub budget_limit: usize,
    /// Lifetime admissions through the shared budget.
    pub admissions: u64,
    /// Admissions that had to wait for capacity.
    pub admission_waits: u64,
    /// Streaming readers ever registered on this session.
    pub readers_opened: u64,
    /// Streaming readers currently registered.
    pub active_readers: usize,
    /// Prefetched cluster windows currently in flight across readers.
    pub in_flight_read_windows: usize,
    /// The global read-ahead cap.
    pub read_budget_limit: usize,
    /// Read-side admissions that *blocked* for capacity (always 0 for
    /// the built-in prefetcher, which degrades instead of blocking —
    /// per-stream denial counts live in
    /// [`crate::cache::PrefetchStats::admission_denials`]).
    pub read_admission_waits: u64,
    /// Hedged duplicate reads currently in flight across the session.
    pub in_flight_hedges: usize,
    /// The global hedged-read cap.
    pub hedge_limit: usize,
}

struct SessionInner {
    config: SessionConfig,
    /// Explicit pool, or `None` to bind lazily to the global IMT pool
    /// exactly the way a bare `TaskGroup::new()` does.
    explicit_pool: Option<Arc<Pool>>,
    budget: IoBudget,
    /// Read-ahead twin of `budget`: prefetched cluster windows in
    /// flight across every streaming reader of the session.
    read_budget: IoBudget,
    /// Speculative-duplicate twin: hedged reads in flight across every
    /// resilient backend of the session.
    hedge_budget: IoBudget,
    /// Task groups minted for writers/helpers, joined by [`Session::drain`].
    groups: Mutex<Vec<TaskGroup>>,
    writers_opened: AtomicU64,
    readers_opened: AtomicU64,
    /// The session's span recorder (disabled unless the config enabled
    /// tracing). Cloned into budgets, writers, streams and backends at
    /// registration time.
    recorder: Recorder,
    /// The unified metrics registry: live latency histograms fed by
    /// the pipeline plus the snapshot surface `rootio stats` dumps.
    metrics: Registry,
    /// The pool the recorder was installed on at build time, so the
    /// session can uninstall it again when it drops.
    traced_pool: Option<Arc<Pool>>,
}

impl Drop for SessionInner {
    fn drop(&mut self) {
        if let Some(pool) = &self.traced_pool {
            pool.clear_recorder(&self.recorder);
        }
    }
}

/// Cloneable handle on one shared I/O session.
#[derive(Clone)]
pub struct Session {
    inner: Arc<SessionInner>,
}

impl Session {
    /// Session on the global IMT pool (bound lazily; writers degrade
    /// to inline/serial execution while IMT is off, exactly like a
    /// standalone `TreeWriter`).
    pub fn new(config: SessionConfig) -> Self {
        Session::build(None, config)
    }

    /// Session on a dedicated pool (hermetic tests, isolated jobs).
    pub fn with_pool(pool: Arc<Pool>, config: SessionConfig) -> Self {
        Session::build(Some(pool), config)
    }

    /// Private single-writer session: what `TreeWriter::new` wraps
    /// itself in when no shared session is given, preserving the old
    /// per-writer `max_inflight_clusters` semantics.
    pub fn solo(max_inflight_clusters: usize) -> Self {
        Session::new(SessionConfig {
            max_inflight_clusters: max_inflight_clusters.max(1),
            ..Default::default()
        })
    }

    fn build(pool: Option<Arc<Pool>>, config: SessionConfig) -> Self {
        let recorder = config.recorder.clone();
        let budget =
            IoBudget::traced(config.max_inflight_clusters, pool.clone(), recorder.clone());
        let read_budget =
            IoBudget::traced(config.max_inflight_read_windows, pool.clone(), recorder.clone());
        let hedge_budget =
            IoBudget::traced(config.max_hedged_reads, pool.clone(), recorder.clone());
        // Install the recorder on the pool the session resolves *now*
        // so task execution shows up in the trace. A traced session on
        // the lazily-bound global pool only records tasks if the pool
        // is already up — `rootio trace` and tests pass explicit pools.
        let traced_pool = if recorder.is_enabled() {
            let p = pool.clone().or_else(crate::imt::pool);
            if let Some(p) = &p {
                p.install_recorder(&recorder);
            }
            p
        } else {
            None
        };
        Session {
            inner: Arc::new(SessionInner {
                config,
                explicit_pool: pool,
                budget,
                read_budget,
                hedge_budget,
                groups: Mutex::new(Vec::new()),
                writers_opened: AtomicU64::new(0),
                readers_opened: AtomicU64::new(0),
                recorder,
                metrics: Registry::new(),
                traced_pool,
            }),
        }
    }

    /// The session's span recorder (disabled unless tracing was
    /// enabled in the config).
    pub fn recorder(&self) -> &Recorder {
        &self.inner.recorder
    }

    /// The session's unified metrics registry.
    pub fn metrics(&self) -> &Registry {
        &self.inner.metrics
    }

    pub fn config(&self) -> &SessionConfig {
        &self.inner.config
    }

    /// The pool writers of this session run on right now: the explicit
    /// pool, else the current global IMT pool (None while IMT is off).
    pub fn pool(&self) -> Option<Arc<Pool>> {
        self.inner.explicit_pool.clone().or_else(crate::imt::pool)
    }

    /// Will flush work actually run concurrently?
    pub fn is_parallel(&self) -> bool {
        self.pool().is_some()
    }

    /// Mint a task group in this session's completion domain: bound to
    /// the session pool (or lazily to the global pool), tracked so
    /// [`Session::drain`] covers it.
    pub fn task_group(&self) -> TaskGroup {
        let group = TaskGroup::bound(self.inner.explicit_pool.clone());
        let mut groups = self.inner.groups.lock().unwrap_or_else(|p| p.into_inner());
        // Bound the roster on long-lived sessions: a group whose only
        // handle is this roster and whose jobs have all finished can
        // never spawn again, so it falls off as its writer closes. An
        // idle group still held by a live writer (between clusters)
        // stays, preserving the drain contract; panicked groups stay
        // so `drain` surfaces the failure.
        groups.retain(|g| !g.is_orphaned() || g.panicked());
        groups.push(group.clone());
        group
    }

    /// Register one writer: it joins the shared budget (with `cap` =
    /// its own `max_inflight_clusters`) and reserves scratch-pool
    /// head-room for the session's lifetime accounting.
    pub fn register_writer(&self, cap: usize) -> WriterRegistration {
        self.inner.writers_opened.fetch_add(1, Ordering::Relaxed);
        compress::pool::reserve_writer();
        WriterRegistration { budget: self.inner.budget.register(cap) }
    }

    /// Register one streaming reader: it joins the shared *read*
    /// budget (with `cap` = its own maximum prefetch window) and
    /// reserves scratch-pool head-room — coalesced fetch windows draw
    /// their buffers from the same shared pool the writers use.
    pub fn register_reader(&self, cap: usize) -> ReaderRegistration {
        self.inner.readers_opened.fetch_add(1, Ordering::Relaxed);
        compress::pool::reserve_reader();
        ReaderRegistration { budget: self.inner.read_budget.register(cap) }
    }

    /// The shared budget (diagnostics / tests).
    pub fn budget(&self) -> &IoBudget {
        &self.inner.budget
    }

    /// The shared read-ahead budget (diagnostics / tests).
    pub fn read_budget(&self) -> &IoBudget {
        &self.inner.read_budget
    }

    /// Register one resilient backend's hedge issuer: it joins the
    /// shared hedged-read budget with `cap` as its own per-backend
    /// bound, so speculative duplicates across all backends of the
    /// session never exceed [`SessionConfig::max_hedged_reads`].
    pub fn register_hedger(&self, cap: usize) -> MemberBudget {
        self.inner.hedge_budget.register(cap)
    }

    /// The shared hedged-read budget (diagnostics / tests).
    pub fn hedge_budget(&self) -> &IoBudget {
        &self.inner.hedge_budget
    }

    /// Join every task group minted by this session; the first
    /// panicked group surfaces as an error.
    pub fn drain(&self) -> Result<()> {
        let groups: Vec<TaskGroup> = {
            let g = self.inner.groups.lock().unwrap_or_else(|p| p.into_inner());
            g.clone()
        };
        for group in groups {
            group.join()?;
        }
        Ok(())
    }

    pub fn stats(&self) -> SessionStats {
        let b: BudgetStats = self.inner.budget.stats();
        let r: BudgetStats = self.inner.read_budget.stats();
        SessionStats {
            writers_opened: self.inner.writers_opened.load(Ordering::Relaxed),
            active_writers: b.active_writers,
            in_flight_clusters: b.in_flight,
            budget_limit: b.limit,
            admissions: b.admissions,
            admission_waits: b.waits,
            readers_opened: self.inner.readers_opened.load(Ordering::Relaxed),
            active_readers: r.active_writers,
            in_flight_read_windows: r.in_flight,
            read_budget_limit: r.limit,
            read_admission_waits: r.waits,
            in_flight_hedges: self.inner.hedge_budget.in_flight(),
            hedge_limit: self.inner.hedge_budget.limit(),
        }
    }
}

/// One writer's membership in a session: budget admission plus the
/// scratch-pool reservation, both released on drop.
pub struct WriterRegistration {
    budget: MemberBudget,
}

impl WriterRegistration {
    /// Admit one cluster (blocking, helping the pool). See
    /// [`MemberBudget::acquire`].
    pub fn acquire(&self) -> ClusterGuard {
        self.budget.acquire()
    }

    /// Non-blocking admission.
    pub fn try_acquire(&self) -> Option<ClusterGuard> {
        self.budget.try_acquire()
    }

    /// Highest in-flight cluster count this writer ever held.
    pub fn high_water(&self) -> usize {
        self.budget.high_water()
    }

    /// The writer's current fair share of the session budget.
    pub fn fair_share(&self) -> usize {
        self.budget.fair_share()
    }

    /// Admissions of this writer that had to wait for capacity — the
    /// per-writer admission-pressure feedback consumed by the
    /// adaptive cluster sizer ([`crate::tree::sizer`]).
    pub fn waits(&self) -> u64 {
        self.budget.waits()
    }
}

impl Drop for WriterRegistration {
    fn drop(&mut self) {
        compress::pool::release_writer();
    }
}

/// One streaming reader's membership in a session: read-budget
/// admission plus the scratch-pool reservation, both released on drop.
/// Handed to a [`crate::cache::ClusterStream`] by
/// [`Session::register_reader`].
pub struct ReaderRegistration {
    budget: MemberBudget,
}

impl ReaderRegistration {
    /// Admit one prefetch window slot (blocking, helping the pool).
    /// See [`MemberBudget::acquire`]. The built-in prefetcher never
    /// calls this — prefetched slots are freed only by their own
    /// consumer, so blocking admission could deadlock a thread on its
    /// sibling streams; it is kept for callers that manage their own
    /// window lifecycle.
    pub fn acquire(&self) -> ClusterGuard {
        self.budget.acquire()
    }

    /// Non-blocking admission — what the prefetcher uses throughout:
    /// a full budget degrades the read-ahead window (and lets the
    /// consumer-demanded head window proceed unbudgeted) instead of
    /// blocking progress.
    pub fn try_acquire(&self) -> Option<ClusterGuard> {
        self.budget.try_acquire()
    }

    /// Highest in-flight window count this reader ever held.
    pub fn high_water(&self) -> usize {
        self.budget.high_water()
    }

    /// The reader's current fair share of the session read budget.
    pub fn fair_share(&self) -> usize {
        self.budget.fair_share()
    }

    /// Admissions of this reader that had to *block* for capacity.
    /// Always 0 for the built-in prefetcher (it never blocks — its
    /// window controller is fed the stream's own denial counter
    /// instead, see [`crate::cache::PrefetchStats`]); meaningful only
    /// for callers using [`ReaderRegistration::acquire`] directly.
    pub fn waits(&self) -> u64 {
        self.budget.waits()
    }
}

impl Drop for ReaderRegistration {
    fn drop(&mut self) {
        compress::pool::release_reader();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_session_owns_the_whole_budget() {
        let s = Session::solo(3);
        let w = s.register_writer(3);
        assert_eq!(w.fair_share(), 3);
        let g: Vec<_> = (0..3).map(|_| w.try_acquire().expect("own budget")).collect();
        assert!(w.try_acquire().is_none());
        assert_eq!(s.stats().in_flight_clusters, 3);
        drop(g);
        assert_eq!(s.stats().in_flight_clusters, 0);
        assert_eq!(s.stats().writers_opened, 1);
    }

    #[test]
    fn shared_budget_splits_across_writers() {
        let s = Session::new(SessionConfig::for_writers(4, 2));
        assert_eq!(s.budget().limit(), 8);
        let writers: Vec<_> = (0..4).map(|_| s.register_writer(8)).collect();
        for w in &writers {
            assert_eq!(w.fair_share(), 2);
        }
        assert_eq!(s.stats().active_writers, 4);
        drop(writers);
        assert_eq!(s.stats().active_writers, 0);
    }

    #[test]
    fn task_groups_join_via_drain() {
        use std::sync::atomic::AtomicUsize;
        let pool = Arc::new(Pool::new(2));
        let s = Session::with_pool(pool, SessionConfig::default());
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            let group = s.task_group();
            for _ in 0..8 {
                let hits = hits.clone();
                group.spawn(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        s.drain().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 24);
    }

    #[test]
    fn readers_attach_to_the_read_budget() {
        let s = Session::new(SessionConfig::for_readers(2, 2));
        assert_eq!(s.read_budget().limit(), 4);
        let r1 = s.register_reader(8);
        let r2 = s.register_reader(8);
        assert_eq!(r1.fair_share(), 2);
        let g1 = r1.try_acquire().expect("window slot");
        let g2 = r1.try_acquire().expect("fair share of 2");
        assert!(r1.try_acquire().is_none(), "reader capped at its share");
        assert!(r2.try_acquire().is_some(), "other reader unaffected");
        assert_eq!(s.stats().active_readers, 2);
        assert_eq!(s.stats().in_flight_read_windows, 2);
        // read admissions never touch the write budget
        assert_eq!(s.stats().in_flight_clusters, 0);
        drop((g1, g2));
        drop((r1, r2));
        let st = s.stats();
        assert_eq!(st.active_readers, 0);
        assert_eq!(st.in_flight_read_windows, 0);
        assert_eq!(st.readers_opened, 2);
    }

    #[test]
    fn writer_registration_reserves_scratch_headroom() {
        // Other lib tests register writers concurrently, so only the
        // balanced register/release pair is asserted (no underflow, no
        // panic), not an absolute count.
        let s = Session::solo(2);
        let w = s.register_writer(2);
        assert!(compress::pool::registered_writers() >= 1);
        drop(w);
    }
}
