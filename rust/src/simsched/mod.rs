//! Deterministic task-schedule simulator.
//!
//! **Why this exists.** The paper's figures sweep thread counts on
//! quad-core laptops, a 36-core Xeon and a 64-core KNL. This
//! reproduction host has **one** CPU core, so wall-clock speedups
//! cannot exceed 1×. Following the repo's substitution rule
//! (DESIGN.md §4), the experiment harnesses therefore *measure* every
//! task's real cost serially (real codec, real serialiser, real PJRT
//! graph — on real data) and replay the coordinator's task graph
//! through this discrete-event simulator to obtain the multi-core
//! scaling shape. The scheduler implemented here — FIFO list
//! scheduling onto a homogeneous worker pool plus named exclusive
//! resources — is exactly the policy of [`crate::imt`]'s pool, the
//! merger's single output thread, the PJRT service thread, and the
//! storage device queue.
//!
//! On a multi-core host the same harnesses also report real wall-clock
//! numbers; the simulator is validated against them in tests (1-worker
//! simulation == serial sum; n-worker makespan lower-bounds hold).

use std::collections::BinaryHeap;
use std::time::Duration;

use crate::metrics::SpanKind;

/// Task identifier (index into the schedule's task list).
pub type TaskId = usize;

/// Where a task may execute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Place {
    /// Any worker of the simulated pool (IMT worker).
    Pool,
    /// A named exclusive resource: `"output"`, `"pjrt"`, `"device"`,
    /// `"lock"`, `"stream-3"`, ... Exactly one task at a time.
    Named(String),
}

/// One unit of work.
#[derive(Clone, Debug)]
pub struct Task {
    pub kind: SpanKind,
    pub cost: Duration,
    pub place: Place,
    /// Tasks that must complete before this one starts.
    pub deps: Vec<TaskId>,
}

/// A task graph under construction.
#[derive(Default, Clone)]
pub struct Graph {
    pub tasks: Vec<Task>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    pub fn push(&mut self, kind: SpanKind, cost: Duration, place: Place, deps: Vec<TaskId>) -> TaskId {
        let id = self.tasks.len();
        for &d in &deps {
            assert!(d < id, "dependency {d} of task {id} not yet defined");
        }
        self.tasks.push(Task { kind, cost, place, deps });
        id
    }

    pub fn pool(&mut self, kind: SpanKind, cost: Duration, deps: Vec<TaskId>) -> TaskId {
        self.push(kind, cost, Place::Pool, deps)
    }

    pub fn named(
        &mut self,
        name: &str,
        kind: SpanKind,
        cost: Duration,
        deps: Vec<TaskId>,
    ) -> TaskId {
        self.push(kind, cost, Place::Named(name.to_string()), deps)
    }
}

/// Placement of one task in the simulated schedule.
#[derive(Clone, Debug)]
pub struct Placement {
    pub task: TaskId,
    /// Worker index for pool tasks; usize::MAX-based ids for named.
    pub unit: String,
    pub start: Duration,
    pub end: Duration,
}

/// Result of a simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub makespan: Duration,
    pub placements: Vec<Placement>,
    /// (unit name, busy time) pairs.
    pub busy: Vec<(String, Duration)>,
}

impl SimResult {
    /// Busy fraction of the pool workers (Figure 7's useful-work metric).
    pub fn pool_utilization(&self, workers: usize) -> f64 {
        if self.makespan.is_zero() || workers == 0 {
            return 0.0;
        }
        let pool_busy: f64 = self
            .busy
            .iter()
            .filter(|(u, _)| u.starts_with("w"))
            .map(|(_, b)| b.as_secs_f64())
            .sum();
        pool_busy / (workers as f64 * self.makespan.as_secs_f64())
    }

}

fn render_rows(
    n_rows: usize,
    spans: &[(usize, SpanKind, Duration, Duration)],
    width: usize,
    names: &[&String],
) -> String {
    let wall = spans.iter().map(|s| s.3).max().unwrap_or_default();
    if wall.is_zero() || n_rows == 0 || width == 0 {
        return String::new();
    }
    let bucket = wall.as_secs_f64() / width as f64;
    let mut grid = vec![vec![(' ', 0f64); width]; n_rows];
    for (row, kind, start, end) in spans {
        let b0 = ((start.as_secs_f64() / bucket) as usize).min(width - 1);
        let b1 = ((end.as_secs_f64() / bucket) as usize).min(width - 1);
        for b in b0..=b1 {
            let cell_start = b as f64 * bucket;
            let cell_end = cell_start + bucket;
            let overlap =
                (end.as_secs_f64().min(cell_end) - start.as_secs_f64().max(cell_start)).max(0.0);
            if overlap > grid[*row][b].1 {
                grid[*row][b] = (kind.glyph(), overlap);
            }
        }
    }
    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        out.push_str(&format!("{:<10}|", names[r]));
        for (ch, _) in row {
            out.push(*ch);
        }
        out.push_str("|\n");
    }
    out
}

/// Simulate `graph` on `workers` pool workers (+ named resources).
///
/// FIFO list scheduling: tasks become ready when all deps complete;
/// ready tasks are started in (ready_time, id) order on the earliest
/// free matching unit.
pub fn simulate(graph: &Graph, workers: usize) -> SimResult {
    use std::cmp::Reverse;
    use std::collections::HashMap;

    let n = graph.tasks.len();
    let mut remaining_deps: Vec<usize> = graph.tasks.iter().map(|t| t.deps.len()).collect();
    let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for (id, t) in graph.tasks.iter().enumerate() {
        for &d in &t.deps {
            dependents[d].push(id);
        }
    }

    // ready queue ordered by (ready_time, id)
    let mut ready: BinaryHeap<Reverse<(Duration, TaskId)>> = BinaryHeap::new();
    for (id, t) in graph.tasks.iter().enumerate() {
        if t.deps.is_empty() {
            ready.push(Reverse((Duration::ZERO, id)));
        }
    }

    let mut worker_free: BinaryHeap<Reverse<(Duration, usize)>> =
        (0..workers.max(1)).map(|i| Reverse((Duration::ZERO, i))).collect();
    let mut named_free: HashMap<String, Duration> = HashMap::new();
    let mut finish: Vec<Duration> = vec![Duration::ZERO; n];
    let mut placements = Vec::with_capacity(n);
    let mut busy: HashMap<String, Duration> = HashMap::new();
    let mut makespan = Duration::ZERO;

    while let Some(Reverse((ready_at, id))) = ready.pop() {
        let t = &graph.tasks[id];
        let (unit, start) = match &t.place {
            Place::Pool => {
                let Reverse((free_at, w)) = worker_free.pop().unwrap();
                (format!("w{w:02}"), free_at.max(ready_at))
            }
            Place::Named(name) => {
                let free_at = named_free.get(name).copied().unwrap_or_default();
                (name.clone(), free_at.max(ready_at))
            }
        };
        let end = start + t.cost;
        finish[id] = end;
        makespan = makespan.max(end);
        *busy.entry(unit.clone()).or_default() += t.cost;
        match &t.place {
            Place::Pool => {
                let w: usize = unit[1..].parse().unwrap();
                worker_free.push(Reverse((end, w)));
            }
            Place::Named(name) => {
                named_free.insert(name.clone(), end);
            }
        }
        placements.push(Placement { task: id, unit, start, end });
        for &dep in &dependents[id] {
            remaining_deps[dep] -= 1;
            if remaining_deps[dep] == 0 {
                ready.push(Reverse((end, dep)));
            }
        }
    }

    debug_assert!(remaining_deps.iter().all(|&d| d == 0), "cycle in task graph");
    let mut busy: Vec<(String, Duration)> = busy.into_iter().collect();
    busy.sort();
    SimResult { makespan, placements, busy }
}

/// Render a simulated schedule with correct per-task kinds.
pub fn timeline(graph: &Graph, result: &SimResult, width: usize) -> String {
    let mut units: Vec<String> = result.placements.iter().map(|p| p.unit.clone()).collect();
    units.sort();
    units.dedup();
    let refs: Vec<&String> = units.iter().collect();
    let spans: Vec<(usize, SpanKind, Duration, Duration)> = result
        .placements
        .iter()
        .map(|p| {
            (
                units.iter().position(|u| *u == p.unit).unwrap(),
                graph.tasks[p.task].kind,
                p.start,
                p.end,
            )
        })
        .collect();
    render_rows(units.len(), &spans, width, &refs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn one_worker_equals_serial_sum() {
        let mut g = Graph::new();
        for _ in 0..10 {
            g.pool(SpanKind::Compress, ms(7), vec![]);
        }
        let r = simulate(&g, 1);
        assert_eq!(r.makespan, ms(70));
    }

    #[test]
    fn independent_tasks_scale_perfectly() {
        let mut g = Graph::new();
        for _ in 0..8 {
            g.pool(SpanKind::Decompress, ms(10), vec![]);
        }
        assert_eq!(simulate(&g, 2).makespan, ms(40));
        assert_eq!(simulate(&g, 4).makespan, ms(20));
        assert_eq!(simulate(&g, 8).makespan, ms(10));
        // more workers than tasks: no further gain
        assert_eq!(simulate(&g, 16).makespan, ms(10));
    }

    #[test]
    fn critical_path_bounds_makespan() {
        let mut g = Graph::new();
        let a = g.pool(SpanKind::Read, ms(5), vec![]);
        let b = g.pool(SpanKind::Decompress, ms(10), vec![a]);
        let _c = g.pool(SpanKind::Process, ms(3), vec![b]);
        // independent short task
        g.pool(SpanKind::Read, ms(1), vec![]);
        let r = simulate(&g, 4);
        assert_eq!(r.makespan, ms(18));
    }

    #[test]
    fn named_resource_serialises() {
        let mut g = Graph::new();
        for _ in 0..5 {
            g.named("output", SpanKind::Write, ms(4), vec![]);
        }
        // pool width is irrelevant for named units
        assert_eq!(simulate(&g, 8).makespan, ms(20));
    }

    #[test]
    fn pipeline_overlaps_pool_and_named() {
        // decode (pool) -> analyze (pjrt); with 2 workers the pjrt unit
        // becomes the bottleneck: total = first decode + 4 analyses
        let mut g = Graph::new();
        for _ in 0..4 {
            let d = g.pool(SpanKind::Decompress, ms(10), vec![]);
            g.named("pjrt", SpanKind::Process, ms(10), vec![d]);
        }
        let r = simulate(&g, 4);
        assert_eq!(r.makespan, ms(50));
    }

    #[test]
    fn utilization_and_timeline() {
        let mut g = Graph::new();
        for _ in 0..4 {
            g.pool(SpanKind::Compress, ms(10), vec![]);
        }
        let r = simulate(&g, 2);
        assert!((r.pool_utilization(2) - 1.0).abs() < 1e-9);
        let art = timeline(&g, &r, 20);
        assert!(art.contains("w00"));
        assert!(art.contains('c'));
    }

    #[test]
    fn deps_to_undefined_task_panics() {
        let result = std::panic::catch_unwind(|| {
            let mut g = Graph::new();
            g.pool(SpanKind::Read, ms(1), vec![5]);
        });
        assert!(result.is_err());
    }

    #[test]
    fn speedup_curve_shape_matches_amdahl() {
        // 1 serial startup + 12 parallel units: classic saturating curve
        let build = || {
            let mut g = Graph::new();
            let s = g.named("startup", SpanKind::Startup, ms(12), vec![]);
            for _ in 0..12 {
                g.pool(SpanKind::Decompress, ms(12), vec![s]);
            }
            g
        };
        let g = build();
        let t1 = simulate(&g, 1).makespan;
        let t4 = simulate(&g, 4).makespan;
        let t12 = simulate(&g, 12).makespan;
        let s4 = t1.as_secs_f64() / t4.as_secs_f64();
        let s12 = t1.as_secs_f64() / t12.as_secs_f64();
        assert!(s4 > 3.2 && s4 < 3.7, "s4={s4}");
        assert!(s12 > 6.0 && s12 < 7.0, "s12={s12}"); // Amdahl limit
    }
}
