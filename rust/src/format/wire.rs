//! Big-endian byte (de)serialisation helpers for file metadata.

use crate::error::{Error, Result};

/// Append-only big-endian writer.
#[derive(Default)]
pub struct WireWriter {
    pub buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    /// Encode `n` as the u32 length prefix of a variable-length record.
    /// Lengths that do not fit the prefix are a hard error — silently
    /// truncating `n as u32` would commit a record whose prefix promises
    /// the wrong byte count and desynchronise every later field.
    pub fn put_len(&mut self, n: usize) -> Result<()> {
        let n32 = u32::try_from(n).map_err(|_| {
            Error::Format(format!(
                "record length {n} exceeds the u32 wire prefix (max {})",
                u32::MAX
            ))
        })?;
        self.put_u32(n32);
        Ok(())
    }

    pub fn put_bytes(&mut self, v: &[u8]) -> Result<()> {
        self.put_len(v.len())?;
        self.buf.extend_from_slice(v);
        Ok(())
    }

    pub fn put_str(&mut self, v: &str) -> Result<()> {
        self.put_bytes(v.as_bytes())
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based big-endian reader with truncation checks.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Format(format!(
                "truncated metadata: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_be_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| Error::Format("non-utf8 string".into()))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEADBEEF);
        w.put_u64(1 << 40);
        w.put_str("branch/pt").unwrap();
        w.put_bytes(&[1, 2, 3]).unwrap();
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.get_u64().unwrap(), 1 << 40);
        assert_eq!(r.get_str().unwrap(), "branch/pt");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation() {
        let mut w = WireWriter::new();
        w.put_u64(5);
        let buf = w.finish();
        let mut r = WireReader::new(&buf[..5]);
        assert!(r.get_u64().is_err());
        // length prefix promises 10 bytes, only 1 present
        let mut w2 = WireWriter::new();
        w2.put_u32(10);
        w2.put_u8(0xAB);
        let buf2 = w2.finish();
        let mut r2 = WireReader::new(&buf2);
        assert!(r2.get_bytes().is_err());
    }

    /// Lengths that overflow the u32 prefix must surface as
    /// `Error::Format`, not truncate. Exercised through `put_len` so the
    /// test does not have to materialise a 4 GiB buffer.
    #[test]
    fn oversize_length_is_rejected_not_truncated() {
        let mut w = WireWriter::new();
        w.put_len(u32::MAX as usize).unwrap();
        let before = w.buf.len();
        let err = w.put_len(u32::MAX as usize + 1).unwrap_err();
        assert!(matches!(err, Error::Format(_)), "want Error::Format, got {err:?}");
        // A failed encode must not leave a partial prefix behind.
        assert_eq!(w.buf.len(), before);
        let err2 = w.put_len(usize::MAX).unwrap_err();
        assert!(matches!(err2, Error::Format(_)));
    }
}
