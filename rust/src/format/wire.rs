//! Big-endian byte (de)serialisation helpers for file metadata.

use crate::error::{Error, Result};

/// Append-only big-endian writer.
#[derive(Default)]
pub struct WireWriter {
    pub buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based big-endian reader with truncation checks.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Format(format!(
                "truncated metadata: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_be_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| Error::Format("non-utf8 string".into()))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEADBEEF);
        w.put_u64(1 << 40);
        w.put_str("branch/pt");
        w.put_bytes(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = WireReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.get_u64().unwrap(), 1 << 40);
        assert_eq!(r.get_str().unwrap(), "branch/pt");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation() {
        let mut w = WireWriter::new();
        w.put_u64(5);
        let buf = w.finish();
        let mut r = WireReader::new(&buf[..5]);
        assert!(r.get_u64().is_err());
        // length prefix promises 10 bytes, only 1 present
        let mut w2 = WireWriter::new();
        w2.put_u32(10);
        w2.put_u8(0xAB);
        let buf2 = w2.finish();
        let mut r2 = WireReader::new(&buf2);
        assert!(r2.get_bytes().is_err());
    }
}
