//! File reader: header/footer parsing and basket payload fetches.

use crate::compress::crc32;
use crate::error::{Error, Result};
use crate::storage::BackendRef;

use super::directory::{BasketInfo, Directory};
use super::{HEADER_LEN, MAGIC, MIN_VERSION, VERSION};

/// Read-side handle on an `RNTF` file.
pub struct FileReader {
    backend: BackendRef,
    directory: Directory,
    version: u32,
}

impl FileReader {
    /// Open and validate: magic, version, footer checksum, and every
    /// tree's structural invariants. Accepts every wire version from
    /// [`MIN_VERSION`] to [`VERSION`] — older files decode through the
    /// same paths (their directories simply never use newer features).
    pub fn open(backend: BackendRef) -> Result<Self> {
        let total = backend.len()?;
        if total < HEADER_LEN {
            return Err(Error::Format(format!("file too short: {total} bytes")));
        }
        let mut header = [0u8; HEADER_LEN as usize];
        backend.read_at(0, &mut header)?;
        if &header[0..4] != MAGIC {
            return Err(Error::Format("bad magic".into()));
        }
        let version = u32::from_be_bytes(header[4..8].try_into().unwrap());
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(Error::Format(format!("unsupported version {version}")));
        }
        let foff = u64::from_be_bytes(header[8..16].try_into().unwrap());
        let flen = u64::from_be_bytes(header[16..24].try_into().unwrap());
        if foff == 0 {
            return Err(Error::Format("file was never finalised (no footer)".into()));
        }
        if foff + flen > total || flen < 4 {
            return Err(Error::Format("footer out of bounds".into()));
        }
        let mut footer = vec![0u8; flen as usize];
        backend.read_at(foff, &mut footer)?;
        let (payload, crc_bytes) = footer.split_at(footer.len() - 4);
        let want_crc = u32::from_be_bytes(crc_bytes.try_into().unwrap());
        if crc32(payload) != want_crc {
            return Err(Error::Format("footer checksum mismatch".into()));
        }
        let directory = Directory::decode_versioned(payload, version)?;
        for t in &directory.trees {
            t.check()?;
        }
        Ok(FileReader { backend, directory, version })
    }

    pub fn directory(&self) -> &Directory {
        &self.directory
    }

    /// Wire version the file was written at.
    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn backend(&self) -> &BackendRef {
        &self.backend
    }

    /// Fetch the stored bytes of one basket into `buf` (replacing its
    /// contents), verifying the CRC. With a pooled `buf` (see
    /// [`crate::compress::pool`]) the fetch allocates nothing in
    /// steady state.
    pub fn fetch_basket_into(&self, b: &BasketInfo, buf: &mut Vec<u8>) -> Result<()> {
        buf.clear();
        buf.resize(b.comp_len as usize, 0);
        self.backend.read_at(b.offset, buf)?;
        verify_basket_crc(b, buf)
    }

    /// Fetch the stored bytes of one basket, verifying its CRC.
    pub fn fetch_basket(&self, b: &BasketInfo) -> Result<Vec<u8>> {
        let mut buf = Vec::with_capacity(b.comp_len as usize);
        self.fetch_basket_into(b, &mut buf)?;
        Ok(buf)
    }
}

/// Verify stored basket bytes against the directory CRC — the one
/// integrity check every fetch path applies (direct per-basket
/// fetches, the bulk coalesced loader, and the prefetcher's window
/// fetches all funnel through here).
pub(crate) fn verify_basket_crc(info: &BasketInfo, bytes: &[u8]) -> Result<()> {
    if crc32(bytes) != info.crc {
        return Err(Error::Format(format!(
            "basket at offset {} failed checksum",
            info.offset
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::writer::FileWriter;
    use crate::format::{BranchMeta, TreeMeta};
    use crate::serial::schema::{ColumnType, Field, Schema};
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use std::sync::Arc;

    fn one_basket_file() -> (Arc<MemBackend>, Directory, Vec<u8>) {
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be.clone()).unwrap();
        let payload = b"compressed-bytes-go-here".to_vec();
        let (off, crc) = w.append(&payload).unwrap();
        let dir = Directory {
            trees: vec![TreeMeta::classic(
                "t".into(),
                Schema::new(vec![Field::new("x", ColumnType::U8)]),
                24,
                vec![BranchMeta::simple(
                    "x".into(),
                    ColumnType::U8,
                    vec![BasketInfo {
                        offset: off,
                        comp_len: payload.len() as u32,
                        raw_len: payload.len() as u32,
                        first_entry: 0,
                        n_entries: 24,
                        crc,
                        settings: crate::compress::Settings::uncompressed(),
                        zone: None,
                    }],
                )],
            )],
        };
        w.finish(&dir).unwrap();
        (be, dir, payload)
    }

    #[test]
    fn open_and_fetch() {
        let (be, dir, payload) = one_basket_file();
        let r = FileReader::open(be).unwrap();
        assert_eq!(r.directory(), &dir);
        let b = r.directory().trees[0].branches[0].baskets[0];
        assert_eq!(r.fetch_basket(&b).unwrap(), payload);
    }

    #[test]
    fn rejects_bad_magic() {
        let (be, _, _) = one_basket_file();
        be.write_at(0, b"JUNK").unwrap();
        assert!(FileReader::open(be).is_err());
    }

    #[test]
    fn rejects_unfinalised() {
        let be = Arc::new(MemBackend::new());
        let _w = FileWriter::create(be.clone()).unwrap();
        assert!(FileReader::open(be).is_err());
    }

    #[test]
    fn rejects_corrupt_footer() {
        let (be, _, _) = one_basket_file();
        let end = be.len().unwrap();
        be.write_at(end - 6, &[0xFF, 0xFF]).unwrap();
        assert!(FileReader::open(be).is_err());
    }

    #[test]
    fn detects_corrupt_basket() {
        let (be, _, _) = one_basket_file();
        be.write_at(HEADER_LEN + 2, &[0xAA]).unwrap();
        let r = FileReader::open(be).unwrap();
        let b = r.directory().trees[0].branches[0].baskets[0];
        assert!(r.fetch_basket(&b).is_err());
    }

    #[test]
    fn rejects_short_file() {
        let be = Arc::new(MemBackend::from_vec(b"RN".to_vec()));
        assert!(FileReader::open(be).is_err());
    }
}
