//! Append-only file writer.

use std::sync::Mutex;

use crate::compress::crc32;
use crate::error::{Error, Result};
use crate::storage::BackendRef;

use super::directory::{Directory, TreeMeta};
use super::{HEADER_LEN, MAGIC, MIN_VERSION, VERSION};

/// Writes an `RNTF` file: header, appended payloads, footer.
///
/// Thread-safe appends: [`FileWriter::append`] reserves a range under a
/// cursor lock and performs the device write outside it, so multiple
/// compression tasks can land baskets concurrently (the device itself
/// serialises per its own queue model).
///
/// Multi-tree concurrent writing: several tree writers (one sink each)
/// may share one `FileWriter`; their appends interleave safely, each
/// sink registers its finished [`TreeMeta`] via [`FileWriter::add_tree`]
/// as it closes, and [`FileWriter::finish_registered`] commits them all
/// in one footer — sorted by tree name, so the directory bytes are
/// deterministic regardless of which writer closed first.
pub struct FileWriter {
    backend: BackendRef,
    cursor: Mutex<u64>,
    finished: Mutex<bool>,
    /// Wire version the footer will be encoded at (normally
    /// [`VERSION`]; older via [`FileWriter::create_versioned`]).
    version: u32,
    /// Trees registered by concurrently-closing sinks, committed by
    /// [`FileWriter::finish_registered`].
    trees: Mutex<Vec<TreeMeta>>,
}

impl FileWriter {
    /// Start a new file on `backend`: writes the provisional header.
    pub fn create(backend: BackendRef) -> Result<Self> {
        Self::create_versioned(backend, VERSION)
    }

    /// Start a new file at an explicit (possibly older) wire version —
    /// compat tooling and benchmarks that compare layouts. Finishing
    /// fails if the directory uses features the version cannot
    /// represent (element pages / cluster spans need v3).
    pub fn create_versioned(backend: BackendRef, version: u32) -> Result<Self> {
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(Error::Format(format!("cannot write format version {version}")));
        }
        let mut header = Vec::with_capacity(HEADER_LEN as usize);
        header.extend_from_slice(MAGIC);
        header.extend_from_slice(&version.to_be_bytes());
        header.extend_from_slice(&0u64.to_be_bytes()); // footer offset
        header.extend_from_slice(&0u64.to_be_bytes()); // footer length
        backend.write_at(0, &header)?;
        Ok(FileWriter {
            backend,
            cursor: Mutex::new(HEADER_LEN),
            finished: Mutex::new(false),
            version,
            trees: Mutex::new(Vec::new()),
        })
    }

    /// Wire version this file is being written at.
    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn backend(&self) -> &BackendRef {
        &self.backend
    }

    /// Reserve `len` bytes, returning the absolute offset.
    pub fn reserve(&self, len: u64) -> u64 {
        let mut c = self.cursor.lock().unwrap();
        let off = *c;
        *c += len;
        off
    }

    /// Append `payload`, returning `(offset, crc32)`.
    pub fn append(&self, payload: &[u8]) -> Result<(u64, u32)> {
        let off = self.reserve(payload.len() as u64);
        self.backend.write_at(off, payload)?;
        Ok((off, crc32(payload)))
    }

    /// Bytes written so far (payloads + header).
    pub fn position(&self) -> u64 {
        *self.cursor.lock().unwrap()
    }

    /// Register one finished tree for the footer directory. Called by
    /// each writer's sink as it closes — trees land in completion
    /// order here and are sorted at [`FileWriter::finish_registered`].
    /// The push happens under the finalisation lock: a registration
    /// either lands before the footer seals (and is committed) or
    /// errors — it can never be silently lost to a concurrent finish.
    pub fn add_tree(&self, meta: TreeMeta) -> Result<()> {
        let finished = self
            .finished
            .lock()
            .map_err(|_| Error::Sync("file writer poisoned by a panicked writer".into()))?;
        if *finished {
            return Err(Error::Format("file already finalised".into()));
        }
        self.trees
            .lock()
            .map_err(|_| Error::Sync("file writer poisoned by a panicked writer".into()))?
            .push(meta);
        drop(finished);
        Ok(())
    }

    /// Commit every tree registered via [`FileWriter::add_tree`] in one
    /// footer, sorted by name (deterministic bytes regardless of the
    /// writers' completion order). Validates the directory — duplicate
    /// tree names and broken basket indexes are rejected. Seals the
    /// file before reading the registry, so it cannot race
    /// [`FileWriter::add_tree`].
    pub fn finish_registered(&self) -> Result<u64> {
        let mut trees = {
            let mut finished = self
                .finished
                .lock()
                .map_err(|_| Error::Sync("file writer poisoned by a panicked writer".into()))?;
            if *finished {
                return Err(Error::Format("file already finalised".into()));
            }
            *finished = true;
            std::mem::take(
                &mut *self.trees.lock().map_err(|_| {
                    Error::Sync("file writer poisoned by a panicked writer".into())
                })?,
            )
        };
        trees.sort_by(|a, b| a.name.cmp(&b.name));
        let dir = Directory { trees };
        dir.check()?;
        self.write_footer(&dir)
    }

    /// Commit the footer and finalise the header. Consumes the logical
    /// write session; further appends are an error.
    pub fn finish(&self, dir: &Directory) -> Result<u64> {
        {
            let mut fin = self.finished.lock().unwrap();
            if *fin {
                return Err(Error::Format("file already finalised".into()));
            }
            *fin = true;
        }
        self.write_footer(dir)
    }

    /// Encode and append the footer, then patch the header (the file
    /// must already be sealed by the caller).
    fn write_footer(&self, dir: &Directory) -> Result<u64> {
        let mut footer = dir.encode_versioned(self.version)?;
        let crc = crc32(&footer);
        footer.extend_from_slice(&crc.to_be_bytes());
        let foff = self.reserve(footer.len() as u64);
        self.backend.write_at(foff, &footer)?;
        // Patch header with footer location.
        self.backend.write_at(8, &foff.to_be_bytes())?;
        self.backend.write_at(16, &(footer.len() as u64).to_be_bytes())?;
        self.backend.sync()?;
        Ok(foff + footer.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use std::sync::Arc;

    #[test]
    fn header_then_payloads_then_footer() {
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be.clone()).unwrap();
        let (off1, crc1) = w.append(b"basket-one").unwrap();
        let (off2, _) = w.append(b"basket-two!").unwrap();
        assert_eq!(off1, HEADER_LEN);
        assert_eq!(off2, HEADER_LEN + 10);
        assert_eq!(crc1, crc32(b"basket-one"));
        let end = w.finish(&Directory::default()).unwrap();
        assert_eq!(be.len().unwrap(), end);
        // header patched
        let mut b8 = [0u8; 8];
        be.read_at(8, &mut b8).unwrap();
        assert_eq!(u64::from_be_bytes(b8), off2 + 11);
    }

    #[test]
    fn double_finish_is_error() {
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be).unwrap();
        w.finish(&Directory::default()).unwrap();
        assert!(w.finish(&Directory::default()).is_err());
    }

    #[test]
    fn registered_trees_commit_sorted_and_validated() {
        use crate::format::directory::TreeMeta;
        use crate::format::reader::FileReader;
        use crate::serial::schema::Schema;
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be.clone()).unwrap();
        let mk = |name: &str| {
            TreeMeta::classic(
                name.into(),
                Schema::flat_f32("x", 1),
                0,
                vec![crate::format::directory::BranchMeta::simple(
                    "x0".into(),
                    crate::serial::schema::ColumnType::F32,
                    Vec::new(),
                )],
            )
        };
        // registration order b, a — the footer must come out sorted
        w.add_tree(mk("b")).unwrap();
        w.add_tree(mk("a")).unwrap();
        w.finish_registered().unwrap();
        let r = FileReader::open(be).unwrap();
        let names: Vec<&str> =
            r.directory().trees.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }

    #[test]
    fn duplicate_registered_tree_names_are_rejected() {
        use crate::format::directory::TreeMeta;
        use crate::serial::schema::Schema;
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be).unwrap();
        let mk = || {
            TreeMeta::classic(
                "t".into(),
                Schema::flat_f32("x", 1),
                0,
                vec![crate::format::directory::BranchMeta::simple(
                    "x0".into(),
                    crate::serial::schema::ColumnType::F32,
                    Vec::new(),
                )],
            )
        };
        w.add_tree(mk()).unwrap();
        w.add_tree(mk()).unwrap();
        assert!(w.finish_registered().is_err());
    }

    #[test]
    fn add_tree_after_finish_is_rejected() {
        use crate::format::directory::TreeMeta;
        use crate::serial::schema::Schema;
        let be = Arc::new(MemBackend::new());
        let w = FileWriter::create(be).unwrap();
        w.finish(&Directory::default()).unwrap();
        let meta =
            TreeMeta::classic("late".into(), Schema::flat_f32("x", 1), 0, Vec::new());
        assert!(w.add_tree(meta).is_err());
    }

    #[test]
    fn concurrent_appends_do_not_overlap() {
        let be = Arc::new(MemBackend::new());
        let w = Arc::new(FileWriter::create(be).unwrap());
        let offsets: Vec<u64> = {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let w = w.clone();
                    std::thread::spawn(move || {
                        let payload = vec![i as u8; 100 + i as usize];
                        w.append(&payload).unwrap().0
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        let mut sorted = offsets.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 8, "offsets collided: {offsets:?}");
    }
}
