//! The `RNTF` container file format (TFile analogue).
//!
//! ```text
//! [0..4)    magic  "RNTF"
//! [4..8)    u32 BE version (1)
//! [8..16)   u64 BE footer offset   (0 until the file is finalised)
//! [16..24)  u64 BE footer length
//! [24..)    basket payloads (self-describing compressed containers),
//!           appended in any order by the writer
//! footer:   Directory::encode() + u32 BE crc32(footer)
//! ```
//!
//! The footer-last layout mirrors ROOT: a file is readable iff the
//! footer was committed, and appending payloads never rewrites existing
//! bytes (crash-safe up to the final header update).

pub mod directory;
pub mod reader;
pub mod wire;
pub mod writer;

pub use directory::{BasketInfo, BranchMeta, Directory, TreeMeta};
pub use reader::FileReader;
pub use writer::FileWriter;

pub const MAGIC: &[u8; 4] = b"RNTF";
/// Format version. 2: every basket directory entry records its own
/// codec + level (per-column adaptive selection), one byte each after
/// the CRC.
pub const VERSION: u32 = 2;
pub const HEADER_LEN: u64 = 24;
