//! The `RNTF` container file format (TFile analogue).
//!
//! ```text
//! [0..4)    magic  "RNTF"
//! [4..8)    u32 BE version (1, 2, 3 or 4)
//! [8..16)   u64 BE footer offset   (0 until the file is finalised)
//! [16..24)  u64 BE footer length
//! [24..)    basket/page payloads (self-describing compressed
//!           containers), appended in any order by the writer
//! footer:   Directory::encode() + u32 BE crc32(footer)
//! ```
//!
//! The footer-last layout mirrors ROOT: a file is readable iff the
//! footer was committed, and appending payloads never rewrites existing
//! bytes (crash-safe up to the final header update).
//!
//! ## Wire versions
//!
//! * **1** — baskets record offset/lengths/entry-range/CRC only; the
//!   compression settings live solely in the self-describing block
//!   containers.
//! * **2** — every basket directory entry additionally records its own
//!   codec + level (per-column adaptive selection), one byte each after
//!   the CRC.
//! * **3** — paged columnar layout (RNTuple-style): a branch may store
//!   many independently-compressed *pages* per cluster (the per-basket
//!   record is reused as the page record), variable-length branches
//!   split into an offset-page/element-page pair list
//!   ([`BranchMeta::elems`]), and the tree records its cluster cuts
//!   ([`TreeMeta::clusters`]). Readers of v3 files must pair each
//!   offset page with its element page, which the writer stores
//!   immediately after it on disk.
//! * **4** — per-page min/max *zone maps* ([`directory::ZoneMap`]):
//!   every basket/page record may carry the numeric min/max of its
//!   values (one presence byte, then two f64 bit patterns), captured
//!   at page-seal time. Zones are advisory pruning metadata — fetch
//!   plans use them to skip pages a range predicate excludes
//!   ([`crate::cache::Predicate`]); decode never needs them, and
//!   v1–v3 files simply scan without pruning.
//!
//! Readers accept every version up to [`VERSION`]; writers emit
//! [`VERSION`] unless an older wire is requested explicitly
//! ([`writer::FileWriter::create_versioned`], compat tooling only).

pub mod directory;
pub mod reader;
pub mod wire;
pub mod writer;

pub use directory::{BasketInfo, BranchMeta, ClusterSpan, Directory, TreeMeta, ZoneMap};
pub use reader::FileReader;
pub use writer::FileWriter;

pub const MAGIC: &[u8; 4] = b"RNTF";
/// Current format version (see the module docs for the version history).
pub const VERSION: u32 = 4;
/// Oldest wire version this build can still decode.
pub const MIN_VERSION: u32 = 1;
pub const HEADER_LEN: u64 = 24;
