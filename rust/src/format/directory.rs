//! Footer directory: the file's table of contents (TDirectory/TKey
//! metadata analogue). Lists every tree, its schema, and the location,
//! sizes, entry range and checksum of every basket of every branch.

use crate::compress::{Codec, Settings};
use crate::error::{Error, Result};
use crate::serial::schema::{ColumnType, Schema};

use super::wire::{WireReader, WireWriter};

/// Location + integrity info for one stored basket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BasketInfo {
    /// Absolute file offset of the compressed container bytes.
    pub offset: u64,
    /// Stored (compressed container) length.
    pub comp_len: u32,
    /// Decompressed payload length.
    pub raw_len: u32,
    /// First entry number covered by this basket.
    pub first_entry: u64,
    /// Number of entries in this basket.
    pub n_entries: u32,
    /// CRC-32 of the stored bytes.
    pub crc: u32,
    /// Compression settings the basket was written with. The block
    /// container is self-describing, so readers never *need* this to
    /// decode — it records the writer's (possibly per-column adaptive)
    /// choice for inspection and tooling.
    pub settings: Settings,
}

/// Per-branch metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct BranchMeta {
    pub name: String,
    pub ty: ColumnType,
    pub baskets: Vec<BasketInfo>,
}

impl BranchMeta {
    /// Total entries across baskets.
    pub fn entries(&self) -> u64 {
        self.baskets.iter().map(|b| b.n_entries as u64).sum()
    }

    /// Stored bytes across baskets.
    pub fn stored_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.comp_len as u64).sum()
    }

    /// Uncompressed bytes across baskets.
    pub fn raw_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.raw_len as u64).sum()
    }

    /// Find the basket covering `entry`.
    pub fn basket_for(&self, entry: u64) -> Option<usize> {
        self.baskets
            .iter()
            .position(|b| entry >= b.first_entry && entry < b.first_entry + b.n_entries as u64)
    }

    /// Validate the basket index: contiguous, gapless entry ranges.
    pub fn check_index(&self) -> Result<()> {
        let mut next = 0u64;
        for (i, b) in self.baskets.iter().enumerate() {
            if b.first_entry != next {
                return Err(Error::Format(format!(
                    "branch '{}': basket {i} starts at {} expected {next}",
                    self.name, b.first_entry
                )));
            }
            next += b.n_entries as u64;
        }
        Ok(())
    }
}

/// Per-tree metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeMeta {
    pub name: String,
    pub schema: Schema,
    pub entries: u64,
    pub branches: Vec<BranchMeta>,
}

impl TreeMeta {
    pub fn branch(&self, name: &str) -> Option<&BranchMeta> {
        self.branches.iter().find(|b| b.name == name)
    }

    /// Validate invariants: one branch per schema field, consistent
    /// entry counts, gapless basket indexes.
    pub fn check(&self) -> Result<()> {
        if self.branches.len() != self.schema.len() {
            return Err(Error::Format(format!(
                "tree '{}': {} branches vs {} schema fields",
                self.name,
                self.branches.len(),
                self.schema.len()
            )));
        }
        for (br, f) in self.branches.iter().zip(&self.schema.fields) {
            if br.name != f.name || br.ty != f.ty {
                return Err(Error::Format(format!(
                    "tree '{}': branch '{}' does not match field '{}'",
                    self.name, br.name, f.name
                )));
            }
            br.check_index()?;
            if br.entries() != self.entries {
                return Err(Error::Format(format!(
                    "tree '{}': branch '{}' has {} entries, tree has {}",
                    self.name,
                    br.name,
                    br.entries(),
                    self.entries
                )));
            }
        }
        Ok(())
    }
}

/// The whole footer directory.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Directory {
    pub trees: Vec<TreeMeta>,
}

impl Directory {
    pub fn tree(&self, name: &str) -> Option<&TreeMeta> {
        self.trees.iter().find(|t| t.name == name)
    }

    /// Validate the whole directory: tree names must be unique (they
    /// are the lookup key) and every tree must satisfy its own
    /// invariants. Concurrent multi-tree writes go through this before
    /// the footer commits ([`crate::format::writer::FileWriter::finish_registered`]).
    pub fn check(&self) -> Result<()> {
        for (i, t) in self.trees.iter().enumerate() {
            if self.trees[..i].iter().any(|o| o.name == t.name) {
                return Err(Error::Format(format!(
                    "duplicate tree name '{}' in directory",
                    t.name
                )));
            }
            t.check()?;
        }
        Ok(())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.put_u32(self.trees.len() as u32);
        for t in &self.trees {
            w.put_str(&t.name);
            w.put_bytes(&t.schema.encode());
            w.put_u64(t.entries);
            w.put_u32(t.branches.len() as u32);
            for br in &t.branches {
                w.put_str(&br.name);
                w.put_u8(br.ty.code());
                w.put_u32(br.baskets.len() as u32);
                for b in &br.baskets {
                    w.put_u64(b.offset);
                    w.put_u32(b.comp_len);
                    w.put_u32(b.raw_len);
                    w.put_u64(b.first_entry);
                    w.put_u32(b.n_entries);
                    w.put_u32(b.crc);
                    w.put_u8(b.settings.codec.code());
                    w.put_u8(b.settings.level);
                }
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = WireReader::new(buf);
        let n_trees = r.get_u32()? as usize;
        let mut trees = Vec::with_capacity(n_trees);
        for _ in 0..n_trees {
            let name = r.get_str()?;
            let (schema, _) = Schema::decode(r.get_bytes()?)?;
            let entries = r.get_u64()?;
            let n_branches = r.get_u32()? as usize;
            let mut branches = Vec::with_capacity(n_branches);
            for _ in 0..n_branches {
                let bname = r.get_str()?;
                let ty = ColumnType::from_code(r.get_u8()?)?;
                let n_baskets = r.get_u32()? as usize;
                let mut baskets = Vec::with_capacity(n_baskets);
                for _ in 0..n_baskets {
                    baskets.push(BasketInfo {
                        offset: r.get_u64()?,
                        comp_len: r.get_u32()?,
                        raw_len: r.get_u32()?,
                        first_entry: r.get_u64()?,
                        n_entries: r.get_u32()?,
                        crc: r.get_u32()?,
                        settings: Settings {
                            codec: Codec::from_code(r.get_u8()?)?,
                            level: r.get_u8()?,
                        },
                    });
                }
                branches.push(BranchMeta { name: bname, ty, baskets });
            }
            trees.push(TreeMeta { name, schema, entries, branches });
        }
        Ok(Directory { trees })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::schema::Field;

    fn sample() -> Directory {
        let schema = Schema::new(vec![
            Field::new("pt", ColumnType::F32),
            Field::new("n", ColumnType::I32),
        ]);
        let mk = |name: &str, ty| BranchMeta {
            name: name.into(),
            ty,
            baskets: vec![
                BasketInfo {
                    offset: 24,
                    comp_len: 100,
                    raw_len: 400,
                    first_entry: 0,
                    n_entries: 100,
                    crc: 0xABCD,
                    settings: Settings::default_compressed(),
                },
                BasketInfo {
                    offset: 124,
                    comp_len: 80,
                    raw_len: 400,
                    first_entry: 100,
                    n_entries: 100,
                    crc: 0x1234,
                    settings: Settings::new(Codec::Lz4r, 3),
                },
            ],
        };
        Directory {
            trees: vec![TreeMeta {
                name: "events".into(),
                schema,
                entries: 200,
                branches: vec![mk("pt", ColumnType::F32), mk("n", ColumnType::I32)],
            }],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let d = sample();
        let enc = d.encode();
        assert_eq!(Directory::decode(&enc).unwrap(), d);
    }

    #[test]
    fn check_passes_for_consistent_meta() {
        sample().trees[0].check().unwrap();
    }

    #[test]
    fn check_catches_gaps() {
        let mut d = sample();
        d.trees[0].branches[0].baskets[1].first_entry = 150;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_entry_mismatch() {
        let mut d = sample();
        d.trees[0].entries = 999;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn directory_check_rejects_duplicate_tree_names() {
        let mut d = sample();
        d.check().unwrap();
        let dup = d.trees[0].clone();
        d.trees.push(dup);
        assert!(d.check().is_err(), "two trees named 'events' must be rejected");
    }

    #[test]
    fn basket_for_lookup() {
        let d = sample();
        let br = &d.trees[0].branches[0];
        assert_eq!(br.basket_for(0), Some(0));
        assert_eq!(br.basket_for(99), Some(0));
        assert_eq!(br.basket_for(100), Some(1));
        assert_eq!(br.basket_for(199), Some(1));
        assert_eq!(br.basket_for(200), None);
    }

    #[test]
    fn decode_garbage_errors() {
        assert!(Directory::decode(&[0xFF; 3]).is_err());
        let enc = sample().encode();
        assert!(Directory::decode(&enc[..enc.len() / 2]).is_err());
    }
}
