//! Footer directory: the file's table of contents (TDirectory/TKey
//! metadata analogue). Lists every tree, its schema, and the location,
//! sizes, entry range and checksum of every basket (classic layout) or
//! page (paged v3 layout) of every branch.

use crate::compress::{Codec, Settings};
use crate::error::{Error, Result};
use crate::serial::column::ColumnData;
use crate::serial::schema::{ColumnType, Schema};

use super::wire::{WireReader, WireWriter};

/// Per-page min/max zone map (wire v4): the numeric range of every
/// value a basket/page stores, captured at page-seal time. Fetch plans
/// use zones to *prune* pages a range predicate excludes; decode never
/// consults them, so a page without a zone (older wire, non-numeric
/// column, NaN present, empty page) simply never prunes.
///
/// Bounds are stored as `f64` **bit patterns** so the record stays
/// `Copy + Eq` like the rest of [`BasketInfo`] (f64 conversion of
/// integer values rounds to nearest, which is monotone — the converted
/// bounds still bracket every converted value, keeping pruning against
/// f64 predicate constants conservative).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ZoneMap {
    min_bits: u64,
    max_bits: u64,
}

impl ZoneMap {
    /// A zone from already-validated bounds. `min`/`max` must be
    /// non-NaN with `min <= max`; NaN inputs yield `None`.
    pub fn new(min: f64, max: f64) -> Option<ZoneMap> {
        if min.is_nan() || max.is_nan() || min > max {
            return None;
        }
        Some(ZoneMap { min_bits: min.to_bits(), max_bits: max.to_bits() })
    }

    /// Smallest value the page may contain.
    pub fn min(&self) -> f64 {
        f64::from_bits(self.min_bits)
    }

    /// Largest value the page may contain.
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits)
    }

    /// Scan a sealed column chunk for its numeric min/max. `None` for
    /// empty chunks, byte-string columns, and chunks containing NaN
    /// (a NaN page must never be pruned — NaN rows fail every range
    /// predicate *except* `!=`, and the zone cannot represent that).
    pub fn from_column(col: &ColumnData) -> Option<ZoneMap> {
        fn fold<T: Copy, F: Fn(T) -> f64>(vals: &[T], to: F) -> Option<(f64, f64)> {
            let mut it = vals.iter().map(|&v| to(v));
            let first = it.next()?;
            let mut lo = first;
            let mut hi = first;
            for v in it {
                if v.is_nan() {
                    return None;
                }
                if v < lo {
                    lo = v;
                }
                if v > hi {
                    hi = v;
                }
            }
            if lo.is_nan() {
                return None;
            }
            Some((lo, hi))
        }
        let (lo, hi) = match col {
            ColumnData::I32(v) => fold(v, |x| x as f64)?,
            ColumnData::I64(v) => fold(v, |x| x as f64)?,
            ColumnData::F32(v) => fold(v, |x| x as f64)?,
            ColumnData::F64(v) => fold(v, |x| x)?,
            ColumnData::U8(v) => fold(v, |x| x as f64)?,
            ColumnData::ListF32(v) => {
                // Zone over the *elements* — pruned together with the
                // page's rows when a predicate on another (row-aligned)
                // branch excludes them.
                let flat: Vec<f64> = v.iter().flatten().map(|&x| x as f64).collect();
                fold(&flat, |x| x)?
            }
            ColumnData::Bytes(_) => return None,
        };
        ZoneMap::new(lo, hi)
    }
}

/// Location + integrity info for one stored basket (classic layout) or
/// one stored page (paged v3 layout — pages reuse the basket record).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BasketInfo {
    /// Absolute file offset of the compressed container bytes.
    pub offset: u64,
    /// Stored (compressed container) length.
    pub comp_len: u32,
    /// Decompressed payload length.
    pub raw_len: u32,
    /// First entry number covered by this basket. For element pages
    /// ([`BranchMeta::elems`]) this counts *elements*, not rows.
    pub first_entry: u64,
    /// Number of entries in this basket (elements, for element pages).
    pub n_entries: u32,
    /// CRC-32 of the stored bytes.
    pub crc: u32,
    /// Compression settings the basket was written with. The block
    /// container is self-describing, so readers never *need* this to
    /// decode — it records the writer's (possibly per-column adaptive)
    /// choice for inspection and tooling.
    pub settings: Settings,
    /// Min/max of the values this basket stores (wire v4, advisory —
    /// `None` on older wires, non-numeric columns, or NaN-bearing
    /// pages). See [`ZoneMap`].
    pub zone: Option<ZoneMap>,
}

/// One cluster's entry span (v3 paged layout): the row range the
/// writer committed as a unit. Classic-layout trees leave the list
/// empty — their cluster cuts are the lead branch's basket cuts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterSpan {
    pub first_entry: u64,
    pub n_entries: u64,
}

/// Per-branch metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct BranchMeta {
    pub name: String,
    pub ty: ColumnType,
    /// Row-coordinate baskets (classic) or pages (v3). For a paged
    /// variable-length branch these are the *offset* pages: one
    /// page-relative end-offset per row, decoded against the paired
    /// element page.
    pub baskets: Vec<BasketInfo>,
    /// Element pages of a paged variable-length branch, paired 1:1
    /// with `baskets` (empty for fixed-width and classic branches).
    /// `elems[i]` holds exactly the elements of the rows in
    /// `baskets[i]`, is stored immediately after it on disk, and its
    /// `first_entry` counts global *elements*, not rows.
    pub elems: Vec<BasketInfo>,
}

impl BranchMeta {
    /// A classic (non-paged-list) branch with no element pages.
    pub fn simple(name: String, ty: ColumnType, baskets: Vec<BasketInfo>) -> Self {
        BranchMeta { name, ty, baskets, elems: Vec::new() }
    }

    /// Does this branch use the paged offset+element pair layout?
    pub fn is_paged_list(&self) -> bool {
        !self.elems.is_empty()
    }

    /// Total entries across baskets.
    pub fn entries(&self) -> u64 {
        self.baskets.iter().map(|b| b.n_entries as u64).sum()
    }

    /// Stored bytes across baskets (including element pages).
    pub fn stored_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.comp_len as u64).sum::<u64>()
            + self.elems.iter().map(|b| b.comp_len as u64).sum::<u64>()
    }

    /// Uncompressed bytes across baskets (including element pages).
    pub fn raw_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.raw_len as u64).sum::<u64>()
            + self.elems.iter().map(|b| b.raw_len as u64).sum::<u64>()
    }

    /// Find the basket covering `entry`.
    pub fn basket_for(&self, entry: u64) -> Option<usize> {
        self.baskets
            .iter()
            .position(|b| entry >= b.first_entry && entry < b.first_entry + b.n_entries as u64)
    }

    /// Validate the basket index: contiguous, gapless entry ranges,
    /// and — for paged variable-length branches — a 1:1 offset/element
    /// page pairing with element pages stored directly after their
    /// offset page and gapless in global element coordinates.
    pub fn check_index(&self) -> Result<()> {
        let mut next = 0u64;
        for (i, b) in self.baskets.iter().enumerate() {
            if b.first_entry != next {
                return Err(Error::Format(format!(
                    "branch '{}': basket {i} starts at {} expected {next}",
                    self.name, b.first_entry
                )));
            }
            next += b.n_entries as u64;
        }
        if self.elems.is_empty() {
            return Ok(());
        }
        if self.elems.len() != self.baskets.len() {
            return Err(Error::Format(format!(
                "branch '{}': {} element pages vs {} offset pages",
                self.name,
                self.elems.len(),
                self.baskets.len()
            )));
        }
        let mut next_elem = 0u64;
        for (i, (off, el)) in self.baskets.iter().zip(&self.elems).enumerate() {
            if el.first_entry != next_elem {
                return Err(Error::Format(format!(
                    "branch '{}': element page {i} starts at {} expected {next_elem}",
                    self.name, el.first_entry
                )));
            }
            next_elem += el.n_entries as u64;
            // Fetch plans rely on each offset/element pair being one
            // contiguous device range.
            if el.offset != off.offset + off.comp_len as u64 {
                return Err(Error::Format(format!(
                    "branch '{}': element page {i} at {} not adjacent to its offset page \
                     (expected {})",
                    self.name,
                    el.offset,
                    off.offset + off.comp_len as u64
                )));
            }
        }
        Ok(())
    }
}

/// Per-tree metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct TreeMeta {
    pub name: String,
    pub schema: Schema,
    pub entries: u64,
    pub branches: Vec<BranchMeta>,
    /// Cluster cuts of a v3 paged tree (empty for classic layouts).
    pub clusters: Vec<ClusterSpan>,
}

impl TreeMeta {
    /// A tree with no recorded cluster cuts (classic layout).
    pub fn classic(name: String, schema: Schema, entries: u64, branches: Vec<BranchMeta>) -> Self {
        TreeMeta { name, schema, entries, branches, clusters: Vec::new() }
    }

    pub fn branch(&self, name: &str) -> Option<&BranchMeta> {
        self.branches.iter().find(|b| b.name == name)
    }

    /// Validate invariants: one branch per schema field, consistent
    /// entry counts, gapless basket indexes, gapless cluster spans.
    pub fn check(&self) -> Result<()> {
        if self.branches.len() != self.schema.len() {
            return Err(Error::Format(format!(
                "tree '{}': {} branches vs {} schema fields",
                self.name,
                self.branches.len(),
                self.schema.len()
            )));
        }
        for (br, f) in self.branches.iter().zip(&self.schema.fields) {
            if br.name != f.name || br.ty != f.ty {
                return Err(Error::Format(format!(
                    "tree '{}': branch '{}' does not match field '{}'",
                    self.name, br.name, f.name
                )));
            }
            if br.is_paged_list() && br.ty.width().is_some() {
                return Err(Error::Format(format!(
                    "tree '{}': fixed-width branch '{}' has element pages",
                    self.name, br.name
                )));
            }
            br.check_index()?;
            if br.entries() != self.entries {
                return Err(Error::Format(format!(
                    "tree '{}': branch '{}' has {} entries, tree has {}",
                    self.name,
                    br.name,
                    br.entries(),
                    self.entries
                )));
            }
        }
        let mut next = 0u64;
        for (i, c) in self.clusters.iter().enumerate() {
            if c.first_entry != next {
                return Err(Error::Format(format!(
                    "tree '{}': cluster {i} starts at {} expected {next}",
                    self.name, c.first_entry
                )));
            }
            next += c.n_entries;
        }
        if !self.clusters.is_empty() && next != self.entries {
            return Err(Error::Format(format!(
                "tree '{}': clusters cover {next} entries, tree has {}",
                self.name, self.entries
            )));
        }
        Ok(())
    }
}

/// The whole footer directory.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Directory {
    pub trees: Vec<TreeMeta>,
}

fn put_basket(w: &mut WireWriter, b: &BasketInfo, version: u32) {
    w.put_u64(b.offset);
    w.put_u32(b.comp_len);
    w.put_u32(b.raw_len);
    w.put_u64(b.first_entry);
    w.put_u32(b.n_entries);
    w.put_u32(b.crc);
    if version >= 2 {
        w.put_u8(b.settings.codec.code());
        w.put_u8(b.settings.level);
    }
    // Zones are advisory pruning metadata: encoding at an older wire
    // simply drops them (unlike element pages / cluster spans, which
    // are structural and hard-error below v3).
    if version >= 4 {
        match b.zone {
            Some(z) => {
                w.put_u8(1);
                w.put_u64(z.min().to_bits());
                w.put_u64(z.max().to_bits());
            }
            None => w.put_u8(0),
        }
    }
}

fn get_basket(r: &mut WireReader, version: u32) -> Result<BasketInfo> {
    Ok(BasketInfo {
        offset: r.get_u64()?,
        comp_len: r.get_u32()?,
        raw_len: r.get_u32()?,
        first_entry: r.get_u64()?,
        n_entries: r.get_u32()?,
        crc: r.get_u32()?,
        settings: if version >= 2 {
            Settings { codec: Codec::from_code(r.get_u8()?)?, level: r.get_u8()? }
        } else {
            // v1 entries carry no settings; the block containers are
            // self-describing, so this placeholder is never decoded
            // against.
            Settings::uncompressed()
        },
        zone: if version >= 4 && r.get_u8()? != 0 {
            let min = f64::from_bits(r.get_u64()?);
            let max = f64::from_bits(r.get_u64()?);
            let z = ZoneMap::new(min, max).ok_or_else(|| {
                Error::Format(format!("basket zone map [{min}, {max}] is not a valid range"))
            })?;
            Some(z)
        } else {
            None
        },
    })
}

impl Directory {
    pub fn tree(&self, name: &str) -> Option<&TreeMeta> {
        self.trees.iter().find(|t| t.name == name)
    }

    /// Validate the whole directory: tree names must be unique (they
    /// are the lookup key) and every tree must satisfy its own
    /// invariants. Concurrent multi-tree writes go through this before
    /// the footer commits ([`crate::format::writer::FileWriter::finish_registered`]).
    pub fn check(&self) -> Result<()> {
        for (i, t) in self.trees.iter().enumerate() {
            if self.trees[..i].iter().any(|o| o.name == t.name) {
                return Err(Error::Format(format!(
                    "duplicate tree name '{}' in directory",
                    t.name
                )));
            }
            t.check()?;
        }
        Ok(())
    }

    pub fn encode(&self) -> Vec<u8> {
        // The current version can represent every directory, so this
        // cannot fail.
        self.encode_versioned(super::VERSION).expect("current-version encode is total")
    }

    /// Encode at a specific wire version. Fails if the directory uses
    /// features the requested version cannot represent (element pages
    /// or cluster spans need v3).
    pub fn encode_versioned(&self, version: u32) -> Result<Vec<u8>> {
        if !(super::MIN_VERSION..=super::VERSION).contains(&version) {
            return Err(Error::Format(format!("cannot encode directory version {version}")));
        }
        if version < 3 {
            for t in &self.trees {
                if !t.clusters.is_empty() || t.branches.iter().any(|b| !b.elems.is_empty()) {
                    return Err(Error::Format(format!(
                        "tree '{}' uses the paged layout; requires format version 3",
                        t.name
                    )));
                }
            }
        }
        let mut w = WireWriter::new();
        w.put_u32(self.trees.len() as u32);
        for t in &self.trees {
            w.put_str(&t.name)?;
            w.put_bytes(&t.schema.encode())?;
            w.put_u64(t.entries);
            w.put_u32(t.branches.len() as u32);
            for br in &t.branches {
                w.put_str(&br.name)?;
                w.put_u8(br.ty.code());
                w.put_u32(br.baskets.len() as u32);
                for b in &br.baskets {
                    put_basket(&mut w, b, version);
                }
                if version >= 3 {
                    w.put_u32(br.elems.len() as u32);
                    for b in &br.elems {
                        put_basket(&mut w, b, version);
                    }
                }
            }
            if version >= 3 {
                w.put_u32(t.clusters.len() as u32);
                for c in &t.clusters {
                    w.put_u64(c.first_entry);
                    w.put_u64(c.n_entries);
                }
            }
        }
        Ok(w.finish())
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        Self::decode_versioned(buf, super::VERSION)
    }

    /// Decode a footer written at `version` (the container header
    /// records which).
    pub fn decode_versioned(buf: &[u8], version: u32) -> Result<Self> {
        if !(super::MIN_VERSION..=super::VERSION).contains(&version) {
            return Err(Error::Format(format!("cannot decode directory version {version}")));
        }
        let mut r = WireReader::new(buf);
        let n_trees = r.get_u32()? as usize;
        let mut trees = Vec::with_capacity(n_trees);
        for _ in 0..n_trees {
            let name = r.get_str()?;
            let (schema, _) = Schema::decode(r.get_bytes()?)?;
            let entries = r.get_u64()?;
            let n_branches = r.get_u32()? as usize;
            let mut branches = Vec::with_capacity(n_branches);
            for _ in 0..n_branches {
                let bname = r.get_str()?;
                let ty = ColumnType::from_code(r.get_u8()?)?;
                let n_baskets = r.get_u32()? as usize;
                let mut baskets = Vec::with_capacity(n_baskets);
                for _ in 0..n_baskets {
                    baskets.push(get_basket(&mut r, version)?);
                }
                let mut elems = Vec::new();
                if version >= 3 {
                    let n_elems = r.get_u32()? as usize;
                    elems.reserve(n_elems);
                    for _ in 0..n_elems {
                        elems.push(get_basket(&mut r, version)?);
                    }
                }
                branches.push(BranchMeta { name: bname, ty, baskets, elems });
            }
            let mut clusters = Vec::new();
            if version >= 3 {
                let n_clusters = r.get_u32()? as usize;
                clusters.reserve(n_clusters);
                for _ in 0..n_clusters {
                    clusters.push(ClusterSpan {
                        first_entry: r.get_u64()?,
                        n_entries: r.get_u64()?,
                    });
                }
            }
            trees.push(TreeMeta { name, schema, entries, branches, clusters });
        }
        Ok(Directory { trees })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::schema::Field;

    fn sample() -> Directory {
        let schema = Schema::new(vec![
            Field::new("pt", ColumnType::F32),
            Field::new("n", ColumnType::I32),
        ]);
        let mk = |name: &str, ty| {
            BranchMeta::simple(
                name.into(),
                ty,
                vec![
                    BasketInfo {
                        offset: 24,
                        comp_len: 100,
                        raw_len: 400,
                        first_entry: 0,
                        n_entries: 100,
                        crc: 0xABCD,
                        settings: Settings::default_compressed(),
                        zone: ZoneMap::new(-2.5, 117.0),
                    },
                    BasketInfo {
                        offset: 124,
                        comp_len: 80,
                        raw_len: 400,
                        first_entry: 100,
                        n_entries: 100,
                        crc: 0x1234,
                        settings: Settings::new(Codec::Lz4r, 3),
                        zone: None,
                    },
                ],
            )
        };
        Directory {
            trees: vec![TreeMeta::classic(
                "events".into(),
                schema,
                200,
                vec![mk("pt", ColumnType::F32), mk("n", ColumnType::I32)],
            )],
        }
    }

    fn paged_sample() -> Directory {
        let schema = Schema::new(vec![
            Field::new("pt", ColumnType::F32),
            Field::new("hits", ColumnType::ListF32),
        ]);
        let page = |offset, comp_len, first_entry, n_entries| BasketInfo {
            offset,
            comp_len,
            raw_len: 4 * n_entries,
            first_entry,
            n_entries,
            crc: 0x5150,
            settings: Settings::default_compressed(),
            zone: ZoneMap::new(0.0, 64.0),
        };
        let pt = BranchMeta::simple(
            "pt".into(),
            ColumnType::F32,
            vec![page(24, 50, 0, 64), page(74, 50, 64, 36)],
        );
        let hits = BranchMeta {
            name: "hits".into(),
            ty: ColumnType::ListF32,
            baskets: vec![page(200, 40, 0, 64), page(380, 40, 64, 36)],
            // element pages directly follow their offset page, counted
            // in global element coordinates
            elems: vec![page(240, 140, 0, 130), page(420, 90, 130, 77)],
        };
        Directory {
            trees: vec![TreeMeta {
                name: "events".into(),
                schema,
                entries: 100,
                branches: vec![pt, hits],
                clusters: vec![
                    ClusterSpan { first_entry: 0, n_entries: 64 },
                    ClusterSpan { first_entry: 64, n_entries: 36 },
                ],
            }],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let d = sample();
        let enc = d.encode();
        assert_eq!(Directory::decode(&enc).unwrap(), d);
    }

    #[test]
    fn paged_encode_decode_roundtrip() {
        let d = paged_sample();
        d.check().unwrap();
        let enc = d.encode();
        assert_eq!(Directory::decode(&enc).unwrap(), d);
    }

    #[test]
    fn older_versions_reject_paged_features() {
        let d = paged_sample();
        assert!(d.encode_versioned(2).is_err());
        assert!(d.encode_versioned(1).is_err());
        // a classic directory still encodes fine at either version
        assert!(sample().encode_versioned(2).is_ok());
        assert!(sample().encode_versioned(1).is_ok());
    }

    /// Zones are v4 wire: a v3 encode of the same directory silently
    /// drops them (they are advisory), and the v3 decode comes back
    /// zone-free but otherwise identical.
    #[test]
    fn v3_wire_drops_zone_maps() {
        let d = sample();
        assert!(d.trees[0].branches[0].baskets[0].zone.is_some());
        let v3 = d.encode_versioned(3).unwrap();
        let v4 = d.encode_versioned(4).unwrap();
        // one presence byte per zone-less basket, +16 payload when present
        assert!(v4.len() > v3.len());
        let back = Directory::decode_versioned(&v3, 3).unwrap();
        for (t, t0) in back.trees.iter().zip(&d.trees) {
            for (b, b0) in t.branches.iter().zip(&t0.branches) {
                for (k, k0) in b.baskets.iter().zip(&b0.baskets) {
                    assert_eq!(k.zone, None);
                    assert_eq!(BasketInfo { zone: k0.zone, ..*k }, *k0);
                }
            }
        }
    }

    #[test]
    fn zone_map_roundtrips_through_v4_wire() {
        let d = sample();
        let back = Directory::decode(&d.encode()).unwrap();
        let z = back.trees[0].branches[0].baskets[0].zone.unwrap();
        assert_eq!((z.min(), z.max()), (-2.5, 117.0));
        assert_eq!(back.trees[0].branches[0].baskets[1].zone, None);
        assert_eq!(back, d);
    }

    #[test]
    fn zone_from_column_covers_numeric_types_and_rejects_nan() {
        let z = ZoneMap::from_column(&ColumnData::I32(vec![3, -7, 12])).unwrap();
        assert_eq!((z.min(), z.max()), (-7.0, 12.0));
        let z = ZoneMap::from_column(&ColumnData::F64(vec![0.5])).unwrap();
        assert_eq!((z.min(), z.max()), (0.5, 0.5));
        let z =
            ZoneMap::from_column(&ColumnData::ListF32(vec![vec![1.0, 9.0], vec![], vec![-4.0]]))
                .unwrap();
        assert_eq!((z.min(), z.max()), (-4.0, 9.0));
        assert_eq!(ZoneMap::from_column(&ColumnData::F32(vec![])), None);
        assert_eq!(ZoneMap::from_column(&ColumnData::F32(vec![1.0, f32::NAN])), None);
        assert_eq!(ZoneMap::from_column(&ColumnData::Bytes(vec![vec![1]])), None);
        assert_eq!(ZoneMap::new(f64::NAN, 1.0), None);
        assert_eq!(ZoneMap::new(2.0, 1.0), None);
    }

    #[test]
    fn v1_wire_omits_settings() {
        let d = sample();
        let v1 = d.encode_versioned(1).unwrap();
        let v2 = d.encode_versioned(2).unwrap();
        // 2 settings bytes per basket, 4 baskets
        assert_eq!(v2.len(), v1.len() + 8);
        let back = Directory::decode_versioned(&v1, 1).unwrap();
        assert_eq!(back.trees[0].branches[0].baskets.len(), 2);
        assert_eq!(
            back.trees[0].branches[0].baskets[0].settings,
            Settings::uncompressed()
        );
        // everything except the settings survives
        assert_eq!(back.trees[0].branches[0].baskets[0].offset, 24);
        assert_eq!(back.trees[0].branches[1].baskets[1].first_entry, 100);
    }

    #[test]
    fn check_passes_for_consistent_meta() {
        sample().trees[0].check().unwrap();
    }

    #[test]
    fn check_catches_gaps() {
        let mut d = sample();
        d.trees[0].branches[0].baskets[1].first_entry = 150;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_entry_mismatch() {
        let mut d = sample();
        d.trees[0].entries = 999;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_elem_page_gaps() {
        let mut d = paged_sample();
        d.trees[0].branches[1].elems[1].first_entry = 131;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_unpaired_elem_pages() {
        let mut d = paged_sample();
        d.trees[0].branches[1].elems.pop();
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_non_adjacent_elem_pages() {
        let mut d = paged_sample();
        d.trees[0].branches[1].elems[0].offset += 8;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn check_catches_cluster_gaps() {
        let mut d = paged_sample();
        d.trees[0].clusters[1].first_entry = 65;
        assert!(d.trees[0].check().is_err());
        let mut d = paged_sample();
        d.trees[0].clusters[1].n_entries = 35;
        assert!(d.trees[0].check().is_err());
    }

    #[test]
    fn directory_check_rejects_duplicate_tree_names() {
        let mut d = sample();
        d.check().unwrap();
        let dup = d.trees[0].clone();
        d.trees.push(dup);
        assert!(d.check().is_err(), "two trees named 'events' must be rejected");
    }

    #[test]
    fn basket_for_lookup() {
        let d = sample();
        let br = &d.trees[0].branches[0];
        assert_eq!(br.basket_for(0), Some(0));
        assert_eq!(br.basket_for(99), Some(0));
        assert_eq!(br.basket_for(100), Some(1));
        assert_eq!(br.basket_for(199), Some(1));
        assert_eq!(br.basket_for(200), None);
    }

    #[test]
    fn decode_garbage_errors() {
        assert!(Directory::decode(&[0xFF; 3]).is_err());
        let enc = sample().encode();
        assert!(Directory::decode(&enc[..enc.len() / 2]).is_err());
    }
}
