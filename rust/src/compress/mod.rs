//! Block compression layer (ROOT's RZip container analogue).
//!
//! Every basket payload is stored as a sequence of self-describing
//! compressed blocks, each with an 11-byte header (ROOT uses 9 bytes with
//! 3-byte sizes; we widen to u32 and keep the two-char algorithm tag):
//!
//! ```text
//! [0..2]  algorithm tag: "L4" (lz4r), "ZL" (rzip), "XX" (stored)
//! [2]     level
//! [3..7]  u32 LE compressed payload size
//! [7..11] u32 LE uncompressed size
//! ```
//!
//! Buffers larger than [`MAX_BLOCK`] are split so blocks stay
//! independently decompressible — the unit of the paper's parallel
//! (de)compression. If a block does not shrink, it is stored raw
//! (tag "XX"), like ROOT falling back to uncompressed baskets.

pub mod bitstream;
pub mod crc32;
pub mod huffman;
pub mod kernels;
pub mod lz4r;
pub mod pool;
pub mod rzip;
pub mod select;

use crate::error::{Error, Result};

pub use crc32::crc32;

/// Maximum uncompressed bytes per block.
pub const MAX_BLOCK: usize = 16 * 1024 * 1024;
/// Block header size in bytes.
pub const HEADER_LEN: usize = 11;

/// Compression algorithm selector (ROOT's ECompressionAlgorithm).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Codec {
    /// Store raw — no CPU cost, ratio 1.0.
    None,
    /// LZ4-style byte codec — fast, moderate ratio.
    Lz4r,
    /// LZ77 + Huffman — slow to compress, dense (zlib analogue).
    Rzip,
}

impl Codec {
    pub fn tag(self) -> [u8; 2] {
        match self {
            Codec::None => *b"XX",
            Codec::Lz4r => *b"L4",
            Codec::Rzip => *b"ZL",
        }
    }

    pub fn from_tag(tag: [u8; 2]) -> Result<Self> {
        match &tag {
            b"XX" => Ok(Codec::None),
            b"L4" => Ok(Codec::Lz4r),
            b"ZL" => Ok(Codec::Rzip),
            t => Err(Error::Codec(format!("unknown codec tag {t:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Lz4r => "lz4r",
            Codec::Rzip => "rzip",
        }
    }

    /// Single-byte wire code for directory metadata (format VERSION 2:
    /// each basket entry records its own codec + level so per-column
    /// selection survives into the file).
    pub fn code(self) -> u8 {
        match self {
            Codec::None => 0,
            Codec::Lz4r => 1,
            Codec::Rzip => 2,
        }
    }

    /// Inverse of [`Codec::code`].
    pub fn from_code(code: u8) -> Result<Self> {
        match code {
            0 => Ok(Codec::None),
            1 => Ok(Codec::Lz4r),
            2 => Ok(Codec::Rzip),
            other => Err(Error::Codec(format!("unknown codec code {other}"))),
        }
    }
}

impl std::str::FromStr for Codec {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Codec::None),
            "lz4r" | "lz4" => Ok(Codec::Lz4r),
            "rzip" | "zlib" => Ok(Codec::Rzip),
            other => Err(Error::Codec(format!("unknown codec '{other}'"))),
        }
    }
}

/// Codec + level, the per-file / per-branch compression configuration
/// (ROOT's fCompress).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Settings {
    pub codec: Codec,
    pub level: u8,
}

impl Settings {
    pub const fn new(codec: Codec, level: u8) -> Self {
        Settings { codec, level }
    }

    /// ROOT's default: zlib level 1-ish. We default to rzip level 4.
    pub const fn default_compressed() -> Self {
        Settings { codec: Codec::Rzip, level: 4 }
    }

    pub const fn uncompressed() -> Self {
        Settings { codec: Codec::None, level: 0 }
    }
}

impl Default for Settings {
    fn default() -> Self {
        Settings::default_compressed()
    }
}

fn write_header(out: &mut Vec<u8>, codec: Codec, level: u8, comp_len: usize, raw_len: usize) {
    out.extend_from_slice(&codec.tag());
    out.push(level);
    out.extend_from_slice(&(comp_len as u32).to_le_bytes());
    out.extend_from_slice(&(raw_len as u32).to_le_bytes());
}

fn emit_block(out: &mut Vec<u8>, settings: Settings, chunk: &[u8]) {
    // The stored (Codec::None) path writes the chunk straight into the
    // container — no intermediate copy, no per-block allocation.
    let payload = match settings.codec {
        Codec::None => None,
        Codec::Lz4r => Some(lz4r::compress(chunk, settings.level)),
        Codec::Rzip => Some(rzip::compress(chunk, settings.level)),
    };
    match payload {
        // Incompressible: store raw, like ROOT.
        Some(p) if p.len() < chunk.len() => {
            write_header(out, settings.codec, settings.level, p.len(), chunk.len());
            out.extend_from_slice(&p);
        }
        _ => {
            write_header(out, Codec::None, settings.level, chunk.len(), chunk.len());
            out.extend_from_slice(chunk);
        }
    }
}

/// Compress `src` into the block container format, appending to `out`
/// (which typically comes from [`pool`], so steady-state flushes do
/// not allocate scratch).
pub fn compress_into(settings: Settings, src: &[u8], out: &mut Vec<u8>) {
    out.reserve(src.len() / 2 + HEADER_LEN);
    if src.is_empty() {
        // Always emit at least one block so empty payloads round-trip.
        emit_block(out, settings, src);
        return;
    }
    for chunk in src.chunks(MAX_BLOCK) {
        emit_block(out, settings, chunk);
    }
}

/// Compress `src` into a fresh block-container buffer.
pub fn compress(settings: Settings, src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + HEADER_LEN);
    compress_into(settings, src, &mut out);
    out
}

/// The byte ranges at which [`compress_into`] splits `len` input bytes
/// into independent blocks — the write pipeline's task-decomposition
/// boundary. Compressing each range separately (in order) yields a
/// container byte-identical to compressing the whole buffer at once,
/// which is what lets the writer fan one basket out as per-block tasks
/// without changing the stored bytes. `len == 0` yields one empty
/// range (empty payloads still emit one block).
pub fn block_ranges(len: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return vec![0..0];
    }
    (0..len.div_ceil(MAX_BLOCK))
        .map(|i| i * MAX_BLOCK..((i + 1) * MAX_BLOCK).min(len))
        .collect()
}

/// Parsed view of one block in a container buffer.
#[derive(Debug, Clone, Copy)]
pub struct BlockInfo {
    pub codec: Codec,
    pub comp_len: usize,
    pub raw_len: usize,
    /// offset of the payload within the container
    pub payload_off: usize,
}

/// Parse the block header at byte offset `pos`.
fn parse_block_at(src: &[u8], pos: usize) -> Result<BlockInfo> {
    if pos + HEADER_LEN > src.len() {
        return Err(Error::Codec("truncated block header".into()));
    }
    let codec = Codec::from_tag([src[pos], src[pos + 1]])?;
    let comp_len =
        u32::from_le_bytes([src[pos + 3], src[pos + 4], src[pos + 5], src[pos + 6]]) as usize;
    let raw_len =
        u32::from_le_bytes([src[pos + 7], src[pos + 8], src[pos + 9], src[pos + 10]]) as usize;
    if raw_len > MAX_BLOCK {
        return Err(Error::Codec(format!("block too large: {raw_len}")));
    }
    let payload_off = pos + HEADER_LEN;
    if payload_off + comp_len > src.len() {
        return Err(Error::Codec("truncated block payload".into()));
    }
    Ok(BlockInfo { codec, comp_len, raw_len, payload_off })
}

/// Parse block boundaries without decompressing (used by the parallel
/// decompression scheduler to fan blocks out to the task pool).
pub fn scan_blocks(src: &[u8]) -> Result<Vec<BlockInfo>> {
    let mut blocks = Vec::new();
    let mut pos = 0usize;
    while pos < src.len() {
        let b = parse_block_at(src, pos)?;
        pos = b.payload_off + b.comp_len;
        blocks.push(b);
    }
    Ok(blocks)
}

/// Decompress a single scanned block, appending to `out`.
pub fn decompress_block_into(src: &[u8], b: &BlockInfo, out: &mut Vec<u8>) -> Result<()> {
    let payload = &src[b.payload_off..b.payload_off + b.comp_len];
    match b.codec {
        Codec::None => {
            if payload.len() != b.raw_len {
                return Err(Error::Codec("stored block size mismatch".into()));
            }
            out.extend_from_slice(payload);
            Ok(())
        }
        Codec::Lz4r => lz4r::decompress_into(payload, b.raw_len, out),
        Codec::Rzip => rzip::decompress_into(payload, b.raw_len, out),
    }
}

/// Decompress a single scanned block into a fresh buffer.
pub fn decompress_block(src: &[u8], b: &BlockInfo) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(b.raw_len);
    decompress_block_into(src, b, &mut out)?;
    Ok(out)
}

/// Decompress a whole container buffer, appending to `out`. This is
/// the basket hot path: `out` comes from [`pool`], blocks are parsed
/// and expanded in-place, and no intermediate buffers are allocated.
pub fn decompress_into(src: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let mut pos = 0usize;
    while pos < src.len() {
        let b = parse_block_at(src, pos)?;
        out.reserve(b.raw_len);
        decompress_block_into(src, &b, out)?;
        pos = b.payload_off + b.comp_len;
    }
    Ok(())
}

/// Decompress a whole container buffer into a fresh `Vec`.
pub fn decompress(src: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_into(src, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize) -> Vec<u8> {
        (0..n).map(|i| ((i / 7) % 251) as u8).collect()
    }

    #[test]
    fn roundtrip_all_codecs() {
        let data = sample(100_000);
        for codec in [Codec::None, Codec::Lz4r, Codec::Rzip] {
            let c = compress(Settings::new(codec, 5), &data);
            assert_eq!(decompress(&c).unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn empty_payload_roundtrips() {
        for codec in [Codec::None, Codec::Lz4r, Codec::Rzip] {
            let c = compress(Settings::new(codec, 5), &[]);
            assert!(!c.is_empty());
            assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
        }
    }

    #[test]
    fn incompressible_falls_back_to_stored() {
        let mut x = 1u32;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                (x >> 24) as u8
            })
            .collect();
        let c = compress(Settings::new(Codec::Rzip, 9), &data);
        let blocks = scan_blocks(&c).unwrap();
        assert!(blocks.iter().all(|b| b.codec == Codec::None || b.comp_len < b.raw_len));
        assert_eq!(decompress(&c).unwrap(), data);
        // stored fallback bounds expansion to HEADER_LEN per block
        assert!(c.len() <= data.len() + HEADER_LEN);
    }

    #[test]
    fn multiblock_split() {
        // force multiple blocks with a small synthetic MAX via big input
        let data = sample(MAX_BLOCK + 1000);
        let c = compress(Settings::new(Codec::Lz4r, 1), &data);
        let blocks = scan_blocks(&c).unwrap();
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].raw_len, MAX_BLOCK);
        assert_eq!(blocks[1].raw_len, 1000);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn scan_rejects_garbage() {
        assert!(scan_blocks(b"QQ\x05junkjunk").is_err());
        assert!(scan_blocks(&[0x4C]).is_err()); // truncated header
        let data = sample(1000);
        let mut c = compress(Settings::default(), &data);
        c.truncate(c.len() - 1);
        assert!(scan_blocks(&c).is_err());
    }

    #[test]
    fn decompress_into_appends_at_nonzero_base() {
        // Back-references inside a block must resolve relative to the
        // block's own start, not the start of the output buffer.
        let data = b"abcabcabc_repeat_repeat_repeat".repeat(500);
        for codec in [Codec::None, Codec::Lz4r, Codec::Rzip] {
            let c = compress(Settings::new(codec, 5), &data);
            let mut out = b"prefix".to_vec();
            decompress_into(&c, &mut out).unwrap();
            assert_eq!(&out[..6], b"prefix", "{codec:?}");
            assert_eq!(&out[6..], &data[..], "{codec:?}");
        }
    }

    #[test]
    fn compress_into_appends() {
        let data = sample(10_000);
        let mut out = vec![0xEE; 3];
        compress_into(Settings::new(Codec::Lz4r, 3), &data, &mut out);
        assert_eq!(&out[..3], &[0xEE; 3]);
        assert_eq!(decompress(&out[3..]).unwrap(), data);
    }

    #[test]
    fn block_ranges_cover_input_exactly() {
        assert_eq!(block_ranges(0), vec![0..0]);
        assert_eq!(block_ranges(1), vec![0..1]);
        assert_eq!(block_ranges(MAX_BLOCK), vec![0..MAX_BLOCK]);
        let r = block_ranges(2 * MAX_BLOCK + 7);
        assert_eq!(
            r,
            vec![0..MAX_BLOCK, MAX_BLOCK..2 * MAX_BLOCK, 2 * MAX_BLOCK..2 * MAX_BLOCK + 7]
        );
    }

    #[test]
    fn per_range_compression_concat_matches_whole() {
        // The invariant the pipelined writer's block tasks rely on:
        // compressing each block range separately and concatenating
        // equals compressing the whole buffer.
        let data = sample(MAX_BLOCK + 1000);
        for codec in [Codec::None, Codec::Lz4r] {
            let settings = Settings::new(codec, 2);
            let whole = compress(settings, &data);
            let mut cat = Vec::new();
            for r in block_ranges(data.len()) {
                compress_into(settings, &data[r], &mut cat);
            }
            assert_eq!(cat, whole, "{codec:?}");
        }
        // empty payload: the single empty range emits the empty block
        let whole = compress(Settings::new(Codec::Rzip, 3), &[]);
        let mut cat = Vec::new();
        for r in block_ranges(0) {
            compress_into(Settings::new(Codec::Rzip, 3), &data[r], &mut cat);
        }
        assert_eq!(cat, whole);
    }

    #[test]
    fn codec_parse() {
        assert_eq!("lz4".parse::<Codec>().unwrap(), Codec::Lz4r);
        assert_eq!("zlib".parse::<Codec>().unwrap(), Codec::Rzip);
        assert_eq!("none".parse::<Codec>().unwrap(), Codec::None);
        assert!("snappy".parse::<Codec>().is_err());
    }

    #[test]
    fn rzip_denser_than_lz4r_on_text() {
        let data = b"structured event record with field names and values "
            .repeat(2000);
        let zl = compress(Settings::new(Codec::Rzip, 6), &data);
        let l4 = compress(Settings::new(Codec::Lz4r, 6), &data);
        assert!(zl.len() < l4.len(), "rzip {} vs lz4r {}", zl.len(), l4.len());
    }
}
