//! LSB-first bit reader/writer for the entropy-coded `Rzip` codec.

/// LSB-first bit writer over a growable byte buffer.
pub struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        BitWriter { out: Vec::new(), acc: 0, nbits: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        BitWriter { out: Vec::with_capacity(cap), acc: 0, nbits: 0 }
    }

    /// Write the low `n` bits of `bits` (n <= 32), LSB first.
    #[inline]
    pub fn put(&mut self, bits: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || bits < (1u32 << n));
        self.acc |= (bits as u64) << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Flush any partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
        }
        self.out
    }

    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.nbits as usize
    }
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Top up the accumulator to ≥ 56 buffered bits (fewer only near
    /// the end of the data). Public so batched decoders can pay for
    /// one refill and then consume several symbols against
    /// [`BitReader::buffered`] / [`BitReader::peek_buffered`].
    #[inline]
    pub fn refill(&mut self) {
        // Fast path (EXPERIMENTS.md §Perf, L3 iteration 3): absorb up
        // to 7 bytes with one unaligned u64 load instead of a per-byte
        // loop — the refill sits under every decoded symbol.
        if self.pos + 8 <= self.data.len() {
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.acc |= w << self.nbits;
            let consumed = (63 - self.nbits) >> 3;
            self.pos += consumed as usize;
            self.nbits += consumed * 8;
            return;
        }
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n <= 32), LSB first. Reading past the end yields
    /// zero bits — callers detect truncation via symbol counts.
    #[inline]
    pub fn get(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        if self.nbits < n {
            self.refill();
        }
        let mask = if n == 32 { u64::MAX } else { (1u64 << n) - 1 };
        let v = (self.acc & mask) as u32;
        let taken = n.min(self.nbits);
        self.acc >>= taken;
        self.nbits -= taken;
        v
    }

    /// Peek up to `n` bits without consuming.
    #[inline]
    pub fn peek(&mut self, n: u32) -> u32 {
        if self.nbits < n {
            self.refill();
        }
        let mask = if n == 32 { u64::MAX } else { (1u64 << n) - 1 };
        (self.acc & mask) as u32
    }

    /// Bits currently buffered in the accumulator.
    #[inline]
    pub fn buffered(&self) -> u32 {
        self.nbits
    }

    /// Peek `n` bits **without** the refill check: the caller must have
    /// established `buffered() >= n` (after a [`BitReader::refill`]).
    /// This removes the per-symbol branch from batched decode loops.
    #[inline]
    pub fn peek_buffered(&self, n: u32) -> u32 {
        debug_assert!(self.nbits >= n);
        let mask = if n == 32 { u64::MAX } else { (1u64 << n) - 1 };
        (self.acc & mask) as u32
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn skip(&mut self, n: u32) {
        let taken = n.min(self.nbits);
        self.acc >>= taken;
        self.nbits -= taken;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_varied_widths() {
        let mut w = BitWriter::new();
        let vals: Vec<(u32, u32)> = (0..1000)
            .map(|i| {
                let n = 1 + (i % 24) as u32;
                let v = (i as u32).wrapping_mul(2654435761) & ((1u32 << n) - 1);
                (v, n)
            })
            .collect();
        for &(v, n) in &vals {
            w.put(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.get(n), v);
        }
    }

    #[test]
    fn peek_then_skip() {
        let mut w = BitWriter::new();
        w.put(0b1011, 4);
        w.put(0b110, 3);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek(4), 0b1011);
        r.skip(4);
        assert_eq!(r.get(3), 0b110);
    }

    #[test]
    fn read_past_end_is_zero() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.get(8), 0xFF);
        assert_eq!(r.get(8), 0);
    }
}
