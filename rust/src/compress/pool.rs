//! Scratch-buffer pool for the basket (de)compression hot path.
//!
//! Riley & Jones ("Multi-threaded Output in CMS using ROOT") attribute
//! most multithreaded I/O overhead to allocation and queue contention;
//! this module removes the allocation half on our read path. Every
//! per-basket scratch buffer (the fetched compressed bytes and the
//! decompressed wire bytes) is drawn from here instead of `Vec::new`,
//! so in steady state a reading thread performs **zero heap
//! allocations per basket** for scratch space — buffers grow to the
//! high-water basket size once and are recycled forever after.
//!
//! Two tiers:
//! * a **thread-local shelf** (no locking, LIFO so the most
//!   recently-used — cache-warm — buffer is handed out first), and
//! * a shared global [`BufferPool`] fallback that lets buffers migrate
//!   between threads (e.g. warm-up on the caller, steady state on the
//!   IMT workers).
//!
//! Hit/miss counters are kept on the global pool (thread-local hits
//! included) so tests can assert the steady-state property — see
//! [`stats`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Buffers above this capacity are dropped instead of pooled, bounding
/// the pool's resident memory (a pathological 16 MB+ basket should not
/// pin its buffer forever).
pub const MAX_POOLED_CAPACITY: usize = 32 * 1024 * 1024;

/// Max buffers kept per thread-local shelf. A reading task holds at
/// most two scratch buffers at once (raw + decompressed), so a small
/// shelf already gives a 100% hit rate; the slack absorbs nesting.
const SHELF_MAX: usize = 8;

/// Max buffers kept in the shared fallback pool.
const GLOBAL_MAX: usize = 64;

/// Shared (cross-thread) buffer pool: a LIFO stack behind a mutex.
/// Instantiable for tests; the library hot path uses the process-wide
/// instance via [`get`] / [`stats`].
pub struct BufferPool {
    stack: Mutex<Vec<Vec<u8>>>,
    max_buffers: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Snapshot of pool effectiveness counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// `get` calls served from a pooled buffer (thread-local or shared).
    pub hits: u64,
    /// `get` calls that had to allocate a fresh buffer.
    pub misses: u64,
}

impl PoolStats {
    /// Fraction of requests served without allocating (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

impl BufferPool {
    pub const fn new(max_buffers: usize) -> Self {
        BufferPool {
            stack: Mutex::new(Vec::new()),
            max_buffers,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Take a cleared buffer with at least `min_capacity` capacity.
    /// Counted as a hit when a pooled buffer was reused (even if it
    /// had to grow — growth converges to the high-water mark).
    pub fn take(&self, min_capacity: usize) -> Vec<u8> {
        let reused = self.stack.lock().unwrap().pop();
        match reused {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                if buf.capacity() < min_capacity {
                    buf.reserve(min_capacity);
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(min_capacity)
            }
        }
    }

    /// Return a buffer to the pool (dropped when full or oversized).
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_CAPACITY {
            return;
        }
        buf.clear();
        let mut stack = self.stack.lock().unwrap();
        if stack.len() < self.max_buffers {
            stack.push(buf);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

static GLOBAL: BufferPool = BufferPool::new(GLOBAL_MAX);

thread_local! {
    static SHELF: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

/// Borrow a scratch buffer from the process-wide pool: thread-local
/// shelf first (lock-free), shared pool as fallback. The buffer is
/// returned automatically when the [`Scratch`] guard drops.
pub fn get(min_capacity: usize) -> Scratch {
    let local = SHELF.with(|s| s.borrow_mut().pop());
    let buf = match local {
        Some(mut buf) => {
            GLOBAL.hits.fetch_add(1, Ordering::Relaxed);
            buf.clear();
            if buf.capacity() < min_capacity {
                buf.reserve(min_capacity);
            }
            buf
        }
        None => GLOBAL.take(min_capacity),
    };
    Scratch { buf }
}

/// Counters of the process-wide pool (thread-local hits included).
pub fn stats() -> PoolStats {
    GLOBAL.stats()
}

/// RAII scratch buffer: derefs to `Vec<u8>`, returns itself to the
/// current thread's shelf (overflow: the shared pool) on drop.
pub struct Scratch {
    buf: Vec<u8>,
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_CAPACITY {
            return;
        }
        let overflow = SHELF.with(|s| {
            let mut shelf = s.borrow_mut();
            if shelf.len() < SHELF_MAX {
                shelf.push(buf);
                None
            } else {
                Some(buf)
            }
        });
        if let Some(buf) = overflow {
            GLOBAL.put(buf);
        }
    }
}

impl From<Vec<u8>> for Scratch {
    /// Adopt an owned buffer: it joins the pool when the guard drops.
    /// This is how externally-produced payloads (tests, adapters)
    /// enter the recycling loop of [`BasketSink`] implementations.
    ///
    /// [`BasketSink`]: crate::tree::sink::BasketSink
    fn from(buf: Vec<u8>) -> Self {
        Scratch { buf }
    }
}

impl std::ops::Deref for Scratch {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn private_pool_steady_state_has_zero_allocations() {
        // After the first (cold) take, every subsequent take of the
        // same or smaller size reuses the one buffer: exactly 1 miss.
        let pool = BufferPool::new(8);
        for _ in 0..100 {
            let mut b = pool.take(4096);
            b.extend_from_slice(&[1, 2, 3]);
            pool.put(b);
        }
        let st = pool.stats();
        assert_eq!(st.misses, 1, "steady state must not allocate");
        assert_eq!(st.hits, 99);
        assert!(st.hit_rate() > 0.98);
    }

    #[test]
    fn buffers_grow_to_high_water_mark() {
        let pool = BufferPool::new(8);
        let b = pool.take(100);
        pool.put(b);
        let b = pool.take(100_000); // same buffer, grown
        assert!(b.capacity() >= 100_000);
        pool.put(b);
        let b = pool.take(50); // stays at high-water capacity
        assert!(b.capacity() >= 100_000);
        assert_eq!(pool.stats().misses, 1);
    }

    #[test]
    fn oversized_and_empty_buffers_are_not_pooled() {
        let pool = BufferPool::new(8);
        pool.put(Vec::new());
        pool.put(Vec::with_capacity(MAX_POOLED_CAPACITY + 1));
        let _ = pool.take(16);
        assert_eq!(pool.stats().misses, 1, "nothing should have been pooled");
    }

    #[test]
    fn pool_is_bounded() {
        let pool = BufferPool::new(2);
        for _ in 0..10 {
            pool.put(Vec::with_capacity(64));
        }
        assert_eq!(pool.stack.lock().unwrap().len(), 2);
    }

    #[test]
    fn thread_local_shelf_guarantees_hits_single_threaded() {
        // The shelf is per-thread, so no concurrent test can steal our
        // warm buffers: after warm-up, hits must grow by >= our reuse
        // count (other threads can only add to the global counters).
        {
            let _warm = (get(1024), get(1024)); // populate the shelf
        }
        let before = stats().hits;
        for _ in 0..50 {
            let a = get(512);
            let b = get(512);
            drop(a);
            drop(b);
        }
        let after = stats().hits;
        assert!(
            after - before >= 100,
            "expected >= 100 shelf hits, got {}",
            after - before
        );
    }

    #[test]
    fn scratch_derefs_like_a_vec() {
        let mut s = get(8);
        s.extend_from_slice(b"hello");
        assert_eq!(&s[..], b"hello");
        assert_eq!(s.len(), 5);
        s.clear();
        assert!(s.is_empty());
    }
}
