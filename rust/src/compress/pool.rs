//! Scratch-buffer pool for the basket (de)compression hot path.
//!
//! Riley & Jones ("Multi-threaded Output in CMS using ROOT") attribute
//! most multithreaded I/O overhead to allocation and queue contention;
//! this module removes the allocation half on our read path. Every
//! per-basket scratch buffer (the fetched compressed bytes and the
//! decompressed wire bytes) is drawn from here instead of `Vec::new`,
//! so in steady state a reading thread performs **zero heap
//! allocations per basket** for scratch space — buffers grow to the
//! high-water basket size once and are recycled forever after.
//!
//! Two tiers:
//! * a **thread-local shelf** (no locking, LIFO so the most
//!   recently-used — cache-warm — buffer is handed out first), and
//! * a shared global [`BufferPool`] fallback that lets buffers migrate
//!   between threads (e.g. warm-up on the caller, steady state on the
//!   IMT workers).
//!
//! Hit/miss counters are kept on the global pool (thread-local hits
//! included) so tests can assert the steady-state property — see
//! [`stats`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Buffers above this capacity are dropped instead of pooled, bounding
/// the pool's resident memory (a pathological 16 MB+ basket should not
/// pin its buffer forever).
pub const MAX_POOLED_CAPACITY: usize = 32 * 1024 * 1024;

/// Max buffers kept per thread-local shelf. A reading task holds at
/// most two scratch buffers at once (raw + decompressed), so a small
/// shelf already gives a 100% hit rate; the slack absorbs nesting.
const SHELF_MAX: usize = 8;

/// Max buffers kept in the shared fallback pool.
const GLOBAL_MAX: usize = 64;

/// Session sizing of the shared pool (see [`reserve_writer`]): the
/// baseline resident-byte high-water once any writer is registered...
const BASE_MAX_BYTES: usize = 128 * 1024 * 1024;
/// ...plus this much head-room per registered writer,
const PER_WRITER_BYTES: usize = 16 * 1024 * 1024;
/// ...and this many extra pooled buffers per registered writer.
const PER_WRITER_BUFFERS: usize = 8;

/// Shared (cross-thread) buffer pool: a LIFO stack behind a mutex with
/// a resident-byte high-water. Returning a buffer past the high-water
/// (or the buffer cap) **evicts the coldest pooled buffers** — the
/// bottom of the LIFO stack, least recently used — to make room, and
/// drops the newcomer only when eviction cannot help; both outcomes
/// are counted ([`PoolStats::evictions`] / [`PoolStats::drops`]) so
/// many-writer pressure is observable instead of silently unbounded.
/// Instantiable for tests; the library hot path uses the process-wide
/// instance via [`get`] / [`stats`].
pub struct BufferPool {
    stack: Mutex<Vec<Vec<u8>>>,
    max_buffers: AtomicUsize,
    /// Resident-byte high-water (capacity sum of pooled buffers).
    max_bytes: AtomicUsize,
    /// Current resident bytes (mutated only under the stack lock).
    resident: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
    drops: AtomicU64,
    evictions: AtomicU64,
}

/// Snapshot of pool effectiveness counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// `get` calls served from a pooled buffer (thread-local or shared).
    pub hits: u64,
    /// `get` calls that had to allocate a fresh buffer.
    pub misses: u64,
    /// Returned buffers dropped (oversized, or over the high-water even
    /// after eviction). A bounded value under steady load means the
    /// eviction policy is recycling instead of discarding.
    pub drops: u64,
    /// Cold pooled buffers evicted to admit warmer returns.
    pub evictions: u64,
    /// Capacity bytes currently resident in the shared pool.
    pub resident_bytes: usize,
}

impl PoolStats {
    /// Fraction of requests served without allocating (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

impl BufferPool {
    /// Pool capped at `max_buffers` with no byte high-water (legacy
    /// behaviour; sessions install one via [`BufferPool::set_limits`]).
    pub const fn new(max_buffers: usize) -> Self {
        BufferPool::with_limits(max_buffers, usize::MAX)
    }

    /// Pool capped at `max_buffers` buffers and `max_bytes` resident
    /// capacity bytes.
    pub const fn with_limits(max_buffers: usize, max_bytes: usize) -> Self {
        BufferPool {
            stack: Mutex::new(Vec::new()),
            max_buffers: AtomicUsize::new(max_buffers),
            max_bytes: AtomicUsize::new(max_bytes),
            resident: AtomicUsize::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Retune the pool's high-water marks (session-scoped sizing) and
    /// evict down to them if the pool is currently over.
    pub fn set_limits(&self, max_buffers: usize, max_bytes: usize) {
        self.max_buffers.store(max_buffers, Ordering::SeqCst);
        self.max_bytes.store(max_bytes, Ordering::SeqCst);
        let mut stack = self.stack.lock().unwrap();
        while !stack.is_empty()
            && (stack.len() > max_buffers
                || self.resident.load(Ordering::Relaxed) > max_bytes)
        {
            let evicted = stack.remove(0);
            self.resident.fetch_sub(evicted.capacity(), Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take a cleared buffer with at least `min_capacity` capacity.
    /// Counted as a hit when a pooled buffer was reused (even if it
    /// had to grow — growth converges to the high-water mark).
    pub fn take(&self, min_capacity: usize) -> Vec<u8> {
        let reused = {
            let mut stack = self.stack.lock().unwrap();
            let buf = stack.pop();
            if let Some(b) = &buf {
                self.resident.fetch_sub(b.capacity(), Ordering::Relaxed);
            }
            buf
        };
        match reused {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                if buf.capacity() < min_capacity {
                    buf.reserve(min_capacity);
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(min_capacity)
            }
        }
    }

    /// Return a buffer to the pool. Past the high-water the coldest
    /// pooled buffers are evicted in its favour (the newcomer is
    /// cache-warm); the newcomer itself is dropped — and counted —
    /// only when it is oversized or larger than the whole budget.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        if buf.capacity() > MAX_POOLED_CAPACITY {
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        }
        buf.clear();
        let cap = buf.capacity();
        let mut stack = self.stack.lock().unwrap();
        let max_buffers = self.max_buffers.load(Ordering::SeqCst);
        let max_bytes = self.max_bytes.load(Ordering::SeqCst);
        if cap > max_bytes || max_buffers == 0 {
            // Infeasible even on an empty pool: drop the newcomer
            // without sacrificing the resident working set to a
            // pointless eviction sweep.
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        }
        while !stack.is_empty()
            && (stack.len() >= max_buffers
                || self.resident.load(Ordering::Relaxed).saturating_add(cap) > max_bytes)
        {
            let evicted = stack.remove(0);
            self.resident.fetch_sub(evicted.capacity(), Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        if stack.len() >= max_buffers
            || self.resident.load(Ordering::Relaxed).saturating_add(cap) > max_bytes
        {
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.resident.fetch_add(cap, Ordering::Relaxed);
        stack.push(buf);
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            drops: self.drops.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
        }
    }
}

static GLOBAL: BufferPool = BufferPool::new(GLOBAL_MAX);

thread_local! {
    static SHELF: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

/// Borrow a scratch buffer from the process-wide pool: thread-local
/// shelf first (lock-free), shared pool as fallback. The buffer is
/// returned automatically when the [`Scratch`] guard drops.
pub fn get(min_capacity: usize) -> Scratch {
    let local = SHELF.with(|s| s.borrow_mut().pop());
    let buf = match local {
        Some(mut buf) => {
            GLOBAL.hits.fetch_add(1, Ordering::Relaxed);
            buf.clear();
            if buf.capacity() < min_capacity {
                buf.reserve(min_capacity);
            }
            buf
        }
        None => GLOBAL.take(min_capacity),
    };
    Scratch { buf }
}

/// Counters of the process-wide pool (thread-local hits included).
pub fn stats() -> PoolStats {
    GLOBAL.stats()
}

/// Registered writers (session accounting for the shared pool). A
/// mutex — not an atomic — so the count update and the matching
/// `set_limits` apply as one unit: racing registrations can never
/// leave the pool sized for a stale writer count.
static WRITERS: Mutex<usize> = Mutex::new(0);

fn apply_writer_limits(n: usize) {
    if n == 0 {
        // Back to the unscoped defaults (no byte high-water): the last
        // session released its reservation.
        GLOBAL.set_limits(GLOBAL_MAX, usize::MAX);
    } else {
        GLOBAL.set_limits(
            GLOBAL_MAX + n * PER_WRITER_BUFFERS,
            BASE_MAX_BYTES + n * PER_WRITER_BYTES,
        );
    }
}

/// Session-scoped accounting: an [`crate::session::Session`] registers
/// each writer it opens, growing the shared pool's high-water marks so
/// many concurrent writers recycle buffers instead of thrashing the
/// allocator — and shrinking (evicting) them back when writers close.
pub fn reserve_writer() {
    let mut writers = WRITERS.lock().unwrap_or_else(|p| p.into_inner());
    *writers += 1;
    apply_writer_limits(*writers);
}

/// Release one writer's reservation (the pair of [`reserve_writer`]);
/// evicts the shared pool down to the reduced high-water.
pub fn release_writer() {
    let mut writers = WRITERS.lock().unwrap_or_else(|p| p.into_inner());
    debug_assert!(*writers > 0, "release_writer without reserve_writer");
    *writers = writers.saturating_sub(1);
    apply_writer_limits(*writers);
}

/// Read-side twin of [`reserve_writer`]: a streaming prefetcher
/// ([`crate::cache`]) holds pooled scratch for its coalesced fetch
/// windows, so a session registers each reader against the same
/// head-room accounting — the pool cannot tell (and need not care)
/// which direction a registered pipeline moves bytes.
pub fn reserve_reader() {
    reserve_writer();
}

/// Release one reader's reservation (the pair of [`reserve_reader`]).
pub fn release_reader() {
    release_writer();
}

/// Writers currently registered against the shared pool.
pub fn registered_writers() -> usize {
    *WRITERS.lock().unwrap_or_else(|p| p.into_inner())
}

/// RAII scratch buffer: derefs to `Vec<u8>`, returns itself to the
/// current thread's shelf (overflow: the shared pool) on drop.
pub struct Scratch {
    buf: Vec<u8>,
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_CAPACITY {
            return;
        }
        let overflow = SHELF.with(|s| {
            let mut shelf = s.borrow_mut();
            if shelf.len() < SHELF_MAX {
                shelf.push(buf);
                None
            } else {
                Some(buf)
            }
        });
        if let Some(buf) = overflow {
            GLOBAL.put(buf);
        }
    }
}

impl From<Vec<u8>> for Scratch {
    /// Adopt an owned buffer: it joins the pool when the guard drops.
    /// This is how externally-produced payloads (tests, adapters)
    /// enter the recycling loop of [`BasketSink`] implementations.
    ///
    /// [`BasketSink`]: crate::tree::sink::BasketSink
    fn from(buf: Vec<u8>) -> Self {
        Scratch { buf }
    }
}

impl std::ops::Deref for Scratch {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

/// `u32` buffers above this length are dropped instead of pooled
/// (16 MB resident), mirroring [`MAX_POOLED_CAPACITY`] for the typed
/// pool below.
pub const MAX_POOLED_U32_LEN: usize = 4 * 1024 * 1024;

/// Max `u32` buffers kept per thread-local shelf. The rzip tokeniser
/// holds exactly two at once (`head` + `prev` chains), so a shelf of
/// four absorbs nesting with room to spare.
const U32_SHELF_MAX: usize = 4;

/// Max `u32` buffers kept in the shared fallback pool.
const U32_GLOBAL_MAX: usize = 16;

thread_local! {
    static U32_SHELF: RefCell<Vec<Vec<u32>>> = const { RefCell::new(Vec::new()) };
}

static U32_GLOBAL: Mutex<Vec<Vec<u32>>> = Mutex::new(Vec::new());
static U32_HITS: AtomicU64 = AtomicU64::new(0);
static U32_MISSES: AtomicU64 = AtomicU64::new(0);

/// Borrow a `u32` scratch buffer holding exactly `len` copies of
/// `fill`, recycled through the same two-tier (thread-local shelf +
/// shared fallback) scheme as the byte pool.
///
/// This exists for the rzip tokeniser's hash tables: before pooling,
/// every `compress` call allocated (and the allocator zeroed) a fresh
/// 512 KB `head` array — a fixed tax that dominated tiny-basket
/// compression. A recycled buffer only pays the `fill` memset over
/// warm pages.
pub fn get_u32(len: usize, fill: u32) -> ScratchU32 {
    let reused = U32_SHELF
        .with(|s| s.borrow_mut().pop())
        .or_else(|| U32_GLOBAL.lock().unwrap_or_else(|p| p.into_inner()).pop());
    let mut buf = match reused {
        Some(b) => {
            U32_HITS.fetch_add(1, Ordering::Relaxed);
            b
        }
        None => {
            U32_MISSES.fetch_add(1, Ordering::Relaxed);
            Vec::new()
        }
    };
    buf.clear();
    buf.resize(len, fill);
    ScratchU32 { buf }
}

/// `(hits, misses)` of the typed `u32` pool — lets tests pin the
/// steady-state zero-allocation property.
pub fn u32_stats() -> (u64, u64) {
    (U32_HITS.load(Ordering::Relaxed), U32_MISSES.load(Ordering::Relaxed))
}

/// RAII `u32` scratch buffer: derefs to `Vec<u32>`, returns itself to
/// the current thread's shelf (overflow: the shared pool) on drop.
pub struct ScratchU32 {
    buf: Vec<u32>,
}

impl Drop for ScratchU32 {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_U32_LEN {
            return;
        }
        let overflow = U32_SHELF.with(|s| {
            let mut shelf = s.borrow_mut();
            if shelf.len() < U32_SHELF_MAX {
                shelf.push(buf);
                None
            } else {
                Some(buf)
            }
        });
        if let Some(buf) = overflow {
            let mut global = U32_GLOBAL.lock().unwrap_or_else(|p| p.into_inner());
            if global.len() < U32_GLOBAL_MAX {
                global.push(buf);
            }
        }
    }
}

impl std::ops::Deref for ScratchU32 {
    type Target = Vec<u32>;
    fn deref(&self) -> &Vec<u32> {
        &self.buf
    }
}

impl std::ops::DerefMut for ScratchU32 {
    fn deref_mut(&mut self) -> &mut Vec<u32> {
        &mut self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn private_pool_steady_state_has_zero_allocations() {
        // After the first (cold) take, every subsequent take of the
        // same or smaller size reuses the one buffer: exactly 1 miss.
        let pool = BufferPool::new(8);
        for _ in 0..100 {
            let mut b = pool.take(4096);
            b.extend_from_slice(&[1, 2, 3]);
            pool.put(b);
        }
        let st = pool.stats();
        assert_eq!(st.misses, 1, "steady state must not allocate");
        assert_eq!(st.hits, 99);
        assert!(st.hit_rate() > 0.98);
    }

    #[test]
    fn buffers_grow_to_high_water_mark() {
        let pool = BufferPool::new(8);
        let b = pool.take(100);
        pool.put(b);
        let b = pool.take(100_000); // same buffer, grown
        assert!(b.capacity() >= 100_000);
        pool.put(b);
        let b = pool.take(50); // stays at high-water capacity
        assert!(b.capacity() >= 100_000);
        assert_eq!(pool.stats().misses, 1);
    }

    #[test]
    fn oversized_and_empty_buffers_are_not_pooled() {
        let pool = BufferPool::new(8);
        pool.put(Vec::new());
        pool.put(Vec::with_capacity(MAX_POOLED_CAPACITY + 1));
        let _ = pool.take(16);
        assert_eq!(pool.stats().misses, 1, "nothing should have been pooled");
    }

    #[test]
    fn pool_is_bounded() {
        let pool = BufferPool::new(2);
        for _ in 0..10 {
            pool.put(Vec::with_capacity(64));
        }
        assert_eq!(pool.stack.lock().unwrap().len(), 2);
    }

    #[test]
    fn high_water_evicts_coldest_first() {
        // Byte high-water of 1000: three 400-capacity buffers exceed
        // it, so admitting the third evicts the coldest (first-pooled).
        let pool = BufferPool::with_limits(8, 1000);
        pool.put(Vec::with_capacity(400));
        pool.put(Vec::with_capacity(400));
        assert_eq!(pool.stats().resident_bytes, 800);
        pool.put(Vec::with_capacity(400));
        let st = pool.stats();
        assert_eq!(st.evictions, 1, "coldest buffer evicted for the newcomer");
        assert_eq!(st.drops, 0);
        assert_eq!(st.resident_bytes, 800);
        assert!(st.resident_bytes <= 1000, "resident stays under the high-water");
    }

    #[test]
    fn newcomer_larger_than_budget_is_dropped_without_evicting() {
        let pool = BufferPool::with_limits(8, 100);
        pool.put(Vec::with_capacity(64));
        // 200 > the whole byte budget: no amount of eviction could
        // admit it — dropped upfront, the working set stays resident.
        pool.put(Vec::with_capacity(200));
        let st = pool.stats();
        assert_eq!(st.drops, 1);
        assert_eq!(st.evictions, 0, "infeasible newcomer must not evict");
        assert_eq!(st.resident_bytes, 64);
    }

    #[test]
    fn set_limits_shrinks_the_pool() {
        let pool = BufferPool::with_limits(8, usize::MAX);
        for _ in 0..6 {
            pool.put(Vec::with_capacity(100));
        }
        assert_eq!(pool.stats().resident_bytes, 600);
        pool.set_limits(8, 250);
        let st = pool.stats();
        assert!(st.resident_bytes <= 250, "evicted down to the new high-water");
        assert_eq!(st.evictions, 4);
    }

    #[test]
    fn writer_reservation_scales_and_releases() {
        // Global counters: other tests may register writers too, so
        // assert only the delta produced by this balanced pair.
        let before = registered_writers();
        reserve_writer();
        assert!(registered_writers() >= before + 1);
        release_writer();
        // take/put still works through a resize
        let b = GLOBAL.take(1024);
        GLOBAL.put(b);
    }

    #[test]
    fn thread_local_shelf_guarantees_hits_single_threaded() {
        // The shelf is per-thread, so no concurrent test can steal our
        // warm buffers: after warm-up, hits must grow by >= our reuse
        // count (other threads can only add to the global counters).
        {
            let _warm = (get(1024), get(1024)); // populate the shelf
        }
        let before = stats().hits;
        for _ in 0..50 {
            let a = get(512);
            let b = get(512);
            drop(a);
            drop(b);
        }
        let after = stats().hits;
        assert!(
            after - before >= 100,
            "expected >= 100 shelf hits, got {}",
            after - before
        );
    }

    #[test]
    fn u32_pool_reuses_buffers_and_refills() {
        // Warm the shelf, then every get must be a hit (the shelf is
        // per-thread so concurrent tests cannot steal our buffers),
        // and the returned contents must be exactly len × fill even
        // after a larger previous use left stale entries behind.
        {
            let _warm = pool_pair();
        }
        let (h0, _) = u32_stats();
        for round in 0..20 {
            let a = get_u32(1 << 10, u32::MAX);
            assert_eq!(a.len(), 1 << 10);
            assert!(a.iter().all(|&v| v == u32::MAX), "round {round}");
            let b = get_u32(100, 7);
            assert_eq!(&b[..], &[7u32; 100][..]);
        }
        let (h1, _) = u32_stats();
        assert!(h1 - h0 >= 40, "expected >= 40 shelf hits, got {}", h1 - h0);
    }

    fn pool_pair() -> (ScratchU32, ScratchU32) {
        (get_u32(1 << 12, u32::MAX), get_u32(1 << 12, u32::MAX))
    }

    #[test]
    fn scratch_derefs_like_a_vec() {
        let mut s = get(8);
        s.extend_from_slice(b"hello");
        assert_eq!(&s[..], b"hello");
        assert_eq!(s.len(), 5);
        s.clear();
        assert!(s.is_empty());
    }
}
