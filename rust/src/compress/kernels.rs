//! Word-wide (SWAR) inner-loop kernels shared by the codecs.
//!
//! The LZ match-extension loop is the single hottest scalar loop in
//! both [`super::lz4r`] and [`super::rzip`]: every candidate probe
//! compares the source against its back-reference byte by byte. Here
//! it runs slice-at-a-time — one unaligned `u64` load per side, XOR,
//! and `trailing_zeros` to locate the first differing byte — which is
//! 4–8× fewer loads and branches on typical match lengths.
//!
//! Every wide kernel keeps its scalar twin `pub` so differential tests
//! (and the fig8 microbenchmark) can pin **byte-identical** results:
//! the wide path must return exactly the same length for every input,
//! therefore the same token stream, therefore the same stored bytes.
//! On targets without cheap unaligned 64-bit loads
//! (`target_pointer_width != "64"`) the dispatching entry point simply
//! is the scalar path.

/// Length of the common prefix of `src[a..]` and `src[b..]`, scanning
/// while `b + len < end`. Callers pass `a < b <= end <= src.len()`.
/// Scalar reference implementation — the semantics the wide kernel
/// must reproduce exactly.
#[inline]
pub fn common_prefix_scalar(src: &[u8], a: usize, b: usize, end: usize) -> usize {
    let mut len = 0usize;
    while b + len < end && src[a + len] == src[b + len] {
        len += 1;
    }
    len
}

/// Word-wide common-prefix scan: compare 8 bytes per iteration with
/// one XOR; `trailing_zeros() / 8` finds the first mismatching byte
/// (the loads are little-endian, so low bytes are earlier positions).
/// Returns exactly what [`common_prefix_scalar`] returns.
#[cfg(target_pointer_width = "64")]
#[inline]
pub fn common_prefix_wide(src: &[u8], a: usize, b: usize, end: usize) -> usize {
    let mut len = 0usize;
    // Both loads must stay in bounds: the `a` side needs a+len+8 <= end
    // too (a < b, so the b bound is the tighter one only for b).
    while b + len + 8 <= end {
        let wa = u64::from_le_bytes(src[a + len..a + len + 8].try_into().unwrap());
        let wb = u64::from_le_bytes(src[b + len..b + len + 8].try_into().unwrap());
        let x = wa ^ wb;
        if x != 0 {
            return len + (x.trailing_zeros() / 8) as usize;
        }
        len += 8;
    }
    while b + len < end && src[a + len] == src[b + len] {
        len += 1;
    }
    len
}

/// Dispatching entry point: wide on 64-bit targets, scalar elsewhere.
#[inline]
pub fn common_prefix(src: &[u8], a: usize, b: usize, end: usize) -> usize {
    #[cfg(target_pointer_width = "64")]
    {
        common_prefix_wide(src, a, b, end)
    }
    #[cfg(not(target_pointer_width = "64"))]
    {
        common_prefix_scalar(src, a, b, end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift_bytes(n: usize, mut x: u32) -> Vec<u8> {
        (0..n)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect()
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn wide_matches_scalar_on_random_pairs() {
        let mut data = xorshift_bytes(4096, 0xC0FFEE);
        // Plant long repeats so matches of every length class occur.
        for rep in [3usize, 7, 8, 9, 15, 16, 17, 31, 64, 200] {
            let start = rep * 37 % 2000;
            let (head, tail) = data.split_at_mut(start + rep);
            tail[..rep].copy_from_slice(&head[start..start + rep]);
        }
        let n = data.len();
        let mut x = 0x1234_5678u32;
        for _ in 0..20_000 {
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            let a = (x as usize) % (n - 1);
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            let b = a + 1 + (x as usize) % (n - a - 1);
            assert_eq!(
                common_prefix_wide(&data, a, b, n),
                common_prefix_scalar(&data, a, b, n),
                "a={a} b={b}"
            );
        }
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn wide_matches_scalar_at_boundaries() {
        // Identical halves: the match runs into `end` at every length
        // around the 8-byte stride, including len 0 and len = end - b.
        for total in [2usize, 7, 8, 9, 15, 16, 17, 24, 31, 40] {
            let half: Vec<u8> = (0..total).map(|i| (i * 11 + 3) as u8).collect();
            let mut data = half.clone();
            data.extend_from_slice(&half);
            for end in total..=data.len() {
                assert_eq!(
                    common_prefix_wide(&data, 0, total, end),
                    common_prefix_scalar(&data, 0, total, end),
                    "total={total} end={end}"
                );
            }
        }
    }

    #[test]
    fn overlapping_ranges_agree() {
        // a and b overlap (b - a < match length): the RLE case.
        let data = vec![9u8; 300];
        for b in 1..40 {
            assert_eq!(common_prefix(&data, 0, b, data.len()), data.len() - b);
            assert_eq!(common_prefix_scalar(&data, 0, b, data.len()), data.len() - b);
        }
    }
}
