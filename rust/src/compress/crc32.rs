//! CRC-32 (IEEE 802.3, the zlib polynomial), table-driven.
//!
//! ROOT protects each key payload with a checksum; we do the same for
//! every `RNTF` record. Built from scratch — no external crates.
//!
//! Two table-driven widths share one table set:
//! * **slicing-by-8** (the default on 64-bit targets): one 8-byte load
//!   per iteration folded through eight 256-entry tables — two
//!   independent 4-table XOR trees per word, so the CPU overlaps them;
//! * **slicing-by-4** ([`crc32_update_scalar`], also the fallback on
//!   narrow targets): the previous implementation, kept `pub` as the
//!   differential reference for tests and the fig8 microbenchmark.
//!
//! Both produce bit-identical CRCs (it is the same polynomial walked in
//! different strides); the differential tests pin that.

/// Slicing-by-eight tables, generated at first use. The first four
/// are exactly the slicing-by-4 tables, so the scalar path reuses them.
struct Tables {
    t: [[u32; 256]; 8],
}

static TABLES: std::sync::OnceLock<Tables> = std::sync::OnceLock::new();

fn tables() -> &'static Tables {
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i as usize] = c;
        }
        for k in 1..8 {
            for i in 0..256 {
                t[k][i] = (t[k - 1][i] >> 8) ^ t[0][(t[k - 1][i] & 0xFF) as usize];
            }
        }
        Tables { t }
    })
}

/// CRC-32 of `data` (init/final xor 0xFFFFFFFF, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

/// Streaming update; feed `state = 0xFFFFFFFF` first, xor at the end.
/// Dispatches to slicing-by-8 on 64-bit targets.
pub fn crc32_update(state: u32, data: &[u8]) -> u32 {
    #[cfg(target_pointer_width = "64")]
    {
        crc32_update_by8(state, data)
    }
    #[cfg(not(target_pointer_width = "64"))]
    {
        crc32_update_scalar(state, data)
    }
}

/// Slicing-by-8: fold one little-endian `u64` per iteration. The low
/// word (state-xored) walks tables 7..4, the high word tables 3..0 —
/// two independent dependency chains the CPU executes in parallel.
#[cfg(target_pointer_width = "64")]
pub fn crc32_update_by8(mut state: u32, data: &[u8]) -> u32 {
    let t = &tables().t;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        let lo = (w as u32) ^ state;
        let hi = (w >> 32) as u32;
        state = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        state = t[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// Slicing-by-4 reference implementation (the pre-vectorised update),
/// kept public so differential tests and the fig8 microbenchmark can
/// pin the wide path against it.
pub fn crc32_update_scalar(mut state: u32, data: &[u8]) -> u32 {
    let t = &tables().t;
    let mut chunks = data.chunks_exact(4);
    for c in &mut chunks {
        state ^= u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        state = t[3][(state & 0xFF) as usize]
            ^ t[2][((state >> 8) & 0xFF) as usize]
            ^ t[1][((state >> 16) & 0xFF) as usize]
            ^ t[0][(state >> 24) as usize];
    }
    for &b in chunks.remainder() {
        state = t[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 1) as u8).collect();
        let oneshot = crc32(&data);
        let mut st = 0xFFFF_FFFFu32;
        for chunk in data.chunks(97) {
            st = crc32_update(st, chunk);
        }
        assert_eq!(st ^ 0xFFFF_FFFF, oneshot);
    }

    #[test]
    fn by8_matches_scalar_every_length_and_phase() {
        // Differential: the slicing-by-8 path must equal the by-4
        // reference for every tail length (0..=23 covers all phases of
        // both strides) and from varied starting states.
        let mut x = 0x2545_F491u32;
        let data: Vec<u8> = (0..1024)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        for n in (0..24).chain([63, 64, 65, 255, 1024]) {
            for seed in [0u32, 0xFFFF_FFFF, 0xDEAD_BEEF] {
                assert_eq!(
                    crc32_update(seed, &data[..n]),
                    crc32_update_scalar(seed, &data[..n]),
                    "len {n} seed {seed:#x}"
                );
            }
        }
    }

    #[test]
    fn unaligned_tails() {
        for n in 0..16 {
            let data: Vec<u8> = (0..n).map(|i| i as u8).collect();
            // bytewise reference implementation
            let want = {
                let mut st = 0xFFFF_FFFFu32;
                for &b in &data {
                    let mut x = (st ^ b as u32) & 0xFF;
                    for _ in 0..8 {
                        x = if x & 1 != 0 { 0xEDB8_8320 ^ (x >> 1) } else { x >> 1 };
                    }
                    st = (st >> 8) ^ x;
                }
                st ^ 0xFFFF_FFFF
            };
            assert_eq!(crc32(&data), want, "len {n}");
        }
    }
}
