//! CRC-32 (IEEE 802.3, the zlib polynomial), table-driven.
//!
//! ROOT protects each key payload with a checksum; we do the same for
//! every `RNTF` record. Built from scratch — no external crates.

/// Slicing-by-four tables, generated at first use.
struct Tables {
    t: [[u32; 256]; 4],
}

static TABLES: std::sync::OnceLock<Tables> = std::sync::OnceLock::new();

fn tables() -> &'static Tables {
    TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 4];
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i as usize] = c;
        }
        for i in 0..256 {
            t[1][i] = (t[0][i] >> 8) ^ t[0][(t[0][i] & 0xFF) as usize];
            t[2][i] = (t[1][i] >> 8) ^ t[0][(t[1][i] & 0xFF) as usize];
            t[3][i] = (t[2][i] >> 8) ^ t[0][(t[2][i] & 0xFF) as usize];
        }
        Tables { t }
    })
}

/// CRC-32 of `data` (init/final xor 0xFFFFFFFF, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

/// Streaming update; feed `state = 0xFFFFFFFF` first, xor at the end.
pub fn crc32_update(mut state: u32, data: &[u8]) -> u32 {
    let t = &tables().t;
    let mut chunks = data.chunks_exact(4);
    for c in &mut chunks {
        state ^= u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        state = t[3][(state & 0xFF) as usize]
            ^ t[2][((state >> 8) & 0xFF) as usize]
            ^ t[1][((state >> 16) & 0xFF) as usize]
            ^ t[0][(state >> 24) as usize];
    }
    for &b in chunks.remainder() {
        state = t[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Canonical check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 1) as u8).collect();
        let oneshot = crc32(&data);
        let mut st = 0xFFFF_FFFFu32;
        for chunk in data.chunks(97) {
            st = crc32_update(st, chunk);
        }
        assert_eq!(st ^ 0xFFFF_FFFF, oneshot);
    }

    #[test]
    fn unaligned_tails() {
        for n in 0..16 {
            let data: Vec<u8> = (0..n).map(|i| i as u8).collect();
            // consistency against bytewise reference
            let mut c = 0xFFFF_FFFFu32;
            for &b in &data {
                c = {
                    let mut x = c ^ b as u32;
                    for _ in 0..8 {
                        x = if x & 1 != 0 { 0xEDB8_8320 ^ (x >> 1) } else { x >> 1 };
                    }
                    (c >> 8) ^ x
                };
            }
            // the loop above is a bitwise reference impl of one table step
            let want = {
                let mut st = 0xFFFF_FFFFu32;
                for &b in &data {
                    let mut x = (st ^ b as u32) & 0xFF;
                    for _ in 0..8 {
                        x = if x & 1 != 0 { 0xEDB8_8320 ^ (x >> 1) } else { x >> 1 };
                    }
                    st = (st >> 8) ^ x;
                }
                st ^ 0xFFFF_FFFF
            };
            assert_eq!(crc32(&data), want, "len {n}");
        }
    }
}
