//! `Lz4r`: a from-scratch LZ4-style byte-aligned codec.
//!
//! This is the "fast, light" point in the paper's codec trade-off
//! (ROOT's LZ4 backend): greedy hash-table matching, byte-aligned token
//! stream, no entropy stage. Compression and decompression are both
//! memory-bandwidth-bound, an order of magnitude faster than [`super::rzip`]
//! at a worse ratio.
//!
//! Token stream (own format, both ends controlled here):
//! ```text
//! token := (lit_len:4 | match_code:4)
//! lit_len   15 => extension bytes (255-continuation)
//! literals  lit_len bytes
//! -- if input exhausted after literals, stream ends (no match part) --
//! offset    u16 LE, 1..=65535 back-reference distance
//! match_code 15 => extension bytes; match_len = match_code + 4
//! ```

use crate::error::{Error, Result};

use super::kernels;

const MIN_MATCH: usize = 4;
const HASH_LOG: usize = 14;
const HASH_SHIFT: u32 = 32 - HASH_LOG as u32;
const MAX_OFFSET: usize = 65535;

#[inline]
fn hash4(data: &[u8], pos: usize) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(2_654_435_761) >> HASH_SHIFT) as usize
}

#[inline]
fn write_len(out: &mut Vec<u8>, mut extra: usize) {
    while extra >= 255 {
        out.push(255);
        extra -= 255;
    }
    out.push(extra as u8);
}

/// Compress `src`. `effort` (1..=9) scales the match-search step
/// acceleration: higher effort = denser probing = better ratio.
/// Match extension runs word-wide (SWAR) on 64-bit targets; the token
/// stream is byte-identical to [`compress_scalar`] either way.
pub fn compress(src: &[u8], effort: u8) -> Vec<u8> {
    compress_impl::<true>(src, effort)
}

/// Scalar reference compressor: byte-at-a-time match extension. Kept
/// public so differential tests and the fig8 microbenchmark can pin
/// byte-identical output against the wide path.
pub fn compress_scalar(src: &[u8], effort: u8) -> Vec<u8> {
    compress_impl::<false>(src, effort)
}

fn compress_impl<const WIDE: bool>(src: &[u8], effort: u8) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    let n = src.len();
    if n < MIN_MATCH + 1 {
        emit_sequence(&mut out, src, None);
        return out;
    }

    // Acceleration: after `miss_budget` consecutive misses, start
    // skipping positions (LZ4-style). Higher effort = larger budget.
    let miss_budget = 1usize << (3 + effort.clamp(1, 9) as usize);

    let mut table = vec![0u32; 1 << HASH_LOG]; // pos + 1; 0 = empty
    let mut lit_start = 0usize;
    let mut pos = 0usize;
    let mut misses = 0usize;
    let limit = n - MIN_MATCH;

    while pos <= limit {
        let h = hash4(src, pos);
        let cand = table[h] as usize;
        table[h] = (pos + 1) as u32;
        if cand > 0 {
            let cpos = cand - 1;
            let off = pos - cpos;
            if off <= MAX_OFFSET && src[cpos..cpos + MIN_MATCH] == src[pos..pos + MIN_MATCH] {
                // Extend forward past the verified MIN_MATCH prefix.
                let ext = if WIDE {
                    kernels::common_prefix(src, cpos + MIN_MATCH, pos + MIN_MATCH, n)
                } else {
                    kernels::common_prefix_scalar(src, cpos + MIN_MATCH, pos + MIN_MATCH, n)
                };
                let len = MIN_MATCH + ext;
                emit_sequence(&mut out, &src[lit_start..pos], Some((off, len)));
                pos += len;
                lit_start = pos;
                misses = 0;
                continue;
            }
        }
        misses += 1;
        pos += 1 + misses / miss_budget;
    }
    emit_sequence(&mut out, &src[lit_start..], None);
    out
}

fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], m: Option<(usize, usize)>) {
    if literals.is_empty() && m.is_none() {
        return;
    }
    let lit_nibble = literals.len().min(15) as u8;
    let match_code = match m {
        Some((_, len)) => (len - MIN_MATCH).min(15) as u8,
        None => 0,
    };
    out.push((lit_nibble << 4) | match_code);
    if literals.len() >= 15 {
        write_len(out, literals.len() - 15);
    }
    out.extend_from_slice(literals);
    if let Some((off, mlen)) = m {
        debug_assert!(off >= 1 && off <= MAX_OFFSET);
        out.extend_from_slice(&(off as u16).to_le_bytes());
        if mlen - MIN_MATCH >= 15 {
            write_len(out, mlen - MIN_MATCH - 15);
        }
    }
}

/// Decompress exactly `dst_len` bytes, appending to `out`. Match
/// offsets are resolved relative to the start of this block's output
/// (`out` may already hold earlier blocks — the pooled-buffer path).
/// Overlapping matches copy word-wide (a doubling `extend_from_within`
/// cascade) instead of byte-at-a-time; output is byte-identical to
/// [`decompress_into_scalar`].
pub fn decompress_into(src: &[u8], dst_len: usize, out: &mut Vec<u8>) -> Result<()> {
    decompress_impl::<true>(src, dst_len, out)
}

/// Scalar reference decoder (byte-loop overlap copies), kept public
/// for differential tests and the fig8 microbenchmark.
pub fn decompress_into_scalar(src: &[u8], dst_len: usize, out: &mut Vec<u8>) -> Result<()> {
    decompress_impl::<false>(src, dst_len, out)
}

fn decompress_impl<const WIDE: bool>(
    src: &[u8],
    dst_len: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let base = out.len();
    out.reserve(dst_len);
    let mut pos = 0usize;
    let err = |m: &str| Error::Codec(format!("lz4r: {m}"));

    while out.len() - base < dst_len {
        if pos >= src.len() {
            return Err(err("truncated stream"));
        }
        let token = src[pos];
        pos += 1;
        // literals
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            loop {
                let b = *src.get(pos).ok_or_else(|| err("truncated litlen"))?;
                pos += 1;
                lit += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        if pos + lit > src.len() {
            return Err(err("literal overrun"));
        }
        out.extend_from_slice(&src[pos..pos + lit]);
        pos += lit;
        if pos == src.len() {
            break; // final literal-only sequence
        }
        // match
        if pos + 2 > src.len() {
            return Err(err("truncated offset"));
        }
        let off = u16::from_le_bytes([src[pos], src[pos + 1]]) as usize;
        pos += 2;
        if off == 0 || off > out.len() - base {
            return Err(err("bad offset"));
        }
        let mut mlen = (token & 0x0F) as usize;
        if mlen == 15 {
            loop {
                let b = *src.get(pos).ok_or_else(|| err("truncated matchlen"))?;
                pos += 1;
                mlen += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let mlen = mlen + MIN_MATCH;
        let start = out.len() - off;
        if off >= mlen {
            // non-overlapping: one memcpy (§Perf L3 iteration 4)
            out.extend_from_within(start..start + mlen);
        } else if WIDE {
            // Overlapping (off < mlen): doubling cascade. Each round
            // copies the whole span available so far from `start`; the
            // copied region is periodic with period `off` and every
            // round starts at a multiple of the period, so the result
            // is byte-identical to the scalar byte loop in O(log)
            // memcpys instead of `mlen` single-byte pushes.
            let mut remaining = mlen;
            while remaining > 0 {
                let avail = out.len() - start;
                let k = avail.min(remaining);
                out.extend_from_within(start..start + k);
                remaining -= k;
            }
        } else {
            // overlapping copy (off < mlen), byte-by-byte semantics
            for i in 0..mlen {
                let b = out[start + i];
                out.push(b);
            }
        }
    }

    if out.len() - base != dst_len {
        return Err(err(&format!(
            "size mismatch: got {}, want {}",
            out.len() - base,
            dst_len
        )));
    }
    Ok(())
}

/// Decompress into exactly `dst_len` bytes.
pub fn decompress(src: &[u8], dst_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(dst_len);
    decompress_into(src, dst_len, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8], effort: u8) {
        let c = compress(data, effort);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn empty_and_tiny() {
        for data in [&b""[..], b"a", b"ab", b"abcd", b"abcde"] {
            roundtrip(data, 5);
        }
    }

    #[test]
    fn highly_compressible() {
        let data = vec![42u8; 100_000];
        let c = compress(&data, 5);
        assert!(c.len() < data.len() / 50, "ratio too poor: {}", c.len());
        roundtrip(&data, 5);
    }

    #[test]
    fn repeating_pattern() {
        let data: Vec<u8> = b"the quick brown fox ".iter().cycle().take(50_000).copied().collect();
        let c = compress(&data, 5);
        assert!(c.len() < data.len() / 10);
        roundtrip(&data, 5);
    }

    #[test]
    fn incompressible_random() {
        // xorshift-ish stream: should stay ~1:1, must still roundtrip
        let mut x = 0x12345678u32;
        let data: Vec<u8> = (0..65_536)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        let c = compress(&data, 9);
        assert!(c.len() <= data.len() + data.len() / 128 + 64);
        roundtrip(&data, 9);
    }

    #[test]
    fn overlapping_matches() {
        // "aaaa..." forces offset-1 overlapping copies
        let mut data = vec![b'a'; 1000];
        data.extend_from_slice(b"bcd");
        data.extend(vec![b'a'; 500]);
        roundtrip(&data, 5);
    }

    #[test]
    fn all_efforts_roundtrip() {
        let data: Vec<u8> =
            (0..30_000u32).flat_map(|i| ((i % 1000) as u16).to_be_bytes()).collect();
        for e in 1..=9 {
            roundtrip(&data, e);
        }
    }

    #[test]
    fn corrupt_input_is_an_error_not_a_panic() {
        let data = b"hello world hello world hello world".repeat(100);
        let mut c = compress(&data, 5);
        // Truncate and mangle.
        c.truncate(c.len() / 2);
        assert!(decompress(&c, data.len()).is_err());
        assert!(decompress(&[], 10).is_err());
        // bad offset: token demanding a match with no history
        assert!(decompress(&[0x01, b'x', 0xFF, 0xFF, 0x00], 100).is_err());
    }

    #[test]
    fn wide_paths_are_byte_identical_to_scalar() {
        // Differential: SWAR match extension must emit the exact token
        // stream of the scalar reference, and the doubling overlap
        // copy must decode to the exact bytes of the byte loop —
        // across adversarial shapes (empty, tiny, incompressible,
        // highly repetitive, mixed).
        let mut x = 0xA5A5_0001u32;
        let random: Vec<u8> = (0..20_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        let mut mixed = b"header".to_vec();
        mixed.extend(vec![7u8; 3000]); // RLE: offset-1 overlap copies
        mixed.extend_from_slice(&random[..2000]);
        mixed.extend(b"abcdefgh".repeat(400)); // period-8 overlap
        mixed.extend_from_slice(&mixed.clone()[..4000]); // far back-refs
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"abcd".to_vec(),
            vec![0u8; 65_000],
            random.clone(),
            b"the quick brown fox ".repeat(800).to_vec(),
            mixed,
        ];
        for (i, data) in cases.iter().enumerate() {
            for effort in [1u8, 5, 9] {
                let wide = compress(data, effort);
                let scalar = compress_scalar(data, effort);
                assert_eq!(wide, scalar, "case {i} effort {effort}: tokens diverged");
                let mut dw = Vec::new();
                decompress_into(&wide, data.len(), &mut dw).unwrap();
                let mut ds = Vec::new();
                decompress_into_scalar(&wide, data.len(), &mut ds).unwrap();
                assert_eq!(dw, ds, "case {i}: decode diverged");
                assert_eq!(&dw, data, "case {i}: roundtrip broke");
            }
        }
    }

    #[test]
    fn long_matches_cross_extension_boundary() {
        // match length around 15+255 boundaries
        for extra in [14, 15, 16, 269, 270, 271, 600] {
            let mut data = b"0123456789abcdef".to_vec();
            let rep: Vec<u8> = data.iter().cycle().take(MIN_MATCH + extra).copied().collect();
            data.extend_from_slice(&rep);
            roundtrip(&data, 5);
        }
    }
}
