//! Canonical Huffman coding for the `Rzip` codec.
//!
//! Codes are length-limited to [`MAX_BITS`] (15, as in deflate) by
//! halving frequencies and rebuilding when the tree grows too deep; the
//! canonical assignment means only the code *lengths* need to be stored
//! in the block header.

use crate::error::{Error, Result};

use super::bitstream::{BitReader, BitWriter};

pub const MAX_BITS: u32 = 15;

/// Encoder table: per-symbol (code, length). Length 0 = symbol unused.
#[derive(Clone)]
pub struct Encoder {
    pub lengths: Vec<u8>,
    codes: Vec<u16>,
}

/// Build optimal length-limited code lengths for `freqs`.
///
/// Standard two-queue Huffman over a scratch heap; if the deepest leaf
/// exceeds `MAX_BITS`, halve all frequencies (keeping nonzero alive) and
/// rebuild — converges quickly and costs at most a fraction of a percent
/// of compression ratio.
pub fn build_lengths(freqs: &[u64]) -> Vec<u8> {
    let n = freqs.len();
    let mut f: Vec<u64> = freqs.to_vec();
    loop {
        let lengths = build_lengths_once(&f);
        let maxlen = lengths.iter().copied().max().unwrap_or(0);
        if maxlen as u32 <= MAX_BITS {
            return lengths;
        }
        for v in f.iter_mut().take(n) {
            if *v > 0 {
                *v = (*v + 1) / 2;
            }
        }
    }
}

fn build_lengths_once(freqs: &[u64]) -> Vec<u8> {
    let n = freqs.len();
    let mut lengths = vec![0u8; n];
    let live: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match live.len() {
        0 => return lengths,
        1 => {
            // A single symbol still needs one bit on the wire.
            lengths[live[0]] = 1;
            return lengths;
        }
        _ => {}
    }

    // Node arena: leaves then internals; parent pointers give depths.
    #[derive(Clone, Copy)]
    struct Node {
        freq: u64,
        parent: usize,
    }
    let mut nodes: Vec<Node> =
        live.iter().map(|&i| Node { freq: freqs[i], parent: usize::MAX }).collect();

    // Min-heap of (freq, node index); ties broken by index for determinism.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        nodes.iter().enumerate().map(|(i, nd)| Reverse((nd.freq, i))).collect();

    while heap.len() > 1 {
        let Reverse((fa, a)) = heap.pop().unwrap();
        let Reverse((fb, b)) = heap.pop().unwrap();
        let id = nodes.len();
        nodes.push(Node { freq: fa + fb, parent: usize::MAX });
        nodes[a].parent = id;
        nodes[b].parent = id;
        heap.push(Reverse((fa + fb, id)));
    }

    for (leaf, &sym) in live.iter().enumerate() {
        let mut depth = 0u8;
        let mut cur = leaf;
        while nodes[cur].parent != usize::MAX {
            cur = nodes[cur].parent;
            depth += 1;
        }
        lengths[sym] = depth;
    }
    lengths
}

/// Assign canonical codes for `lengths` (shorter codes first, then by
/// symbol order), LSB-first bit-reversed so they can be written with the
/// LSB-first bitstream.
fn canonical_codes(lengths: &[u8]) -> Result<Vec<u16>> {
    let mut bl_count = [0u32; (MAX_BITS + 1) as usize];
    for &l in lengths {
        if l as u32 > MAX_BITS {
            return Err(Error::Codec(format!("code length {l} > {MAX_BITS}")));
        }
        bl_count[l as usize] += 1;
    }
    bl_count[0] = 0;
    let mut next_code = [0u16; (MAX_BITS + 2) as usize];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS as usize {
        code = (code + bl_count[bits - 1]) << 1;
        if code > (1 << bits) && bl_count[bits] > 0 {
            return Err(Error::Codec("over-subscribed code".into()));
        }
        next_code[bits] = code as u16;
    }
    let mut codes = vec![0u16; lengths.len()];
    for (sym, &l) in lengths.iter().enumerate() {
        if l > 0 {
            let c = next_code[l as usize];
            next_code[l as usize] += 1;
            // bit-reverse to LSB-first order
            codes[sym] = reverse_bits(c, l as u32);
        }
    }
    Ok(codes)
}

#[inline]
fn reverse_bits(v: u16, n: u32) -> u16 {
    let mut r = 0u16;
    let mut v = v;
    for _ in 0..n {
        r = (r << 1) | (v & 1);
        v >>= 1;
    }
    r
}

impl Encoder {
    pub fn from_freqs(freqs: &[u64]) -> Result<Self> {
        let lengths = build_lengths(freqs);
        let codes = canonical_codes(&lengths)?;
        Ok(Encoder { lengths, codes })
    }

    pub fn from_lengths(lengths: Vec<u8>) -> Result<Self> {
        let codes = canonical_codes(&lengths)?;
        Ok(Encoder { lengths, codes })
    }

    #[inline]
    pub fn emit(&self, w: &mut BitWriter, sym: usize) {
        debug_assert!(self.lengths[sym] > 0, "emitting unused symbol {sym}");
        w.put(self.codes[sym] as u32, self.lengths[sym] as u32);
    }

    /// Cost in bits of coding `sym`.
    #[inline]
    pub fn cost(&self, sym: usize) -> u32 {
        self.lengths[sym] as u32
    }
}

/// Decoder: a flat `(1 << max_len)`-entry lookup table mapping the next
/// `max_len` bits to (symbol, length) — one table load per symbol.
///
/// Perf note (EXPERIMENTS.md §Perf, L3 iteration 2): the table is sized
/// to the *actual* longest code of the block, not the 15-bit ceiling —
/// typical blocks top out at 11–13 bits, shrinking table construction
/// (the per-block fixed cost of decompression) by 4–16×.
pub struct Decoder {
    table: Vec<u32>, // (len << 16) | symbol
    peek_bits: u32,
}

impl Decoder {
    pub fn from_lengths(lengths: &[u8]) -> Result<Self> {
        let codes = canonical_codes(lengths)?;
        let max_len = lengths.iter().copied().max().unwrap_or(1).max(1) as u32;
        let mut table = vec![u32::MAX; 1 << max_len];
        for (sym, &l) in lengths.iter().enumerate() {
            if l == 0 {
                continue;
            }
            let l32 = l as u32;
            let code = codes[sym] as usize; // already LSB-first
            let step = 1usize << l32;
            let mut idx = code;
            while idx < table.len() {
                table[idx] = (l32 << 16) | sym as u32;
                idx += step;
            }
        }
        Ok(Decoder { table, peek_bits: max_len })
    }

    /// Decode one symbol.
    #[inline]
    pub fn read(&self, r: &mut BitReader<'_>) -> Result<usize> {
        let bits = r.peek(self.peek_bits);
        let entry = self.table[bits as usize];
        if entry == u32::MAX {
            return Err(Error::Codec("invalid huffman code".into()));
        }
        r.skip(entry >> 16);
        Ok((entry & 0xFFFF) as usize)
    }

    /// Bits one table lookup consumes at most — the budget a batched
    /// caller must have buffered before [`Decoder::read_buffered`].
    #[inline]
    pub fn peek_bits(&self) -> u32 {
        self.peek_bits
    }

    /// Decode one symbol without the refill check: the caller
    /// guarantees `r.buffered() >= self.peek_bits()` (one
    /// [`BitReader::refill`] covers several ≤15-bit codes, the batched
    /// multi-symbol fast path of the rzip decoder). Byte-identical to
    /// [`Decoder::read`] — only the refill bookkeeping differs.
    #[inline]
    pub fn read_buffered(&self, r: &mut BitReader<'_>) -> Result<usize> {
        let bits = r.peek_buffered(self.peek_bits);
        let entry = self.table[bits as usize];
        if entry == u32::MAX {
            return Err(Error::Codec("invalid huffman code".into()));
        }
        r.skip(entry >> 16);
        Ok((entry & 0xFFFF) as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(freqs: &[u64], stream: &[usize]) {
        let enc = Encoder::from_freqs(freqs).unwrap();
        let mut w = BitWriter::new();
        for &s in stream {
            enc.emit(&mut w, s);
        }
        let bytes = w.finish();
        let dec = Decoder::from_lengths(&enc.lengths).unwrap();
        let mut r = BitReader::new(&bytes);
        for &s in stream {
            assert_eq!(dec.read(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn skewed_alphabet() {
        let mut freqs = vec![0u64; 8];
        freqs[0] = 1000;
        freqs[1] = 200;
        freqs[2] = 50;
        freqs[3] = 1;
        let stream: Vec<usize> = (0..500).map(|i| [0, 0, 0, 1, 0, 2, 0, 3][i % 8]).collect();
        roundtrip(&freqs, &stream);
    }

    #[test]
    fn single_symbol() {
        let mut freqs = vec![0u64; 4];
        freqs[2] = 42;
        roundtrip(&freqs, &[2; 100]);
    }

    #[test]
    fn uniform_256() {
        let freqs = vec![7u64; 256];
        let stream: Vec<usize> = (0..2048).map(|i| (i * 37) % 256).collect();
        roundtrip(&freqs, &stream);
    }

    #[test]
    fn length_limiting_kicks_in() {
        // Fibonacci-like frequencies force depth > 15 without limiting.
        let mut freqs = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lengths = build_lengths(&freqs);
        assert!(lengths.iter().all(|&l| l as u32 <= MAX_BITS));
        let stream: Vec<usize> = (0..200).map(|i| i % 40).collect();
        roundtrip(&freqs, &stream);
    }

    #[test]
    fn shorter_codes_for_hotter_symbols() {
        let freqs = vec![1000u64, 10, 10, 10];
        let enc = Encoder::from_freqs(&freqs).unwrap();
        assert!(enc.lengths[0] <= enc.lengths[1]);
    }

    #[test]
    fn kraft_inequality_holds() {
        let freqs: Vec<u64> = (1..100).map(|i| (i * i) as u64).collect();
        let lengths = build_lengths(&freqs);
        let kraft: f64 =
            lengths.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-9, "kraft = {kraft}");
    }
}
