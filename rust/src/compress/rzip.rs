//! `Rzip`: a from-scratch deflate-style codec — LZ77 with hash-chain
//! matching plus canonical-Huffman entropy coding.
//!
//! This is the "slow, dense" point in the paper's codec trade-off (ROOT's
//! default zlib backend, a.k.a. RZip). Like zlib, compression is much more
//! expensive than decompression and the cost scales with `level` — the
//! property behind the paper's Figure 6 observation that "when writing out
//! compressed data, the CPU becomes the bottleneck due to the cost of
//! compression".
//!
//! Stream layout (the container stores compressed/uncompressed sizes):
//! ```text
//! u16 LE  lit/len alphabet size   (<= LIT_ALPHABET)
//! u16 LE  distance alphabet size  (<= DIST_ALPHABET)
//! u8  * n code lengths, both alphabets
//! bits    huffman-coded tokens, terminated by EOB
//! ```
//! Match lengths and distances use a two-bit-mantissa bucket scheme
//! (`bucket`): value -> (code, extra-bits), as in zstd/brotli.

use crate::error::{Error, Result};

use super::bitstream::{BitReader, BitWriter};
use super::huffman::{Decoder, Encoder};
use super::{kernels, pool};

pub const MIN_MATCH: usize = 4;
const MAX_DIST: usize = (1 << 22) - 1;
const EOB: usize = 256;
/// 256 literals + EOB + up to 48 length-bucket codes.
const LIT_ALPHABET: usize = 256 + 1 + 48;
const DIST_ALPHABET: usize = 48;
/// Ceiling on the hash-table size; actual size adapts to the input
/// (see [`hash_log_for`]).
const MAX_HASH_LOG: u32 = 17;
const MIN_HASH_LOG: u32 = 10;

/// Hash-table size for an `n`-byte input: roughly the next power of two
/// above `n`, clamped to `[2^10, 2^17]` entries. A pure function of the
/// input length, so the wide and scalar compressors — and repeated runs
/// — always walk identical chains (determinism). Before this, every
/// call paid for a fixed 512 KB (`1 << 17` entries) table; a 4 KB
/// basket now touches a 4 KB table instead.
#[inline]
fn hash_log_for(n: usize) -> u32 {
    let bits = usize::BITS - n.max(1).leading_zeros();
    bits.clamp(MIN_HASH_LOG, MAX_HASH_LOG)
}

/// value -> (bucket code, number of extra bits, extra bits payload)
#[inline]
fn bucket(v: u32) -> (usize, u32, u32) {
    if v < 4 {
        (v as usize, 0, 0)
    } else {
        let k = 31 - v.leading_zeros();
        let nbits = k - 1;
        let top = (v >> nbits) & 1;
        let code = (2 * k + top) as usize;
        (code, nbits, v & ((1 << nbits) - 1))
    }
}

/// Inverse of [`bucket`]: (code, extra payload) -> value.
#[inline]
fn unbucket(code: usize, extra: u32) -> u32 {
    if code < 4 {
        code as u32
    } else {
        let k = (code / 2) as u32;
        let top = (code & 1) as u32;
        let nbits = k - 1;
        (1 << k) + (top << nbits) + extra
    }
}

/// Extra-bit count for a bucket code (needed by the decoder).
#[inline]
fn bucket_bits(code: usize) -> u32 {
    if code < 4 {
        0
    } else {
        (code as u32 / 2) - 1
    }
}

#[derive(Clone, Copy)]
enum Token {
    Literal(u8),
    Match { len: u32, dist: u32 }, // len = mlen - MIN_MATCH, dist = d - 1
}

/// Chain-search depth per compression level (level 0 handled by caller).
fn chain_depth(level: u8) -> usize {
    match level.clamp(1, 9) {
        1 => 1,
        2 => 4,
        3 => 8,
        4 => 16,
        5 => 24,
        6 => 32,
        7 => 64,
        8 => 96,
        _ => 128,
    }
}

#[inline]
fn hash4(data: &[u8], pos: usize, shift: u32) -> usize {
    let v = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    (v.wrapping_mul(2_654_435_761) >> shift) as usize
}

/// LZ77 tokenisation with hash chains. `WIDE` selects the SWAR
/// match-length kernel; both variants emit identical token streams
/// (the kernel is byte-identical to the scalar loop, pinned by
/// differential tests here and in `kernels`).
fn tokenize<const WIDE: bool>(src: &[u8], level: u8) -> Vec<Token> {
    let n = src.len();
    let mut tokens = Vec::with_capacity(n / 3 + 8);
    if n < MIN_MATCH + 1 {
        tokens.extend(src.iter().map(|&b| Token::Literal(b)));
        return tokens;
    }
    let depth = chain_depth(level);
    // Miss acceleration (the LZ4 trick zlib lacks): after a run of
    // consecutive match misses, probe the chains less often. On
    // incompressible input this converts O(n·depth) probing into a
    // fast literal copy (the paper's "compressing random floats burns
    // CPU" regime stays CPU-bound, but at realistic zlib-like rates);
    // a hit resets the run so compressible data is unaffected.
    let accel = match level.clamp(1, 9) {
        1..=3 => 8usize,
        4..=6 => 16,
        _ => 64,
    };
    let mut misses = 0usize;
    let hash_log = hash_log_for(n);
    let shift = 32 - hash_log;
    // Pooled hash tables: recycled across calls so tiny baskets stop
    // paying a fixed allocation tax for the chain arrays.
    let mut head_scratch = pool::get_u32(1usize << hash_log, u32::MAX);
    let mut prev_scratch = pool::get_u32(n, u32::MAX);
    let head = &mut head_scratch[..];
    let prev = &mut prev_scratch[..];
    let limit = n - MIN_MATCH;
    let mut pos = 0usize;

    while pos < n {
        if pos > limit {
            tokens.push(Token::Literal(src[pos]));
            pos += 1;
            continue;
        }
        let h = hash4(src, pos, shift);
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        let mut cand = head[h];
        let mut probes = depth;
        while cand != u32::MAX && probes > 0 {
            let cpos = cand as usize;
            let dist = pos - cpos;
            if dist > MAX_DIST {
                break;
            }
            // Quick reject: match must beat best_len.
            if best_len == 0 || src.get(cpos + best_len) == src.get(pos + best_len) {
                let len = if WIDE {
                    kernels::common_prefix(src, cpos, pos, n)
                } else {
                    kernels::common_prefix_scalar(src, cpos, pos, n)
                };
                if len >= MIN_MATCH && len > best_len {
                    best_len = len;
                    best_dist = dist;
                }
            }
            cand = prev[cpos];
            probes -= 1;
        }
        if best_len >= MIN_MATCH {
            misses = 0;
            tokens.push(Token::Match {
                len: (best_len - MIN_MATCH) as u32,
                dist: (best_dist - 1) as u32,
            });
            // Insert every position of the match into the chains
            // (bounded so pathological inputs stay linear-ish).
            let insert_end = (pos + best_len).min(limit + 1).min(pos + 64);
            let mut p = pos;
            while p < insert_end {
                let hh = hash4(src, p, shift);
                prev[p] = head[hh];
                head[hh] = p as u32;
                p += 1;
            }
            pos += best_len;
        } else {
            prev[pos] = head[h];
            head[h] = pos as u32;
            misses += 1;
            // emit 1 + misses/accel literals without probing
            let step = (1 + misses / accel).min(n - pos);
            for i in 0..step {
                tokens.push(Token::Literal(src[pos + i]));
            }
            pos += step;
        }
    }
    tokens
}

/// Compress `src` at `level` (1..=9).
pub fn compress(src: &[u8], level: u8) -> Vec<u8> {
    compress_impl::<true>(src, level)
}

/// Scalar reference compressor — the pre-vectorised match loop, kept
/// public so differential tests and the fig8 microbenchmark can pin
/// the wide path against it. Output is byte-identical to
/// [`compress`].
pub fn compress_scalar(src: &[u8], level: u8) -> Vec<u8> {
    compress_impl::<false>(src, level)
}

fn compress_impl<const WIDE: bool>(src: &[u8], level: u8) -> Vec<u8> {
    let tokens = tokenize::<WIDE>(src, level);

    // Count symbol frequencies.
    let mut lit_freq = vec![0u64; LIT_ALPHABET];
    let mut dist_freq = vec![0u64; DIST_ALPHABET];
    for t in &tokens {
        match *t {
            Token::Literal(b) => lit_freq[b as usize] += 1,
            Token::Match { len, dist } => {
                let (lc, _, _) = bucket(len);
                lit_freq[257 + lc] += 1;
                let (dc, _, _) = bucket(dist);
                dist_freq[dc] += 1;
            }
        }
    }
    lit_freq[EOB] += 1;

    let lit_enc = Encoder::from_freqs(&lit_freq).expect("lit table");
    let dist_enc = Encoder::from_freqs(&dist_freq).expect("dist table");

    let mut out = Vec::with_capacity(src.len() / 2 + 512);
    out.extend_from_slice(&(LIT_ALPHABET as u16).to_le_bytes());
    out.extend_from_slice(&(DIST_ALPHABET as u16).to_le_bytes());
    out.extend_from_slice(&lit_enc.lengths);
    out.extend_from_slice(&dist_enc.lengths);

    let mut w = BitWriter::with_capacity(src.len() / 2);
    for t in &tokens {
        match *t {
            Token::Literal(b) => lit_enc.emit(&mut w, b as usize),
            Token::Match { len, dist } => {
                let (lc, lb, lx) = bucket(len);
                lit_enc.emit(&mut w, 257 + lc);
                if lb > 0 {
                    w.put(lx, lb);
                }
                let (dc, db, dx) = bucket(dist);
                dist_enc.emit(&mut w, dc);
                if db > 0 {
                    w.put(dx, db);
                }
            }
        }
    }
    lit_enc.emit(&mut w, EOB);
    out.extend_from_slice(&w.finish());
    out
}

/// Decompress exactly `dst_len` bytes, appending to `out`. Match
/// distances are resolved relative to the start of this block's output
/// (`out` may already hold earlier blocks — the pooled-buffer path).
pub fn decompress_into(src: &[u8], dst_len: usize, out: &mut Vec<u8>) -> Result<()> {
    decompress_impl::<true>(src, dst_len, out)
}

/// Scalar reference decoder — per-symbol refills and byte-at-a-time
/// overlap copies, kept public as the differential baseline for the
/// batched wide path. Output is byte-identical to
/// [`decompress_into`].
pub fn decompress_into_scalar(src: &[u8], dst_len: usize, out: &mut Vec<u8>) -> Result<()> {
    decompress_impl::<false>(src, dst_len, out)
}

fn decompress_impl<const WIDE: bool>(
    src: &[u8],
    dst_len: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let err = |m: &str| Error::Codec(format!("rzip: {m}"));
    if src.len() < 4 {
        return Err(err("truncated header"));
    }
    let n_lit = u16::from_le_bytes([src[0], src[1]]) as usize;
    let n_dist = u16::from_le_bytes([src[2], src[3]]) as usize;
    if n_lit > LIT_ALPHABET || n_lit <= EOB || n_dist > DIST_ALPHABET {
        return Err(err("bad alphabet sizes"));
    }
    let tbl_end = 4 + n_lit + n_dist;
    if src.len() < tbl_end {
        return Err(err("truncated code lengths"));
    }
    let lit_dec = Decoder::from_lengths(&src[4..4 + n_lit])?;
    let dist_dec = Decoder::from_lengths(&src[4 + n_lit..tbl_end])?;
    let lit_peek = lit_dec.peek_bits();

    let base = out.len();
    out.reserve(dst_len);
    let mut r = BitReader::new(&src[tbl_end..]);
    // Batched decode (WIDE): one `refill` tops the accumulator up to
    // ≥ 56 bits, which covers ⌊56/15⌋ = 3+ worst-case literal codes —
    // the inner loop then decodes literals with `read_buffered` (no
    // per-symbol refill branch) until the budget runs out. Extra
    // refills never change which bits each symbol consumes, so the
    // decoded stream is trivially identical to the scalar path.
    'outer: loop {
        if WIDE {
            r.refill();
        }
        loop {
            let sym = if WIDE && r.buffered() >= lit_peek {
                lit_dec.read_buffered(&mut r)?
            } else {
                lit_dec.read(&mut r)?
            };
            if sym < 256 {
                out.push(sym as u8);
                if out.len() - base > dst_len {
                    return Err(err("output overrun"));
                }
                if WIDE && r.buffered() < lit_peek {
                    continue 'outer;
                }
                continue;
            }
            if sym == EOB {
                break 'outer;
            }
            let lc = sym - 257;
            let lx = r.get(bucket_bits(lc));
            let mlen = unbucket(lc, lx) as usize + MIN_MATCH;
            let dc = dist_dec.read(&mut r)?;
            let dx = r.get(bucket_bits(dc));
            let dist = unbucket(dc, dx) as usize + 1;
            if dist > out.len() - base {
                return Err(err("bad distance"));
            }
            let start = out.len() - dist;
            if dist >= mlen {
                // non-overlapping: one memcpy (§Perf L3 iteration 4)
                out.extend_from_within(start..start + mlen);
            } else if WIDE {
                // Overlapping RLE-style match: double the copied span
                // each round (everything already appended is a valid
                // period-`dist` continuation), turning the byte loop
                // into O(log(mlen/dist)) memcpys. Byte-identical to
                // the scalar loop below.
                let mut remaining = mlen;
                while remaining > 0 {
                    let avail = out.len() - start;
                    let k = avail.min(remaining);
                    out.extend_from_within(start..start + k);
                    remaining -= k;
                }
            } else {
                for i in 0..mlen {
                    let b = out[start + i];
                    out.push(b);
                }
            }
            if out.len() - base > dst_len {
                return Err(err("output overrun"));
            }
            if WIDE {
                continue 'outer;
            }
        }
    }
    if out.len() - base != dst_len {
        return Err(err(&format!(
            "size mismatch: got {}, want {}",
            out.len() - base,
            dst_len
        )));
    }
    Ok(())
}

/// Decompress into exactly `dst_len` bytes.
pub fn decompress(src: &[u8], dst_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(dst_len);
    decompress_into(src, dst_len, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8], level: u8) -> usize {
        let c = compress(data, level);
        let d = decompress(&c, data.len()).unwrap();
        assert_eq!(d, data);
        c.len()
    }

    #[test]
    fn bucket_inverse() {
        for v in (0..100_000u32).step_by(7).chain([0, 1, 2, 3, 4, 5, 1 << 20]) {
            let (c, nb, x) = bucket(v);
            assert_eq!(bucket_bits(c), nb);
            assert_eq!(unbucket(c, x), v, "v={v}");
        }
    }

    #[test]
    fn empty_and_tiny() {
        for data in [&b""[..], b"x", b"xy", b"xyz", b"xyzw"] {
            roundtrip(data, 6);
        }
    }

    #[test]
    fn text_compresses_well() {
        let data = b"The ROOT I/O subsystem performs serialisation, compression \
                     and storage access; each phase can be parallelised. "
            .repeat(500);
        let c = roundtrip(&data, 6);
        assert!(c < data.len() / 10, "ratio {} / {}", c, data.len());
    }

    #[test]
    fn higher_level_no_worse_much() {
        let data: Vec<u8> = (0..60_000u32).flat_map(|i| ((i % 700) as u32).to_be_bytes()).collect();
        let c1 = roundtrip(&data, 1);
        let c9 = roundtrip(&data, 9);
        assert!(c9 as f64 <= c1 as f64 * 1.02, "c1={c1} c9={c9}");
    }

    #[test]
    fn random_roundtrips() {
        let mut x = 0xDEADBEEFu32;
        let data: Vec<u8> = (0..100_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        roundtrip(&data, 1);
        roundtrip(&data, 9);
    }

    #[test]
    fn float_column_data() {
        // big-endian f32 columns, the actual payload shape in this repo
        let data: Vec<u8> =
            (0..25_000).flat_map(|i| ((i as f32) * 0.37).sin().to_be_bytes()).collect();
        for level in [1, 5, 9] {
            roundtrip(&data, level);
        }
    }

    #[test]
    fn overlapping_and_long_matches() {
        let mut data = vec![b'z'; 70_000];
        data.extend_from_slice(b"tail");
        roundtrip(&data, 6);
    }

    #[test]
    fn wide_paths_are_byte_identical_to_scalar() {
        // Differential pin: the SWAR tokeniser must emit the exact
        // same compressed bytes as the scalar reference, and both
        // decoders must reproduce the input from either stream.
        let mut x = 0x1234_5678u32;
        let mut rnd = |n: usize| -> Vec<u8> {
            (0..n)
                .map(|_| {
                    x ^= x << 13;
                    x ^= x >> 17;
                    x ^= x << 5;
                    x as u8
                })
                .collect()
        };
        let mut mixed = b"header ".to_vec();
        mixed.extend(vec![0u8; 700]); // RLE (overlap dist 1)
        mixed.extend(rnd(900)); // incompressible
        mixed.extend(b"abcdefgh".repeat(300)); // period-8 overlap
        mixed.extend(mixed.clone()); // far back-reference
        let cases: Vec<Vec<u8>> = vec![
            Vec::new(),
            b"a".to_vec(),
            b"abcd".to_vec(),
            vec![0u8; 65_000],
            rnd(20_000),
            b"the quick brown fox jumps over the lazy dog. ".repeat(400),
            mixed,
        ];
        for (i, data) in cases.iter().enumerate() {
            for level in [1u8, 5, 9] {
                let wide = compress(data, level);
                let scalar = compress_scalar(data, level);
                assert_eq!(wide, scalar, "case {i} level {level}: compressed bytes differ");
                let mut d_wide = Vec::new();
                decompress_into(&wide, data.len(), &mut d_wide).unwrap();
                let mut d_scalar = Vec::new();
                decompress_into_scalar(&wide, data.len(), &mut d_scalar).unwrap();
                assert_eq!(d_wide, *data, "case {i} level {level}: wide decode");
                assert_eq!(d_scalar, *data, "case {i} level {level}: scalar decode");
            }
        }
    }

    #[test]
    fn adaptive_hash_sizes_roundtrip() {
        // Sizes straddling the hash_log_for breakpoints (2^10..2^17):
        // every size must roundtrip and stay wide==scalar.
        let mut x = 0x9E37_79B9u32;
        for n in [0usize, 1, 5, 16, 100, 1023, 1024, 1025, 5000, 70_000, 200_000] {
            let data: Vec<u8> = (0..n)
                .map(|i| {
                    x ^= x << 13;
                    x ^= x >> 17;
                    x ^= x << 5;
                    if i % 3 == 0 { (i % 251) as u8 } else { x as u8 }
                })
                .collect();
            let c = compress(&data, 6);
            assert_eq!(c, compress_scalar(&data, 6), "n={n}");
            assert_eq!(decompress(&c, n).unwrap(), data, "n={n}");
        }
    }

    #[test]
    fn hash_table_pool_is_reused() {
        // Two compressions on the same thread: the second must draw
        // its chain arrays from the shelf, not the allocator.
        // (Counters are process-global and other tests compress
        // concurrently, so assert only the hits we must have added.)
        let data = b"pool warmup payload ".repeat(100);
        let _ = compress(&data, 3);
        let (h0, _) = crate::compress::pool::u32_stats();
        let _ = compress(&data, 3);
        let (h1, _) = crate::compress::pool::u32_stats();
        assert!(h1 - h0 >= 2, "expected pooled head+prev hits, got {}", h1 - h0);
    }

    #[test]
    fn corruption_is_an_error() {
        let data = b"hello compression world ".repeat(200);
        let c = compress(&data, 6);
        assert!(decompress(&c[..3], data.len()).is_err());
        assert!(decompress(&c, data.len() + 1).is_err());
        assert!(decompress(&c, data.len() - 1).is_err());
        let mut bad = c.clone();
        let mid = bad.len() / 2;
        bad.truncate(mid);
        // Truncated bitstream: must error, never panic or loop forever.
        let _ = decompress(&bad, data.len());
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing assertion; run with --release")]
    fn decompression_much_faster_than_compression() {
        // Asymmetry sanity: decoding beats level-9 encoding on
        // realistic (only mildly compressible) column data — the
        // paper's premise for read vs write cost. Highly repetitive
        // text is excluded: there encode degenerates to a handful of
        // long matches and can be faster than decode's table builds.
        let data: Vec<u8> = (0..250_000)
            .flat_map(|i| {
                let x = ((i as f32) * 0.37).sin() * 100.0;
                ((x * 128.0).round() / 128.0).to_be_bytes()
            })
            .collect();
        let c = compress(&data, 9);
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            compress(&data, 9);
        }
        let enc = t0.elapsed();
        let t1 = std::time::Instant::now();
        for _ in 0..3 {
            decompress(&c, data.len()).unwrap();
        }
        let dec = t1.elapsed();
        assert!(dec < enc, "decode {dec:?} should beat encode {enc:?}");
    }
}
