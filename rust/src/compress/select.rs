//! Per-column adaptive codec selection.
//!
//! The paper's Figure 8 premise: no single codec sits on the
//! throughput × size frontier for every column. Floating-point noise
//! barely compresses (any CPU spent is wasted — store raw), small-range
//! integers deflate well under a fast LZ (`lz4r`), and text-like
//! payloads reward the dense entropy coder (`rzip`). A global
//! `WriterConfig::compression` forces one point of that trade-off onto
//! every branch; this module instead samples each column's early
//! baskets across a candidate set and commits per column.
//!
//! ## Protocol
//!
//! The controller mirrors [`crate::tree::sizer`]: decisions are made on
//! the producer thread (one [`ColumnSelector::next_settings`] call per
//! basket, before the basket fans out to compression workers), and
//! measurements flow back asynchronously as [`Observation`]s. Because
//! observations may lag by however many baskets are in flight, the
//! selector issues its probe round-robin by *issue count* and commits
//! from whatever observations have arrived — a late probe result can
//! only improve the next re-probe, never corrupt the stream.
//!
//! * **Probe** — the first `candidates.len() × probe_baskets` baskets
//!   cycle through the candidate list round-robin.
//! * **Commit** — once probing is exhausted, each observed candidate is
//!   scored `ratio × throughput_mbps ^ speed_weight` (ratio =
//!   raw/compressed; throughput = raw MB per CPU-second of compression)
//!   and the best observed score wins. If no observations have arrived
//!   yet the writer's global fallback is used and the commit retried on
//!   the next basket.
//! * **Re-probe** — after `reprobe_interval` committed baskets, or
//!   earlier if the committed codec's recent compression ratio drifts
//!   from its commit-time ratio by more than `drift_ratio`
//!   (fractional), the selector forgets its per-candidate stats and
//!   probes again.
//!
//! ## Determinism
//!
//! Scores depend on measured wall time, so two runs may commit
//! different codecs — the same determinism model as the adaptive
//! cluster sizer: every basket records its own [`Settings`] in the
//! file metadata (a codec-code byte and a level byte per basket entry,
//! format `VERSION` 2) and each compressed block is self-describing,
//! so readers decode *any* selection trace to identical data and need
//! no knowledge of the selection policy.

use super::{Codec, Settings};

/// Decisions kept per column for inspection; beyond this the trace
/// stops growing (the summary counters keep counting).
const MAX_TRACE: usize = 4096;

/// Observations in the committed-phase drift window before the drift
/// test is applied — too few baskets and one odd payload would trigger
/// spurious re-probes.
const DRIFT_WINDOW: u32 = 8;

/// How a [`crate::tree::writer::TreeWriter`] picks basket compression
/// settings.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum CodecSelection {
    /// Every basket uses `WriterConfig::compression` (historical
    /// behaviour, and the default).
    #[default]
    Global,
    /// Each column samples its early baskets across
    /// [`SelectConfig::candidates`] and commits to the winner.
    PerColumn(SelectConfig),
}

/// Knobs for per-column selection. The defaults probe two baskets per
/// candidate over a five-point candidate ladder (raw storage, fast and
/// thorough `lz4r`, light and dense `rzip`).
#[derive(Clone, Debug, PartialEq)]
pub struct SelectConfig {
    /// Codec × level points to sample. Empty = always use the fallback.
    pub candidates: Vec<Settings>,
    /// Baskets probed per candidate before committing.
    pub probe_baskets: u32,
    /// Exponent weighting compression throughput against ratio in the
    /// score `ratio × mbps^speed_weight`. `0.0` ranks purely by ratio;
    /// `1.0` treats a 2× throughput gain like a 2× size win.
    pub speed_weight: f64,
    /// Committed baskets between scheduled re-probes (`0` = never).
    pub reprobe_interval: u32,
    /// Fractional drift of the committed codec's recent ratio (vs its
    /// commit-time ratio) that forces an early re-probe.
    pub drift_ratio: f64,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig {
            candidates: vec![
                Settings::uncompressed(),
                Settings { codec: Codec::Lz4r, level: 1 },
                Settings { codec: Codec::Lz4r, level: 6 },
                Settings { codec: Codec::Rzip, level: 2 },
                Settings { codec: Codec::Rzip, level: 6 },
            ],
            probe_baskets: 2,
            speed_weight: 0.3,
            reprobe_interval: 64,
            drift_ratio: 0.2,
        }
    }
}

/// One basket's measured compression outcome, reported back to the
/// selector that issued it.
#[derive(Clone, Copy, Debug)]
pub struct Observation {
    /// The settings the basket was compressed with.
    pub settings: Settings,
    /// Uncompressed payload bytes.
    pub raw_len: u64,
    /// Stored (compressed container) bytes.
    pub comp_len: u64,
    /// CPU nanoseconds spent compressing.
    pub nanos: u64,
}

/// One issued decision, for the per-column trace.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    /// Basket ordinal within the column (0-based issue order).
    pub basket: u64,
    /// Settings issued for that basket.
    pub settings: Settings,
    /// Whether the basket was a probe (`true`) or committed/fallback.
    pub probing: bool,
}

/// Compact, `Copy` roll-up of selection activity — aggregated across
/// columns into `WriteStats` so the report stays `Copy`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SelectSummary {
    /// Columns driven by per-column selection.
    pub columns: u32,
    /// Columns currently in the committed phase.
    pub committed: u32,
    /// Probe baskets issued (across all probe rounds).
    pub probes: u64,
    /// Re-probe rounds triggered (interval or drift).
    pub reprobes: u32,
}

impl SelectSummary {
    /// Fold another column's summary into this one.
    pub fn absorb(&mut self, other: SelectSummary) {
        self.columns += other.columns;
        self.committed += other.committed;
        self.probes += other.probes;
        self.reprobes += other.reprobes;
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct CandStats {
    raw: u64,
    comp: u64,
    nanos: u64,
    baskets: u32,
}

#[derive(Clone, Copy, Debug)]
enum Phase {
    Probing,
    Committed { choice: usize },
}

/// Per-column selection state machine. Owned by the writer; all calls
/// happen on the producer thread (observations are relayed there by
/// the writer's inbox), so no interior locking is needed.
pub struct ColumnSelector {
    cfg: SelectConfig,
    fallback: Settings,
    phase: Phase,
    /// Baskets issued in the current probe round.
    probe_issued: u64,
    /// Baskets issued overall (trace ordinal).
    issued: u64,
    stats: Vec<CandStats>,
    /// Ratio at commit time, the drift reference.
    commit_ratio: f64,
    committed_baskets: u32,
    window_raw: u64,
    window_comp: u64,
    window_baskets: u32,
    want_reprobe: bool,
    probes: u64,
    reprobes: u32,
    trace: Vec<Decision>,
}

impl ColumnSelector {
    pub fn new(cfg: SelectConfig, fallback: Settings) -> Self {
        let n = cfg.candidates.len();
        ColumnSelector {
            cfg,
            fallback,
            phase: Phase::Probing,
            probe_issued: 0,
            issued: 0,
            stats: vec![CandStats::default(); n],
            commit_ratio: 0.0,
            committed_baskets: 0,
            window_raw: 0,
            window_comp: 0,
            window_baskets: 0,
            want_reprobe: false,
            probes: 0,
            reprobes: 0,
            trace: Vec::new(),
        }
    }

    /// Settings for the next basket of this column. Called exactly once
    /// per basket, in issue order, on the producer thread.
    pub fn next_settings(&mut self) -> Settings {
        let n = self.cfg.candidates.len();
        if n == 0 {
            return self.record(self.fallback, false);
        }
        if self.want_reprobe {
            self.begin_reprobe();
        }
        match self.phase {
            Phase::Probing => {
                let total = n as u64 * self.cfg.probe_baskets as u64;
                if self.probe_issued < total {
                    let idx = (self.probe_issued % n as u64) as usize;
                    self.probe_issued += 1;
                    self.probes += 1;
                    self.record(self.cfg.candidates[idx], true)
                } else if let Some((idx, ratio)) = self.best_observed() {
                    self.phase = Phase::Committed { choice: idx };
                    self.commit_ratio = ratio;
                    self.committed_baskets = 1;
                    self.window_raw = 0;
                    self.window_comp = 0;
                    self.window_baskets = 0;
                    self.record(self.cfg.candidates[idx], false)
                } else {
                    // Probes issued but no measurements back yet: stay
                    // on the fallback and retry the commit next basket.
                    self.record(self.fallback, false)
                }
            }
            Phase::Committed { choice } => {
                self.committed_baskets += 1;
                if self.cfg.reprobe_interval > 0
                    && self.committed_baskets >= self.cfg.reprobe_interval
                {
                    self.want_reprobe = true;
                }
                self.record(self.cfg.candidates[choice], false)
            }
        }
    }

    /// Report one basket's measured outcome. Arrival order and lag do
    /// not matter; late probe results feed the next (re-)commit.
    pub fn observe(&mut self, obs: Observation) {
        if let Some(idx) =
            self.cfg.candidates.iter().position(|c| *c == obs.settings)
        {
            let s = &mut self.stats[idx];
            s.raw += obs.raw_len;
            s.comp += obs.comp_len;
            s.nanos += obs.nanos;
            s.baskets += 1;
        }
        if let Phase::Committed { choice } = self.phase {
            if self.cfg.candidates[choice] == obs.settings {
                self.window_raw += obs.raw_len;
                self.window_comp += obs.comp_len;
                self.window_baskets += 1;
                if self.window_baskets >= DRIFT_WINDOW && self.commit_ratio > 0.0 {
                    let recent = ratio_of(self.window_raw, self.window_comp);
                    let drift = (recent - self.commit_ratio).abs() / self.commit_ratio;
                    if drift > self.cfg.drift_ratio {
                        self.want_reprobe = true;
                    } else {
                        // Sliding restart: keep watching in windows.
                        self.window_raw = 0;
                        self.window_comp = 0;
                        self.window_baskets = 0;
                    }
                }
            }
        }
    }

    /// The committed settings, if the column has committed.
    pub fn current_choice(&self) -> Option<Settings> {
        match self.phase {
            Phase::Committed { choice } => Some(self.cfg.candidates[choice]),
            Phase::Probing => None,
        }
    }

    /// Issued decisions, capped at [`MAX_TRACE`].
    pub fn trace(&self) -> &[Decision] {
        &self.trace
    }

    /// This column's contribution to the tree-wide [`SelectSummary`].
    pub fn summary(&self) -> SelectSummary {
        SelectSummary {
            columns: 1,
            committed: matches!(self.phase, Phase::Committed { .. }) as u32,
            probes: self.probes,
            reprobes: self.reprobes,
        }
    }

    fn begin_reprobe(&mut self) {
        self.want_reprobe = false;
        self.phase = Phase::Probing;
        self.probe_issued = 0;
        self.stats.iter_mut().for_each(|s| *s = CandStats::default());
        self.commit_ratio = 0.0;
        self.window_raw = 0;
        self.window_comp = 0;
        self.window_baskets = 0;
        self.reprobes += 1;
    }

    /// Best-scoring candidate among those with at least one observed
    /// basket, with its observed ratio.
    fn best_observed(&self) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64, f64)> = None;
        for (idx, s) in self.stats.iter().enumerate() {
            if s.baskets == 0 {
                continue;
            }
            let ratio = ratio_of(s.raw, s.comp);
            let secs = (s.nanos.max(1)) as f64 * 1e-9;
            let mbps = (s.raw as f64 / (1024.0 * 1024.0)) / secs;
            let score = ratio * mbps.max(f64::MIN_POSITIVE).powf(self.cfg.speed_weight);
            let better = match best {
                None => true,
                Some((_, best_score, _)) => score > best_score,
            };
            if better {
                best = Some((idx, score, ratio));
            }
        }
        best.map(|(idx, _, ratio)| (idx, ratio))
    }

    fn record(&mut self, settings: Settings, probing: bool) -> Settings {
        if self.trace.len() < MAX_TRACE {
            self.trace.push(Decision { basket: self.issued, settings, probing });
        }
        self.issued += 1;
        settings
    }
}

fn ratio_of(raw: u64, comp: u64) -> f64 {
    raw as f64 / comp.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SelectConfig {
        SelectConfig::default()
    }

    fn obs(settings: Settings, raw: u64, comp: u64, nanos: u64) -> Observation {
        Observation { settings, raw_len: raw, comp_len: comp, nanos }
    }

    #[test]
    fn probing_cycles_all_candidates_round_robin() {
        let c = cfg();
        let n = c.candidates.len();
        let per = c.probe_baskets as usize;
        let mut sel = ColumnSelector::new(c.clone(), Settings::default_compressed());
        let mut counts = vec![0usize; n];
        for _ in 0..n * per {
            let s = sel.next_settings();
            let idx = c.candidates.iter().position(|x| *x == s).unwrap();
            counts[idx] += 1;
        }
        assert!(counts.iter().all(|&k| k == per), "uneven probe: {counts:?}");
        assert_eq!(sel.summary().probes, (n * per) as u64);
        assert!(sel.trace().iter().all(|d| d.probing));
    }

    #[test]
    fn falls_back_until_observations_arrive_then_commits() {
        let c = cfg();
        let n = c.candidates.len() * c.probe_baskets as usize;
        let fallback = Settings::default_compressed();
        let mut sel = ColumnSelector::new(c.clone(), fallback);
        for _ in 0..n {
            sel.next_settings();
        }
        // All probes issued, nothing measured yet: fallback, uncommitted.
        assert_eq!(sel.next_settings(), fallback);
        assert!(sel.current_choice().is_none());
        // One observation is enough to commit (to the only observed).
        let lz4 = Settings { codec: Codec::Lz4r, level: 1 };
        sel.observe(obs(lz4, 1 << 20, 1 << 18, 2_000_000));
        assert_eq!(sel.next_settings(), lz4);
        assert_eq!(sel.current_choice(), Some(lz4));
        assert_eq!(sel.summary().committed, 1);
    }

    #[test]
    fn commits_to_ratio_speed_winner() {
        let c = cfg();
        let mut sel = ColumnSelector::new(c.clone(), Settings::default_compressed());
        let probes = c.candidates.len() * c.probe_baskets as usize;
        for _ in 0..probes {
            sel.next_settings();
        }
        // lz4-1: ratio 3 at ~500 MB/s. rzip-6: ratio 3.3 at ~20 MB/s.
        // score(lz4) = 3 * 500^0.3 ≈ 19.4 > score(rzip) = 3.3 * 20^0.3 ≈ 8.1.
        let lz4 = Settings { codec: Codec::Lz4r, level: 1 };
        let rzip = Settings { codec: Codec::Rzip, level: 6 };
        let mib = 1u64 << 20;
        sel.observe(obs(lz4, 100 * mib, 100 * mib / 3, 200_000_000));
        sel.observe(obs(rzip, 100 * mib, 30 * mib, 5_000_000_000));
        sel.observe(obs(Settings::uncompressed(), 100 * mib, 100 * mib, 10_000_000));
        assert_eq!(sel.next_settings(), lz4);
    }

    #[test]
    fn pure_ratio_weighting_prefers_denser_codec() {
        let mut c = cfg();
        c.speed_weight = 0.0;
        let mut sel = ColumnSelector::new(c.clone(), Settings::default_compressed());
        for _ in 0..c.candidates.len() * c.probe_baskets as usize {
            sel.next_settings();
        }
        let lz4 = Settings { codec: Codec::Lz4r, level: 1 };
        let rzip = Settings { codec: Codec::Rzip, level: 6 };
        let mib = 1u64 << 20;
        sel.observe(obs(lz4, 100 * mib, 100 * mib / 3, 200_000_000));
        sel.observe(obs(rzip, 100 * mib, 30 * mib, 5_000_000_000));
        assert_eq!(sel.next_settings(), rzip);
    }

    #[test]
    fn drift_triggers_reprobe() {
        let c = cfg();
        let mut sel = ColumnSelector::new(c.clone(), Settings::default_compressed());
        for _ in 0..c.candidates.len() * c.probe_baskets as usize {
            sel.next_settings();
        }
        let lz4 = Settings { codec: Codec::Lz4r, level: 1 };
        let mib = 1u64 << 20;
        sel.observe(obs(lz4, 10 * mib, 2 * mib, 1_000_000)); // ratio 5
        assert_eq!(sel.next_settings(), lz4);
        // Data distribution changes: ratio collapses to ~1.
        for _ in 0..DRIFT_WINDOW {
            sel.observe(obs(lz4, mib, mib, 1_000_000));
        }
        let s = sel.next_settings();
        assert!(sel.summary().reprobes >= 1, "drift should force a re-probe");
        assert_eq!(s, c.candidates[0], "re-probe restarts the round-robin");
    }

    #[test]
    fn scheduled_reprobe_after_interval() {
        let mut c = cfg();
        c.reprobe_interval = 4;
        c.drift_ratio = f64::INFINITY; // isolate the interval trigger
        let mut sel = ColumnSelector::new(c.clone(), Settings::default_compressed());
        for _ in 0..c.candidates.len() * c.probe_baskets as usize {
            sel.next_settings();
        }
        let lz4 = Settings { codec: Codec::Lz4r, level: 1 };
        sel.observe(obs(lz4, 1 << 20, 1 << 18, 1_000_000));
        for _ in 0..c.reprobe_interval + 1 {
            sel.next_settings();
        }
        assert!(sel.summary().reprobes >= 1);
    }

    #[test]
    fn empty_candidates_always_fall_back() {
        let c = SelectConfig { candidates: Vec::new(), ..cfg() };
        let fallback = Settings::default_compressed();
        let mut sel = ColumnSelector::new(c, fallback);
        for _ in 0..10 {
            assert_eq!(sel.next_settings(), fallback);
        }
        assert_eq!(sel.summary().probes, 0);
    }

    #[test]
    fn summary_absorb_accumulates() {
        let mut total = SelectSummary::default();
        total.absorb(SelectSummary { columns: 1, committed: 1, probes: 10, reprobes: 0 });
        total.absorb(SelectSummary { columns: 1, committed: 0, probes: 5, reprobes: 2 });
        assert_eq!(
            total,
            SelectSummary { columns: 2, committed: 1, probes: 15, reprobes: 2 }
        );
    }
}
