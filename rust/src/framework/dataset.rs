//! Dataset shapes and synthetic event sources.
//!
//! The paper benchmarks two CMSSW output datasets — an I/O-heavy
//! reconstruction set (RECO) and a slim analysis set (AOD) — plus the
//! CMS GenSim (~70 columns) and ATLAS xAOD (~200 columns) read
//! workloads. [`DatasetKind`] captures those shapes; event content
//! comes from the PJRT PRNG kernel (via [`crate::runtime::Engine`]) or
//! from [`SplitMix`], a rust fallback with the same statistical shape
//! for engine-less tests.

use std::sync::Arc;

use crate::cache::{ClusterStream, DecodedCluster, PrefetchOptions, PrefetchStats};
use crate::error::Result;
use crate::format::reader::FileReader;
use crate::runtime::{Engine, EventBlock};
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::storage::BackendRef;
use crate::tree::reader::TreeReader;

/// Benchmark dataset shapes (column counts from the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// CMSSW reconstruction output: many wide columns, I/O heavy.
    Reco,
    /// CMSSW analysis output: slim.
    Aod,
    /// CMS GenSim-like read workload (~70 columns).
    GenSim,
    /// ATLAS xAOD-like read workload (~200 columns).
    Xaod,
}

impl DatasetKind {
    pub fn n_branches(self) -> usize {
        match self {
            DatasetKind::Reco => 48,
            DatasetKind::Aod => 12,
            DatasetKind::GenSim => 70,
            DatasetKind::Xaod => 200,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::Reco => "RECO",
            DatasetKind::Aod => "AOD",
            DatasetKind::GenSim => "GenSim",
            DatasetKind::Xaod => "xAOD",
        }
    }

    pub fn schema(self) -> Schema {
        Schema::flat_f32(&format!("{}_c", self.name()), self.n_branches())
    }
}

/// Quantise a float to ~3 fractional bits of mantissa precision loss —
/// the "physics precision" trick real experiments use so reco data
/// compresses; keeps our synthetic columns zlib-friendly (~2-3x) like
/// real event data rather than incompressible white noise.
#[inline]
pub fn quantize(x: f32) -> f32 {
    (x * 128.0).round() / 128.0
}

/// Expand an 8-column physics block to `width` derived columns.
///
/// Column `j` is an affine transform of base column `j % 8` with a
/// per-column scale/offset — cheap, deterministic, and with the same
/// per-column entropy profile as the base physics columns.
pub fn expand_block(block: &EventBlock, width: usize) -> Vec<ColumnData> {
    let base = block.columns();
    (0..width)
        .map(|j| {
            let src = &base[j % base.len()];
            let scale = 1.0 + 0.125 * (j / base.len()) as f32;
            let offset = 0.25 * j as f32;
            ColumnData::F32(src.iter().map(|&x| quantize(x * scale + offset)).collect())
        })
        .collect()
}

/// Generate one expanded dataset block through the PJRT engine.
pub fn engine_block(
    engine: &Engine,
    kind: DatasetKind,
    seed: u32,
    stream: u32,
    block: usize,
) -> Result<Vec<ColumnData>> {
    let ev = engine.generate(seed, stream, block)?;
    Ok(expand_block(&ev, kind.n_branches()))
}

/// SplitMix32 fallback generator (tests / engine-less paths). Produces
/// the same *shape* of data as the PJRT path: pt-like exponential
/// columns, quantised.
pub struct SplitMix {
    state: u64,
}

impl SplitMix {
    pub fn new(seed: u64) -> Self {
        SplitMix { state: seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1) }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        (z ^ (z >> 31)) as u32
    }

    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// A physics-shaped fallback event block (n, 8), row-major.
    pub fn event_block(&mut self, n: usize, ncols: usize) -> EventBlock {
        let data: Vec<f32> = (0..n * ncols)
            .map(|i| {
                let u = self.uniform();
                match i % 8 {
                    0 | 4 => -30.0 * (1.0 - 0.999999 * u).ln(), // pt
                    1 | 5 => 2.5 * (2.0 * u - 1.0),             // eta
                    2 | 6 => std::f32::consts::PI * (2.0 * u - 1.0), // phi
                    _ => 0.1057 * (1.0 + 0.01 * (u - 0.5)),     // m
                }
            })
            .collect();
        EventBlock { n, ncols, data }
    }
}

/// Report from a bounded-memory streaming scan ([`scan_file`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanReport {
    /// Entries visited (lead-branch count).
    pub entries: u64,
    /// Clusters streamed.
    pub clusters: u64,
    /// Prefetcher accounting (coalescing, stall, window band).
    pub prefetch: PrefetchStats,
}

impl ScanReport {
    /// Stored bytes the scan's (possibly projected) fetch plan covered.
    pub fn bytes_selected(&self) -> u64 {
        self.prefetch.bytes_selected
    }

    /// Stored bytes projection pushdown left on the device — what a
    /// whole-tree scan would have fetched on top of
    /// [`ScanReport::bytes_selected`].
    pub fn bytes_skipped(&self) -> u64 {
        self.prefetch.bytes_skipped
    }
}

/// Stream a file's first tree cluster-by-cluster through the parallel
/// read-ahead cache ([`crate::cache`]), applying `f` to each decoded
/// cluster and dropping it. This is the streaming-scan workload the
/// materialising `read_columns` cannot serve: resident decoded data
/// never exceeds the prefetch window, so a scan over a
/// larger-than-memory dataset runs in flat memory while the window
/// hides the device latency.
pub fn scan_file(
    backend: BackendRef,
    opts: &PrefetchOptions,
    mut f: impl FnMut(&DecodedCluster),
) -> Result<ScanReport> {
    let reader = TreeReader::open_first(Arc::new(FileReader::open(backend)?))?;
    let mut stream = ClusterStream::open(&reader, opts)?;
    let mut report = ScanReport::default();
    while let Some(cluster) = stream.next()? {
        report.entries += cluster.entries;
        report.clusters += 1;
        f(&cluster);
    }
    report.prefetch = stream.stats();
    Ok(report)
}

/// Projection-pushdown variant of [`scan_file`]: stream only the given
/// branch indices. The selection reaches the fetch planner, so on a
/// paged (format v3) file unselected columns' pages are never read
/// from the device — `branches` here is the analysis-side spelling of
/// the same selection `ReadOptions::branches` threads through
/// [`crate::coordinator::read::read_columns`]. Decoded clusters carry
/// the selected columns in selection order.
pub fn scan_projection(
    backend: BackendRef,
    branches: &[usize],
    opts: &PrefetchOptions,
    f: impl FnMut(&DecodedCluster),
) -> Result<ScanReport> {
    scan_file(
        backend,
        &PrefetchOptions { branches: Some(branches.to_vec()), ..opts.clone() },
        f,
    )
}

/// Generate one expanded dataset block from the fallback PRNG.
pub fn fallback_block(
    rng: &mut SplitMix,
    kind: DatasetKind,
    block: usize,
) -> Vec<ColumnData> {
    let ev = rng.event_block(block, 8);
    expand_block(&ev, kind.n_branches())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        assert_eq!(DatasetKind::Reco.n_branches(), 48);
        assert_eq!(DatasetKind::GenSim.n_branches(), 70);
        assert_eq!(DatasetKind::Xaod.n_branches(), 200);
        assert_eq!(DatasetKind::Aod.schema().len(), 12);
    }

    #[test]
    fn expand_covers_width_and_length() {
        let mut rng = SplitMix::new(1);
        let ev = rng.event_block(256, 8);
        let cols = expand_block(&ev, 70);
        assert_eq!(cols.len(), 70);
        assert!(cols.iter().all(|c| c.len() == 256));
        // derived columns differ from each other
        assert_ne!(cols[0], cols[8]);
    }

    #[test]
    fn fallback_block_is_deterministic() {
        let a = fallback_block(&mut SplitMix::new(9), DatasetKind::Aod, 128);
        let b = fallback_block(&mut SplitMix::new(9), DatasetKind::Aod, 128);
        assert_eq!(a, b);
    }

    #[test]
    fn quantized_data_compresses() {
        use crate::compress::{self, Codec, Settings};
        let mut rng = SplitMix::new(3);
        let cols = fallback_block(&mut rng, DatasetKind::Reco, 4096);
        let raw = cols[0].encode();
        let c = compress::compress(Settings::new(Codec::Rzip, 5), &raw);
        let ratio = raw.len() as f64 / c.len() as f64;
        assert!(ratio > 1.3, "quantised physics data should compress, got {ratio:.2}");
    }

    #[test]
    fn scan_file_visits_every_cluster_once_in_order() {
        use crate::compress::{Codec, Settings};
        let (be, rep) = crate::experiments::util::synthesize_dataset(
            DatasetKind::Aod,
            8192,
            1024,
            Settings::new(Codec::Lz4r, 3),
            None,
        )
        .unwrap();
        let mut seen_entries = 0u64;
        let mut last_index = None;
        let report = scan_file(be, &PrefetchOptions::default(), |c| {
            assert_eq!(c.index, last_index.map_or(0, |i: usize| i + 1), "in order");
            last_index = Some(c.index);
            seen_entries += c.columns[0].len() as u64;
        })
        .unwrap();
        assert_eq!(rep.entries, 8192);
        assert_eq!(report.entries, 8192);
        assert_eq!(seen_entries, 8192);
        assert_eq!(report.clusters, 8, "8192 entries / 1024 per cluster");
        assert!(
            report.prefetch.coalescing_factor() >= 4.0,
            "12 AOD branches coalesce well: {:.1}",
            report.prefetch.coalescing_factor()
        );
    }

    #[test]
    fn projected_scan_selects_subset_and_accounts_bytes() {
        use crate::compress::{Codec, Settings};
        let (be, _) = crate::experiments::util::synthesize_dataset(
            DatasetKind::Aod,
            4096,
            512,
            Settings::new(Codec::Lz4r, 3),
            None,
        )
        .unwrap();
        let full = scan_file(be.clone(), &PrefetchOptions::default(), |_| {}).unwrap();
        assert_eq!(full.bytes_skipped(), 0, "whole-tree scan skips nothing");
        let mut widths = Vec::new();
        let rep = scan_projection(be, &[7, 0, 3], &PrefetchOptions::default(), |c| {
            widths.push(c.columns.len());
        })
        .unwrap();
        assert_eq!(rep.entries, 4096);
        assert!(widths.iter().all(|&w| w == 3), "clusters carry only the projection");
        assert_eq!(
            rep.bytes_selected() + rep.bytes_skipped(),
            full.bytes_selected(),
            "selected + skipped partition the tree's stored bytes"
        );
        assert!(
            rep.bytes_selected() < full.bytes_selected() / 3,
            "3 of 12 branches: {} of {} bytes",
            rep.bytes_selected(),
            full.bytes_selected()
        );
    }

    #[test]
    fn splitmix_uniformity() {
        let mut rng = SplitMix::new(42);
        let n = 10_000;
        let mean: f32 = (0..n).map(|_| rng.uniform()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
