//! CMSSW-like mini framework (paper §3.1, Figure 3).
//!
//! N *streams* (worker threads) each generate event blocks — through
//! the PJRT PRNG graph when an [`Engine`] is attached — and hand them
//! to the output module. Three output modes reproduce the three curves
//! of Figure 3:
//!
//! * [`OutputMode::None`] — events are generated and dropped: the
//!   "not writing out any data" ceiling (red line).
//! * [`OutputMode::SerialOutput`] — streams ship *raw* column blocks to
//!   a single output thread that serialises, compresses and writes
//!   them: the IMT-off CMSSW output module, which saturates once one
//!   core's compression throughput is reached.
//! * [`OutputMode::ImtMerger`] — streams serialise + compress locally
//!   (in parallel across streams, and across branches when IMT is on)
//!   and the `TBufferMerger` output thread only appends bytes: the
//!   IMT-on path that keeps scaling.

pub mod chain;
pub mod dataset;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::time::Instant;

use crate::compress::Settings;
use crate::error::{Error, Result};
use crate::format::writer::FileWriter;
use crate::format::Directory;
use crate::merger::{MergerConfig, TBufferMerger};
use crate::metrics::{Recorder, SpanKind};
use crate::runtime::Engine;
use crate::serial::column::ColumnData;
use crate::session::{Session, SessionConfig};
use crate::storage::BackendRef;
use crate::tree::sink::FileSink;
use crate::tree::writer::{FlushMode, TreeWriter, WriteStats, WriterConfig};

use dataset::{DatasetKind, SplitMix};

/// Output-module mode (the three Figure 3 configurations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputMode {
    /// Generate and drop (throughput ceiling).
    None,
    /// Single output thread does serialisation+compression+write
    /// (IMT off).
    SerialOutput,
    /// TBufferMerger: workers compress, output thread appends (IMT on).
    ImtMerger,
}

/// Framework run configuration.
#[derive(Clone)]
pub struct FrameworkConfig {
    pub streams: usize,
    /// Event blocks each stream produces.
    pub blocks_per_stream: usize,
    /// Events per block (must be a compiled engine block size when an
    /// engine is used).
    pub block: usize,
    pub dataset: DatasetKind,
    pub output: OutputMode,
    pub compression: Settings,
    /// Merger queue depth (backpressure knob).
    pub queue_depth: usize,
}

impl Default for FrameworkConfig {
    fn default() -> Self {
        FrameworkConfig {
            streams: 2,
            blocks_per_stream: 4,
            block: 4096,
            dataset: DatasetKind::Reco,
            output: OutputMode::ImtMerger,
            compression: Settings::default_compressed(),
            queue_depth: 16,
        }
    }
}

/// Outcome of a framework run.
#[derive(Clone, Copy, Debug)]
pub struct FrameworkReport {
    pub events: u64,
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub wall: std::time::Duration,
}

impl FrameworkReport {
    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall.as_secs_f64()
    }

    pub fn throughput_mbps(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }
}

/// Run the framework; `backend` receives the output file (ignored for
/// [`OutputMode::None`]).
pub fn run(
    cfg: &FrameworkConfig,
    backend: BackendRef,
    engine: Option<&Engine>,
    recorder: Option<Arc<Recorder>>,
) -> Result<FrameworkReport> {
    match cfg.output {
        OutputMode::None => run_no_output(cfg, engine, recorder),
        OutputMode::SerialOutput => run_serial_output(cfg, backend, engine, recorder),
        OutputMode::ImtMerger => run_imt_merger(cfg, backend, engine, recorder),
    }
}

/// Generate one block for `(stream, index)` deterministically.
fn gen_block(
    cfg: &FrameworkConfig,
    engine: Option<&Engine>,
    stream: usize,
    index: usize,
) -> Result<Vec<ColumnData>> {
    match engine {
        Some(e) => {
            dataset::engine_block(e, cfg.dataset, index as u32 + 1, stream as u32, cfg.block)
        }
        None => {
            let mut rng = SplitMix::new(((stream as u64) << 32) | index as u64);
            Ok(dataset::fallback_block(&mut rng, cfg.dataset, cfg.block))
        }
    }
}

fn raw_bytes_of(cfg: &FrameworkConfig) -> u64 {
    (cfg.streams * cfg.blocks_per_stream * cfg.block * cfg.dataset.n_branches() * 4) as u64
}

fn run_no_output(
    cfg: &FrameworkConfig,
    engine: Option<&Engine>,
    recorder: Option<Arc<Recorder>>,
) -> Result<FrameworkReport> {
    let t0 = Instant::now();
    let errs: std::sync::Mutex<Vec<Error>> = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for stream in 0..cfg.streams {
            let recorder = recorder.clone();
            let errs = &errs;
            s.spawn(move || {
                for i in 0..cfg.blocks_per_stream {
                    let out = match &recorder {
                        Some(r) => r.record(SpanKind::Generate, || {
                            gen_block(cfg, engine, stream, i)
                        }),
                        None => gen_block(cfg, engine, stream, i),
                    };
                    if let Err(e) = out {
                        errs.lock().unwrap().push(e);
                        return;
                    }
                }
            });
        }
    });
    if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
        return Err(e);
    }
    Ok(FrameworkReport {
        events: (cfg.streams * cfg.blocks_per_stream * cfg.block) as u64,
        raw_bytes: raw_bytes_of(cfg),
        stored_bytes: 0,
        wall: t0.elapsed(),
    })
}

fn run_serial_output(
    cfg: &FrameworkConfig,
    backend: BackendRef,
    engine: Option<&Engine>,
    recorder: Option<Arc<Recorder>>,
) -> Result<FrameworkReport> {
    let t0 = Instant::now();
    let schema = cfg.dataset.schema();
    let fw = Arc::new(FileWriter::create(backend)?);
    let sink = FileSink::new(fw.clone(), schema.len());
    let writer_cfg = WriterConfig {
        basket_entries: cfg.block,
        compression: cfg.compression,
        flush: FlushMode::Serial, // the whole point: single-threaded output
        ..Default::default()
    };
    let mut writer = TreeWriter::new(schema.clone(), sink, writer_cfg);
    if let Some(r) = &recorder {
        writer = writer.with_recorder(r.clone());
    }

    let (tx, rx) = sync_channel::<Vec<ColumnData>>(cfg.queue_depth.max(1));
    let stored = AtomicU64::new(0);
    let errs: std::sync::Mutex<Vec<Error>> = std::sync::Mutex::new(Vec::new());

    std::thread::scope(|s| {
        // Output thread: does ALL serialisation + compression + writes.
        let out_handle = s.spawn(move || -> Result<(FileSink, u64, WriteStats)> {
            while let Ok(block) = rx.recv() {
                writer.fill_columns(&block)?;
            }
            writer.close()
        });
        for stream in 0..cfg.streams {
            let tx = tx.clone();
            let recorder = recorder.clone();
            let errs = &errs;
            s.spawn(move || {
                for i in 0..cfg.blocks_per_stream {
                    let out = match &recorder {
                        Some(r) => {
                            r.record(SpanKind::Generate, || gen_block(cfg, engine, stream, i))
                        }
                        None => gen_block(cfg, engine, stream, i),
                    };
                    match out {
                        Ok(block) => {
                            let send = || tx.send(block);
                            let sent = match &recorder {
                                Some(r) => r.record(SpanKind::Running, send),
                                None => send(),
                            };
                            if sent.is_err() {
                                return; // output thread died; error surfaces there
                            }
                        }
                        Err(e) => {
                            errs.lock().unwrap().push(e);
                            return;
                        }
                    }
                }
            });
        }
        drop(tx);
        match out_handle.join().map_err(|_| Error::Coordinator("output thread panicked".into())) {
            Ok(Ok((sink, entries, _stats))) => {
                let meta = sink.into_meta("events".into(), schema.clone(), entries)?;
                stored.store(
                    meta.branches.iter().map(|b| b.stored_bytes()).sum(),
                    Ordering::Relaxed,
                );
                fw.finish(&Directory { trees: vec![meta] }).map(|_| ())
            }
            Ok(Err(e)) => Err(e),
            Err(e) => Err(e),
        }
    })?;

    if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
        return Err(e);
    }
    Ok(FrameworkReport {
        events: (cfg.streams * cfg.blocks_per_stream * cfg.block) as u64,
        raw_bytes: raw_bytes_of(cfg),
        stored_bytes: stored.load(Ordering::Relaxed),
        wall: t0.elapsed(),
    })
}

fn run_imt_merger(
    cfg: &FrameworkConfig,
    backend: BackendRef,
    engine: Option<&Engine>,
    recorder: Option<Arc<Recorder>>,
) -> Result<FrameworkReport> {
    let t0 = Instant::now();
    let schema = cfg.dataset.schema();
    let merger_cfg = MergerConfig {
        tree_name: "events".into(),
        queue_depth: cfg.queue_depth,
        writer: WriterConfig {
            basket_entries: cfg.block,
            compression: cfg.compression,
            // streams keep filling while their baskets compress on the
            // IMT pool (falls back to inline when IMT is off)
            flush: FlushMode::Pipelined,
            ..Default::default()
        },
    };
    // One I/O session for the whole run: every stream's writer shares
    // the pool and a budget sized for the stream count, so N streams
    // cannot oversubscribe the IMT pool the way N private writer
    // groups did.
    let session = Session::new(SessionConfig::for_writers(
        cfg.streams.max(1),
        merger_cfg.writer.max_inflight_clusters,
    ));
    let merger = TBufferMerger::create_in_session(
        backend,
        schema,
        merger_cfg,
        recorder.clone(),
        &session,
    )?;
    let errs: std::sync::Mutex<Vec<Error>> = std::sync::Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for stream in 0..cfg.streams {
            let mut file = merger.get_file();
            let recorder = recorder.clone();
            let errs = &errs;
            s.spawn(move || {
                let mut work = || -> Result<()> {
                    for i in 0..cfg.blocks_per_stream {
                        let block = match &recorder {
                            Some(r) => r.record(SpanKind::Generate, || {
                                gen_block(cfg, engine, stream, i)
                            })?,
                            None => gen_block(cfg, engine, stream, i)?,
                        };
                        // fill serialises+compresses on this stream thread
                        file.fill_columns(&block)?;
                    }
                    file.write()
                };
                if let Err(e) = work() {
                    errs.lock().unwrap().push(e);
                }
            });
        }
    });
    if let Some(e) = errs.into_inner().unwrap().into_iter().next() {
        return Err(e);
    }
    let stats = merger.close()?;
    Ok(FrameworkReport {
        events: stats.entries,
        raw_bytes: raw_bytes_of(cfg),
        stored_bytes: stats.stored_bytes,
        wall: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::format::reader::FileReader;
    use crate::storage::mem::MemBackend;
    use crate::tree::reader::TreeReader;

    fn cfg(output: OutputMode) -> FrameworkConfig {
        FrameworkConfig {
            streams: 3,
            blocks_per_stream: 2,
            block: 256,
            dataset: DatasetKind::Aod,
            output,
            compression: Settings::new(Codec::Lz4r, 3),
            queue_depth: 4,
        }
    }

    #[test]
    fn no_output_counts_events() {
        let be = Arc::new(MemBackend::new());
        let rep = run(&cfg(OutputMode::None), be, None, None).unwrap();
        assert_eq!(rep.events, 3 * 2 * 256);
        assert_eq!(rep.stored_bytes, 0);
        assert!(rep.events_per_sec() > 0.0);
    }

    #[test]
    fn serial_output_writes_valid_file() {
        let be = Arc::new(MemBackend::new());
        let rep = run(&cfg(OutputMode::SerialOutput), be.clone(), None, None).unwrap();
        assert_eq!(rep.events, 1536);
        assert!(rep.stored_bytes > 0);
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(r.entries(), 1536);
        assert_eq!(r.n_branches(), 12);
    }

    #[test]
    fn imt_merger_writes_valid_file() {
        let be = Arc::new(MemBackend::new());
        let rep = run(&cfg(OutputMode::ImtMerger), be.clone(), None, None).unwrap();
        assert_eq!(rep.events, 1536);
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(r.entries(), 1536);
        let cols = r.read_all().unwrap();
        assert_eq!(cols.len(), 12);
        assert_eq!(cols[0].len(), 1536);
    }

    #[test]
    fn both_output_modes_store_same_multiset() {
        use crate::serial::value::Value;
        let collect = |mode| {
            let be = Arc::new(MemBackend::new());
            run(&cfg(mode), be.clone(), None, None).unwrap();
            let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
            let cols = r.read_all().unwrap();
            let mut vals: Vec<u32> = (0..r.entries() as usize)
                .map(|i| match cols[0].get(i).unwrap() {
                    Value::F32(v) => v.to_bits(),
                    _ => unreachable!(),
                })
                .collect();
            vals.sort();
            vals
        };
        assert_eq!(collect(OutputMode::SerialOutput), collect(OutputMode::ImtMerger));
    }
}
