//! Dataset chains: one logical event stream over N files.
//!
//! Real analyses rarely read one file — a dataset is a *chain* of
//! hundreds of files with identical schemas (ROOT's `TChain`).
//! [`Chain`] walks them as one stream of row [`Batch`]es on top of the
//! per-file [`ClusterStream`]s, with two properties the naive
//! file-at-a-time loop lacks:
//!
//! * **Cross-file pipelining** — all files share one [`Session`] (one
//!   read budget, one completion domain), and the next file's stream
//!   is opened and [`ClusterStream::prime`]d while the current file's
//!   tail clusters are still decoding, so the first cross-boundary
//!   window is already in flight when the boundary is crossed: no
//!   inter-file stall bubble.
//! * **Predicate pushdown** — [`Chain::scan_where`] threads a
//!   [`Predicate`] down to every file's fetch plan, where wire-v4 zone
//!   maps prune whole row-aligned pages before any byte is fetched
//!   ([`crate::cache::plan`]); the surviving rows are then filtered
//!   exactly with the same predicate, so the result is row-identical
//!   to an unpruned scan filtered row by row. Files without zones
//!   (wire v1–v3) simply scan unpruned — the residual filter alone
//!   keeps them exact.
//!
//! Accounting sums across files ([`ChainReport`]): the projection
//! split (`bytes_selected`/`bytes_skipped`) plus the pruning saving
//! (`pages_pruned`/`bytes_pruned`) partition the chain's stored bytes.

use std::sync::Arc;

use crate::cache::plan::Predicate;
use crate::cache::{ClusterStream, PrefetchOptions, PrefetchStats};
use crate::error::{Error, Result};
use crate::format::reader::FileReader;
use crate::metrics::{Recorder, SpanKind};
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::session::{Session, SessionConfig};
use crate::storage::BackendRef;
use crate::tree::reader::TreeReader;
use crate::tree::sizer::SizerSummary;

/// Open + prime the next file once this many clusters remain in the
/// current one: deep enough that the footer read and first window
/// fetch overlap the current tail's decode, shallow enough that the
/// speculative stream holds budget slots only briefly.
const TAIL_PRIME_CLUSTERS: usize = 2;

/// One row batch a chain scan delivers — a decoded cluster in
/// chain-global coordinates, after predicate filtering.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Index of the file this batch came from.
    pub file: usize,
    /// Cluster index within that file.
    pub cluster: usize,
    /// Chain-global first entry of the cluster (pre-filter
    /// coordinates: file bases accumulate whole trees, so the value is
    /// stable whether or not rows were pruned or filtered out).
    pub first_entry: u64,
    /// Selected columns in selection order, equal-length for
    /// writer-produced (cluster-aligned) files. Under
    /// [`Chain::scan_where`] only the predicate's surviving rows
    /// remain.
    pub columns: Vec<ColumnData>,
}

impl Batch {
    /// Rows this batch carries (length of the first column).
    pub fn rows(&self) -> usize {
        self.columns.first().map_or(0, |c| c.len())
    }
}

/// Accounting for one chain scan, summed over every file.
#[derive(Clone, Debug, Default)]
pub struct ChainReport {
    /// Files scanned (empty trees included).
    pub files: u64,
    /// Lead-branch entries the chain covers — pruned and filtered rows
    /// count, so the value is independent of any predicate.
    pub entries: u64,
    /// Rows delivered to the consumer (after pruning + residual
    /// filtering; equals `entries` for a plain [`Chain::scan`]).
    pub rows: u64,
    /// Clusters streamed (pruned-empty ones included).
    pub clusters: u64,
    /// Prefetcher accounting summed across files (byte partition,
    /// pruning counters, stall/decode clocks, window band).
    pub prefetch: PrefetchStats,
}

/// Sum per-file prefetch accounting into a chain-wide total. Counters
/// and clocks add; the window band merges (min of mins, max of maxes,
/// last file's closing target).
fn add_stats(total: &mut PrefetchStats, file: &PrefetchStats) {
    total.clusters += file.clusters;
    total.baskets += file.baskets;
    total.device_reads += file.device_reads;
    total.stored_bytes += file.stored_bytes;
    total.bytes_selected += file.bytes_selected;
    total.bytes_skipped += file.bytes_skipped;
    total.pages_pruned += file.pages_pruned;
    total.bytes_pruned += file.bytes_pruned;
    total.fetch_stall += file.fetch_stall;
    total.fetch_time += file.fetch_time;
    total.decode_time += file.decode_time;
    total.admission_denials += file.admission_denials;
    total.retries += file.retries;
    total.hedges += file.hedges;
    total.hedge_wins += file.hedge_wins;
    total.deadline_misses += file.deadline_misses;
    total.degraded_windows += file.degraded_windows;
    total.window = merge_window(&total.window, &file.window);
}

fn merge_window(a: &SizerSummary, b: &SizerSummary) -> SizerSummary {
    if b.clusters == 0 {
        return *a;
    }
    if a.clusters == 0 {
        return *b;
    }
    SizerSummary {
        min_entries: a.min_entries.min(b.min_entries),
        max_entries: a.max_entries.max(b.max_entries),
        last_entries: b.last_entries,
        grows: a.grows + b.grows,
        shrinks: a.shrinks + b.shrinks,
        clusters: a.clusters + b.clusters,
    }
}

/// Per-row scalar view of a numeric column, in the same `f64` domain
/// zone maps and [`Predicate::matches`] compare in — the residual
/// filter and the pruning pass therefore agree exactly.
fn scalar_at(col: &ColumnData, i: usize) -> Option<f64> {
    match col {
        ColumnData::I32(v) => v.get(i).map(|&x| x as f64),
        ColumnData::I64(v) => v.get(i).map(|&x| x as f64),
        ColumnData::F32(v) => v.get(i).map(|&x| x as f64),
        ColumnData::F64(v) => v.get(i).copied(),
        ColumnData::U8(v) => v.get(i).map(|&x| f64::from(x)),
        _ => None,
    }
}

/// Keep only the rows `keep` marks, preserving order and type.
fn filter_rows(col: &ColumnData, keep: &[bool]) -> ColumnData {
    fn pick<T: Clone>(v: &[T], keep: &[bool]) -> Vec<T> {
        v.iter().zip(keep).filter(|&(_, &k)| k).map(|(x, _)| x.clone()).collect()
    }
    match col {
        ColumnData::I32(v) => ColumnData::I32(pick(v, keep)),
        ColumnData::I64(v) => ColumnData::I64(pick(v, keep)),
        ColumnData::F32(v) => ColumnData::F32(pick(v, keep)),
        ColumnData::F64(v) => ColumnData::F64(pick(v, keep)),
        ColumnData::U8(v) => ColumnData::U8(pick(v, keep)),
        ColumnData::Bytes(v) => ColumnData::Bytes(pick(v, keep)),
        ColumnData::ListF32(v) => ColumnData::ListF32(pick(v, keep)),
    }
}

/// A chain of same-schema files scanned as one event stream.
pub struct Chain {
    files: Vec<BackendRef>,
    /// Recorder the scan's private session adopts (disabled by
    /// default): file transitions emit [`SpanKind::ChainAdvance`]
    /// spans, and every layer below — pool tasks, admission waits,
    /// fetches, decodes — traces into the same buffers.
    recorder: Recorder,
}

/// One file's open stream plus its tree's entry count (the chain-
/// global base advances by whole trees).
struct Cursor {
    stream: ClusterStream,
    entries: u64,
}

impl Chain {
    pub fn new(files: Vec<BackendRef>) -> Chain {
        Chain { files, recorder: Recorder::disabled() }
    }

    /// Trace this chain's scans into `recorder`: the scan session (and
    /// so the pool, budgets, prefetchers and backends under it) emits
    /// spans there, plus a [`SpanKind::ChainAdvance`] span per file
    /// transition.
    pub fn with_recorder(mut self, recorder: Recorder) -> Chain {
        self.recorder = recorder;
        self
    }

    pub fn push(&mut self, file: BackendRef) {
        self.files.push(file);
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    /// Stream every file's clusters in chain order, handing each
    /// decoded cluster to `f` as a [`Batch`] and dropping it — flat
    /// memory however long the chain. Empty trees mid-chain deliver no
    /// batches and do not interrupt the stream.
    pub fn scan(
        &self,
        opts: &PrefetchOptions,
        mut f: impl FnMut(&Batch),
    ) -> Result<ChainReport> {
        self.scan_inner(opts, &mut |b| {
            f(b);
            Ok(b.rows() as u64)
        })
    }

    /// As [`Chain::scan`], keeping only rows matching `predicate`
    /// (`branch op constant`). The predicate is pushed down into every
    /// file's fetch plan — zone-mapped pages that provably contain no
    /// matching row are never fetched — and re-applied row by row to
    /// the survivors, so the delivered rows are exactly the matching
    /// rows, pruned or not. Batches with no surviving rows are not
    /// delivered.
    ///
    /// The predicate branch is fetched even when the selection omits
    /// it (the filter needs its values) but only selected columns
    /// appear in the batches.
    pub fn scan_where(
        &self,
        predicate: Predicate,
        opts: &PrefetchOptions,
        mut f: impl FnMut(&Batch),
    ) -> Result<ChainReport> {
        // Extend the selection with the predicate branch when absent;
        // the extra column is dropped from batches after filtering.
        let out_cols = match &opts.branches {
            None => None, // all branches — the predicate branch is one of them
            Some(sel) => match sel.iter().position(|&b| b == predicate.branch) {
                Some(_) => Some(sel.clone()),
                None => {
                    let mut extended = sel.clone();
                    extended.push(predicate.branch);
                    Some(extended)
                }
            },
        };
        let n_out = opts.branches.as_ref().map(|s| s.len());
        let opts = PrefetchOptions {
            branches: out_cols,
            predicate: Some(predicate),
            ..opts.clone()
        };
        self.scan_inner(&opts, &mut |b| {
            // Predicate slot: its position in the (possibly extended)
            // selection; with branches=None the selection is identity.
            let pred_slot = match &opts.branches {
                None => predicate.branch,
                Some(sel) => sel
                    .iter()
                    .position(|&x| x == predicate.branch)
                    .expect("predicate branch is always in the extended selection"),
            };
            let pred_col = &b.columns[pred_slot];
            let n = pred_col.len();
            if b.columns.iter().any(|c| c.len() != n) {
                return Err(Error::Coordinator(
                    "chain: misaligned cluster columns cannot be row-filtered \
                     (branches disagree on the cluster's row count)"
                        .into(),
                ));
            }
            let keep: Vec<bool> = (0..n)
                .map(|i| {
                    scalar_at(pred_col, i).is_some_and(|v| predicate.matches(v))
                })
                .collect();
            let rows = keep.iter().filter(|&&k| k).count();
            if rows == 0 {
                return Ok(0);
            }
            let filtered = Batch {
                file: b.file,
                cluster: b.cluster,
                first_entry: b.first_entry,
                columns: b
                    .columns
                    .iter()
                    .take(n_out.unwrap_or(b.columns.len()))
                    .map(|c| filter_rows(c, &keep))
                    .collect(),
            };
            f(&filtered);
            Ok(rows as u64)
        })
    }

    /// Scan core shared by [`Chain::scan`] and [`Chain::scan_where`]:
    /// one shared session, per-file streams, and the tail-primed
    /// cross-file handoff. The `scan` path wraps its callback to
    /// deliver every batch unfiltered.
    fn scan_inner(
        &self,
        opts: &PrefetchOptions,
        deliver: &mut dyn FnMut(&Batch) -> Result<u64>,
    ) -> Result<ChainReport> {
        // Twice the window: the budget must admit the current file's
        // tail *and* the next file's primed head at once, or the
        // handoff would serialise behind the tail's slots.
        let session = Session::new(SessionConfig {
            max_inflight_read_windows: (opts.window.max_window() * 2).max(2),
            recorder: self.recorder.clone(),
            ..Default::default()
        });
        let rec = session.recorder().clone();
        let mut report = ChainReport::default();
        let mut schema: Option<Schema> = None;
        let mut base = 0u64;
        let mut pending: Option<Cursor> = None;
        for fi in 0..self.files.len() {
            let mut cur = match pending.take() {
                Some(c) => c,
                None => {
                    let start = rec.is_enabled().then(|| rec.elapsed());
                    let c = self.open_file(fi, opts, &session, &mut schema)?;
                    if let Some(s) = start {
                        rec.push(SpanKind::ChainAdvance, s, rec.elapsed());
                    }
                    c
                }
            };
            let mut consumed = 0usize;
            loop {
                // Near the tail (or on an empty tree): open + prime
                // the next file so its first window fetch overlaps the
                // remaining decode work.
                if pending.is_none()
                    && fi + 1 < self.files.len()
                    && cur.stream.n_clusters() - consumed <= TAIL_PRIME_CLUSTERS
                {
                    let start = rec.is_enabled().then(|| rec.elapsed());
                    let mut next =
                        self.open_file(fi + 1, opts, &session, &mut schema)?;
                    next.stream.prime();
                    if let Some(s) = start {
                        rec.push(SpanKind::ChainAdvance, s, rec.elapsed());
                    }
                    pending = Some(next);
                }
                let Some(cluster) = cur.stream.next()? else { break };
                consumed += 1;
                report.entries += cluster.entries;
                report.clusters += 1;
                let batch = Batch {
                    file: fi,
                    cluster: cluster.index,
                    first_entry: base + cluster.first_entry,
                    columns: cluster.columns,
                };
                report.rows += deliver(&batch)?;
            }
            add_stats(&mut report.prefetch, &cur.stream.stats());
            report.files += 1;
            base += cur.entries;
        }
        Ok(report)
    }

    /// Open file `fi`'s first tree as a stream in the shared session,
    /// checking its schema matches the chain's.
    fn open_file(
        &self,
        fi: usize,
        opts: &PrefetchOptions,
        session: &Session,
        schema: &mut Option<Schema>,
    ) -> Result<Cursor> {
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(self.files[fi].clone())?))?;
        let meta = reader.meta();
        match schema {
            None => *schema = Some(meta.schema.clone()),
            Some(s) if *s == meta.schema => {}
            Some(_) => {
                return Err(Error::Coordinator(format!(
                    "chain: file {fi} ('{}') has a different schema from the \
                     chain's first file",
                    meta.name
                )));
            }
        }
        let entries = reader.entries();
        let stream = ClusterStream::open_in_session(&reader, opts, session)?;
        Ok(Cursor { stream, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::WindowPolicy;
    use crate::compress::{Codec, Settings};
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::schema::Schema;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::reader::TreeReader;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};

    /// Write one file: 2 f32 branches, branch 0 = `start + i`, branch
    /// 1 = `-(start + i)`, at the given wire version.
    fn file_v(start: u64, entries: usize, basket: usize, version: u32) -> BackendRef {
        let schema = Schema::flat_f32("c", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create_versioned(be.clone(), version).unwrap());
        let sink = FileSink::new(fw.clone(), 2);
        let cfg = WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Lz4r, 2),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..entries {
            let x = (start + i as u64) as f32;
            w.fill(vec![Value::F32(x), Value::F32(-x)]).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        be
    }

    fn file(start: u64, entries: usize, basket: usize) -> BackendRef {
        file_v(start, entries, basket, crate::format::VERSION)
    }

    /// Branch-0 values of a chain, read file by file through the plain
    /// serial path.
    fn all_values(files: &[BackendRef]) -> Vec<f32> {
        let mut out = Vec::new();
        for be in files {
            let r = TreeReader::open_first(Arc::new(FileReader::open(be.clone()).unwrap()))
                .unwrap();
            if let ColumnData::F32(v) = &r.read_all().unwrap()[0] {
                out.extend_from_slice(v);
            }
        }
        out
    }

    #[test]
    fn chain_scan_concatenates_files_in_order() {
        let files = vec![file(0, 300, 100), file(300, 250, 100), file(550, 100, 100)];
        let chain = Chain::new(files.clone());
        let mut got: Vec<f32> = Vec::new();
        let mut last_first = None;
        let rep = chain
            .scan(&PrefetchOptions::default(), |b| {
                if let Some(p) = last_first {
                    assert!(b.first_entry > p, "batches arrive in chain-global entry order");
                }
                last_first = Some(b.first_entry);
                assert_eq!(b.columns.len(), 2);
                assert_eq!(b.rows(), b.columns[1].len());
                if let ColumnData::F32(v) = &b.columns[0] {
                    got.extend_from_slice(v);
                }
            })
            .unwrap();
        assert_eq!(rep.files, 3);
        assert_eq!(rep.entries, 650);
        assert_eq!(rep.rows, 650);
        assert_eq!(rep.clusters, 3 + 3 + 1);
        assert_eq!(got, all_values(&files));
        // The whole chain was fetched: the byte partition is exact.
        assert_eq!(rep.prefetch.pages_pruned, 0);
        assert_eq!(rep.prefetch.bytes_skipped, 0);
        assert_eq!(rep.prefetch.stored_bytes, rep.prefetch.bytes_selected);
    }

    #[test]
    fn chain_pipelines_across_file_boundaries_on_a_pool() {
        let files: Vec<BackendRef> =
            (0..5).map(|k| file(k * 400, 400, 100)).collect();
        let chain = Chain::new(files.clone());
        // The chain builds its own session internally; it binds to the
        // global IMT pool, so enable it for real cross-file overlap.
        crate::imt::enable(3);
        let mut got: Vec<f32> = Vec::new();
        let rep = chain
            .scan(
                &PrefetchOptions { window: WindowPolicy::Fixed(3), ..Default::default() },
                |b| {
                    if let ColumnData::F32(v) = &b.columns[0] {
                        got.extend_from_slice(v);
                    }
                },
            )
            .unwrap();
        crate::imt::disable();
        assert_eq!(rep.entries, 2000);
        assert_eq!(got, all_values(&files));
        assert_eq!(rep.prefetch.clusters, 20);
    }

    /// Satellite regression: zero-entry files anywhere in the chain —
    /// first, middle, or everywhere — must neither fuse the stream nor
    /// skew the accounting.
    #[test]
    fn empty_files_anywhere_do_not_fuse_or_skew() {
        let empty = || file(0, 0, 100);
        let shapes: [(Vec<BackendRef>, u64, u64); 4] = [
            (vec![empty(), file(0, 200, 100), file(200, 100, 100)], 300, 5),
            (vec![file(0, 200, 100), empty(), file(200, 100, 100)], 300, 5),
            (vec![file(0, 200, 100), file(200, 100, 100), empty()], 300, 5),
            (vec![empty(), empty(), empty()], 0, 0),
        ];
        for (files, want_entries, want_clusters) in shapes {
            let n_files = files.len() as u64;
            let chain = Chain::new(files.clone());
            let mut got: Vec<f32> = Vec::new();
            let rep = chain
                .scan(&PrefetchOptions::default(), |b| {
                    if let ColumnData::F32(v) = &b.columns[0] {
                        got.extend_from_slice(v);
                    }
                })
                .unwrap();
            assert_eq!(rep.files, n_files, "every file visited, empty or not");
            assert_eq!(rep.entries, want_entries);
            assert_eq!(rep.rows, want_entries);
            assert_eq!(rep.clusters, want_clusters);
            assert_eq!(got, all_values(&files));
        }
    }

    #[test]
    fn scan_where_is_row_identical_to_filtering_an_unpruned_scan() {
        // Monotonic values 0..900 over 3 files: `x >= 600` lives
        // entirely in file 2, so files 0 and 1 prune wholesale.
        let files = vec![file(0, 300, 100), file(300, 300, 100), file(600, 300, 100)];
        let chain = Chain::new(files.clone());
        let pred = Predicate::ge(0, 600.0);
        let mut got: Vec<f32> = Vec::new();
        let mut got_neg: Vec<f32> = Vec::new();
        let rep = chain
            .scan_where(pred, &PrefetchOptions::default(), |b| {
                assert_eq!(b.columns.len(), 2, "full selection, no appended column");
                if let ColumnData::F32(v) = &b.columns[0] {
                    got.extend_from_slice(v);
                }
                if let ColumnData::F32(v) = &b.columns[1] {
                    got_neg.extend_from_slice(v);
                }
            })
            .unwrap();
        let want: Vec<f32> =
            all_values(&files).into_iter().filter(|&x| x >= 600.0).collect();
        assert_eq!(got, want, "pruned+filtered == unpruned-then-filtered");
        let want_neg: Vec<f32> = want.iter().map(|&x| -x).collect();
        assert_eq!(got_neg, want_neg, "sibling columns filtered row-identically");
        assert_eq!(rep.rows, 300);
        assert_eq!(rep.entries, 900, "entries count the whole chain, not survivors");
        assert!(rep.prefetch.pages_pruned > 0, "zones must have pruned pages");
        assert!(rep.prefetch.bytes_pruned > 0);
        // selected + pruned + skipped partition the chain's bytes.
        let full = chain.scan(&PrefetchOptions::default(), |_| {}).unwrap();
        assert_eq!(
            rep.prefetch.bytes_selected
                + rep.prefetch.bytes_pruned
                + rep.prefetch.bytes_skipped,
            full.prefetch.bytes_selected,
            "byte partition across the chain"
        );
        assert!(
            rep.prefetch.bytes_selected < full.prefetch.bytes_selected / 2,
            "a 1-in-3 predicate must cut fetched bytes well below half: {} of {}",
            rep.prefetch.bytes_selected,
            full.prefetch.bytes_selected
        );
    }

    #[test]
    fn scan_where_fetches_but_does_not_emit_an_unselected_predicate_branch() {
        let files = vec![file(0, 200, 100), file(200, 200, 100)];
        let chain = Chain::new(files.clone());
        // Project branch 1 only; the predicate rides branch 0.
        let opts = PrefetchOptions { branches: Some(vec![1]), ..Default::default() };
        let mut got: Vec<f32> = Vec::new();
        let rep = chain
            .scan_where(Predicate::lt(0, 100.0), &opts, |b| {
                assert_eq!(b.columns.len(), 1, "predicate column dropped from batches");
                if let ColumnData::F32(v) = &b.columns[0] {
                    got.extend_from_slice(v);
                }
            })
            .unwrap();
        let want: Vec<f32> = all_values(&files)
            .into_iter()
            .filter(|&x| x < 100.0)
            .map(|x| -x)
            .collect();
        assert_eq!(got, want);
        assert_eq!(rep.rows, 100);
        assert!(rep.prefetch.pages_pruned > 0, "file 2 prunes entirely");
    }

    /// Zone-less wire versions still chain-scan with predicates: no
    /// pruning, but the residual filter keeps the rows exact — and
    /// mixed-version chains compose.
    #[test]
    fn v1_and_v2_files_chain_scan_without_zones() {
        for version in [1u32, 2] {
            let files =
                vec![file_v(0, 300, 100, version), file_v(300, 300, 100, version)];
            let chain = Chain::new(files.clone());
            let mut got: Vec<f32> = Vec::new();
            let rep = chain
                .scan_where(Predicate::ge(0, 450.0), &PrefetchOptions::default(), |b| {
                    if let ColumnData::F32(v) = &b.columns[0] {
                        got.extend_from_slice(v);
                    }
                })
                .unwrap();
            let want: Vec<f32> =
                all_values(&files).into_iter().filter(|&x| x >= 450.0).collect();
            assert_eq!(got, want, "wire v{version}");
            assert_eq!(rep.prefetch.pages_pruned, 0, "v{version} has no zones");
            assert_eq!(rep.prefetch.bytes_pruned, 0);
        }
        // Mixed chain: a zone-less v2 file between two v4 files prunes
        // where it can and filters everywhere.
        let files =
            vec![file(0, 300, 100), file_v(300, 300, 100, 2), file(600, 300, 100)];
        let chain = Chain::new(files.clone());
        let mut got: Vec<f32> = Vec::new();
        let rep = chain
            .scan_where(Predicate::lt(0, 150.0), &PrefetchOptions::default(), |b| {
                if let ColumnData::F32(v) = &b.columns[0] {
                    got.extend_from_slice(v);
                }
            })
            .unwrap();
        let want: Vec<f32> =
            all_values(&files).into_iter().filter(|&x| x < 150.0).collect();
        assert_eq!(got, want);
        assert!(rep.prefetch.pages_pruned > 0, "the v4 files still prune");
    }

    #[test]
    fn mismatched_schema_is_an_error() {
        let a = file(0, 100, 100);
        let b: BackendRef = {
            let schema = Schema::flat_f32("other", 3);
            let be: BackendRef = Arc::new(MemBackend::new());
            let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
            let sink = FileSink::new(fw.clone(), 3);
            let mut w = TreeWriter::new(
                schema.clone(),
                sink,
                WriterConfig {
                    basket_entries: 64,
                    flush: FlushMode::Serial,
                    ..Default::default()
                },
            );
            for i in 0..100 {
                w.fill(vec![
                    Value::F32(i as f32),
                    Value::F32(i as f32),
                    Value::F32(i as f32),
                ])
                .unwrap();
            }
            let (sink, n, _) = w.close().unwrap();
            let meta = sink.into_meta("t".into(), schema, n).unwrap();
            fw.finish(&Directory { trees: vec![meta] }).unwrap();
            be
        };
        let chain = Chain::new(vec![a, b]);
        let err = chain.scan(&PrefetchOptions::default(), |_| {}).unwrap_err();
        assert!(err.to_string().contains("different schema"), "{err}");
    }

    #[test]
    fn empty_chain_scans_to_nothing() {
        let chain = Chain::new(Vec::new());
        assert!(chain.is_empty());
        let rep = chain.scan(&PrefetchOptions::default(), |_| panic!("no batches")).unwrap();
        assert_eq!(rep.files, 0);
        assert_eq!(rep.entries, 0);
        assert_eq!(rep.clusters, 0);
    }
}
