//! Simulated remote object store with heavy-tailed latency and
//! injectable transient faults.
//!
//! Local devices ([`super::sim::SimDevice`]) model a single command
//! queue where bandwidth dominates. Remote HEP storage behaves
//! differently: requests run concurrently up to a connection-pool
//! bound, every request pays a *first-byte* latency drawn from a
//! heavy-tailed (lognormal) distribution, and a small fraction of
//! requests misbehave — they time out, return 5xx-style retryable
//! errors, deliver short reads, or get *stuck* far beyond p99 (the
//! case hedged reads rescue). All of it is deterministic from a seed:
//! latency and fault draws hash the request index, never the wall
//! clock.
//!
//! Two fault schedules:
//! * `fault_rate` — seeded per-request probability (realistic mix);
//! * `fault_every_nth` — every n-th request faults, making the fault
//!   *count* a pure function of the request count, independent of
//!   thread interleaving. Tests use this to assert exact recovery
//!   behaviour without flakiness.
//!
//! As with `SimDevice`, `time_scale` scales all modelled latencies:
//! 1.0 sleeps in real time, 0.0 only accounts. Per-request deadlines
//! ([`IoHints::deadline`]) are compared against the *scaled* service
//! time: a request that would outlive its deadline sleeps out only the
//! deadline and fails with [`Error::Timeout`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};

use super::fault::{mix, unit};
use super::mem::MemBackend;
use super::sim::{lock, DeviceStats};
use super::{Backend, CostHint, IoHints};

/// Knobs for a [`RemoteDevice`]. Defaults model a reasonably healthy
/// WAN object store: 8 ms median first byte with a 40 ms p99 tail,
/// 16 concurrent request slots, no injected faults.
#[derive(Clone, Copy, Debug)]
pub struct RemoteConfig {
    pub read_mbps: f64,
    pub write_mbps: f64,
    /// Median first-byte latency (lognormal).
    pub first_byte_p50: Duration,
    /// 99th-percentile first-byte latency; together with p50 this
    /// fixes the lognormal's shape.
    pub first_byte_p99: Duration,
    /// Bounded concurrent request slots (connection pool). Further
    /// requests queue, and their wait is recorded in
    /// [`DeviceStats::queue_wait`].
    pub request_slots: usize,
    /// Seed for every latency and fault draw.
    pub seed: u64,
    /// Per-request transient fault probability (0 disables).
    pub fault_rate: f64,
    /// When > 0, overrides `fault_rate` with a deterministic-count
    /// schedule: request indices n-1, 2n-1, ... fault.
    pub fault_every_nth: u64,
    /// Relative weights of fault flavours (need not sum to 1; the
    /// remainder after timeout/short/stuck is a 5xx-style retryable
    /// error).
    pub timeout_weight: f64,
    pub short_read_weight: f64,
    pub stuck_weight: f64,
    /// A stuck request is served successfully after
    /// `stuck_factor` × its normal service time.
    pub stuck_factor: f64,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            read_mbps: 200.0,
            write_mbps: 120.0,
            first_byte_p50: Duration::from_millis(8),
            first_byte_p99: Duration::from_millis(40),
            request_slots: 16,
            seed: 0,
            fault_rate: 0.0,
            fault_every_nth: 0,
            timeout_weight: 0.25,
            short_read_weight: 0.25,
            stuck_weight: 0.25,
            stuck_factor: 10.0,
        }
    }
}

enum FaultDraw {
    None,
    /// 5xx-style retryable error after a median first byte.
    Retryable,
    /// Request never completes: fails `TimedOut` after a long wait
    /// (or `Error::Timeout` as soon as the caller's deadline cuts it).
    Timeout,
    /// Device reports fewer bytes delivered than asked.
    ShortRead,
    /// Served correctly, but `stuck_factor` × slower.
    Stuck,
}

struct Slots {
    free: Mutex<usize>,
    cv: Condvar,
}

/// Deterministic, seeded remote object-store simulation.
pub struct RemoteDevice {
    mem: MemBackend,
    cfg: RemoteConfig,
    time_scale: f64,
    slots: Slots,
    requests: AtomicU64,
    stats: Mutex<DeviceStats>,
}

impl RemoteDevice {
    pub fn new(cfg: RemoteConfig, time_scale: f64) -> Self {
        RemoteDevice {
            mem: MemBackend::new(),
            cfg,
            time_scale,
            slots: Slots { free: Mutex::new(cfg.request_slots.max(1)), cv: Condvar::new() },
            requests: AtomicU64::new(0),
            stats: Mutex::new(DeviceStats::default()),
        }
    }

    pub fn config(&self) -> &RemoteConfig {
        &self.cfg
    }

    /// Load bytes into the store without charging latency or faults —
    /// experiments use this to stage a pre-written file remotely.
    pub fn preload(&self, off: u64, data: &[u8]) -> Result<()> {
        self.mem.write_at(off, data)
    }

    /// Per-device counters (same shape as [`super::sim::SimDevice`]),
    /// with first-byte latency recorded as seek time and fault
    /// flavours in the fault fields.
    pub fn device_stats(&self) -> DeviceStats {
        self.stats.lock().map(|s| *s).unwrap_or_default()
    }

    /// Lognormal first-byte latency for request `idx`.
    fn first_byte(&self, idx: u64) -> Duration {
        let p50 = self.cfg.first_byte_p50.as_secs_f64().max(1e-9);
        let p99 = self.cfg.first_byte_p99.as_secs_f64().max(p50);
        let mu = p50.ln();
        // z(0.99) = 2.3263: p99 = exp(mu + 2.3263 sigma)
        let sigma = (p99.ln() - mu) / 2.3263;
        let u1 = unit(mix(self.cfg.seed ^ mix(idx.wrapping_mul(2) + 1))).max(1e-12);
        let u2 = unit(mix(self.cfg.seed ^ mix(idx.wrapping_mul(2) + 2)));
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        Duration::from_secs_f64((mu + sigma * z).exp().min(p99 * 50.0))
    }

    /// Deterministic fault decision for request `idx`.
    fn fault_draw(&self, idx: u64) -> FaultDraw {
        let fires = if self.cfg.fault_every_nth > 0 {
            idx % self.cfg.fault_every_nth == self.cfg.fault_every_nth - 1
        } else if self.cfg.fault_rate > 0.0 {
            unit(mix(self.cfg.seed ^ mix(idx) ^ 0xFA01)) < self.cfg.fault_rate
        } else {
            false
        };
        if !fires {
            return FaultDraw::None;
        }
        let total = (self.cfg.timeout_weight
            + self.cfg.short_read_weight
            + self.cfg.stuck_weight)
            .max(1e-9);
        let scale = total.max(1.0);
        let u = unit(mix(self.cfg.seed ^ mix(idx) ^ 0xFA02)) * scale;
        if u < self.cfg.timeout_weight {
            FaultDraw::Timeout
        } else if u < self.cfg.timeout_weight + self.cfg.short_read_weight {
            FaultDraw::ShortRead
        } else if u < total {
            FaultDraw::Stuck
        } else {
            FaultDraw::Retryable
        }
    }

    fn acquire_slot(&self) -> Result<Duration> {
        let t0 = std::time::Instant::now();
        let mut free = lock(&self.slots.free)?;
        while *free == 0 {
            free = self
                .slots
                .cv
                .wait(free)
                .map_err(|_| Error::Sync("remote slot lock poisoned".into()))?;
        }
        *free -= 1;
        Ok(t0.elapsed())
    }

    fn release_slot(&self) {
        if let Ok(mut free) = self.slots.free.lock() {
            *free += 1;
            self.slots.cv.notify_one();
        }
    }

    fn sleep_scaled(&self, d: Duration) {
        if self.time_scale > 0.0 {
            let scaled = d.mul_f64(self.time_scale);
            if !scaled.is_zero() {
                std::thread::sleep(scaled);
            }
        }
    }

    /// Service one request end to end; `is_write` picks bandwidth and
    /// direction counters. Returns the number of bytes to actually
    /// move (short reads deliver fewer than asked).
    fn service(&self, off: u64, len: usize, hints: IoHints, is_write: bool) -> Result<usize> {
        let waited = self.acquire_slot()?;
        let result = self.service_in_slot(off, len, hints, is_write, waited);
        self.release_slot();
        result
    }

    fn service_in_slot(
        &self,
        off: u64,
        len: usize,
        hints: IoHints,
        is_write: bool,
        waited: Duration,
    ) -> Result<usize> {
        let idx = self.requests.fetch_add(1, Ordering::SeqCst);
        let first = self.first_byte(idx);
        let mbps = if is_write { self.cfg.write_mbps } else { self.cfg.read_mbps };
        let transfer = Duration::from_secs_f64(len as f64 / (mbps * 1e6));
        let draw = self.fault_draw(idx);
        {
            let mut st = lock(&self.stats)?;
            st.seeks += 1;
            st.seek_time += first;
            st.queue_wait += waited;
            if is_write {
                st.writes += 1;
            } else {
                st.reads += 1;
            }
        }
        // Scaled wall-clock service time, capped by the deadline.
        let svc = |d: Duration| d.mul_f64(self.time_scale.max(0.0));
        let deadline_cut = |d: Duration| match hints.deadline {
            Some(dl) if svc(d) > dl => Some(dl),
            _ => None,
        };
        let fail_deadline = |dl: Duration| -> Error {
            if let Ok(mut st) = self.stats.lock() {
                st.timeouts += 1;
            }
            Error::Timeout(format!(
                "remote request {idx} ({len} B at {off}) missed {dl:?} deadline"
            ))
        };
        match draw {
            FaultDraw::None => {
                let total = first + transfer;
                if let Some(dl) = deadline_cut(total) {
                    self.sleep_scaled(dl.div_f64(self.time_scale.max(1e-12)));
                    return Err(fail_deadline(dl));
                }
                self.sleep_scaled(total);
                let mut st = lock(&self.stats)?;
                st.transfer_time += transfer;
                if is_write {
                    st.bytes_written += len as u64;
                } else {
                    st.bytes_read += len as u64;
                }
                Ok(len)
            }
            FaultDraw::Retryable => {
                if let Ok(mut st) = self.stats.lock() {
                    st.faults += 1;
                }
                self.sleep_scaled(first);
                Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    format!("remote request {idx}: transient 5xx"),
                )))
            }
            FaultDraw::Timeout => {
                if let Ok(mut st) = self.stats.lock() {
                    st.faults += 1;
                }
                // Never completes on its own: wait out the deadline if
                // one was given, else a long multiple of p99.
                let stall = self.cfg.first_byte_p99.mul_f64(self.cfg.stuck_factor.max(2.0));
                if let Some(dl) = deadline_cut(stall) {
                    self.sleep_scaled(dl.div_f64(self.time_scale.max(1e-12)));
                    return Err(fail_deadline(dl));
                }
                self.sleep_scaled(stall);
                if let Ok(mut st) = self.stats.lock() {
                    st.timeouts += 1;
                }
                Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    format!("remote request {idx}: timed out"),
                )))
            }
            FaultDraw::ShortRead => {
                if let Ok(mut st) = self.stats.lock() {
                    st.faults += 1;
                    st.short_reads += 1;
                }
                self.sleep_scaled(first);
                if is_write {
                    return Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::Interrupted,
                        format!("remote request {idx}: short write"),
                    )));
                }
                Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    format!("remote request {idx}: short read ({} of {len} B)", len / 2),
                )))
            }
            FaultDraw::Stuck => {
                if let Ok(mut st) = self.stats.lock() {
                    st.faults += 1;
                    st.stuck += 1;
                }
                let total = (first + transfer).mul_f64(self.cfg.stuck_factor.max(1.0));
                if let Some(dl) = deadline_cut(total) {
                    self.sleep_scaled(dl.div_f64(self.time_scale.max(1e-12)));
                    return Err(fail_deadline(dl));
                }
                self.sleep_scaled(total);
                let mut st = lock(&self.stats)?;
                st.transfer_time += transfer;
                if is_write {
                    st.bytes_written += len as u64;
                } else {
                    st.bytes_read += len as u64;
                }
                Ok(len)
            }
        }
    }
}

impl Backend for RemoteDevice {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.read_at_opts(off, buf, IoHints::default())
    }

    fn read_at_opts(&self, off: u64, buf: &mut [u8], hints: IoHints) -> Result<()> {
        self.service(off, buf.len(), hints, false)?;
        self.mem.read_at(off, buf)
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        self.service(off, data.len(), IoHints::default(), true)?;
        self.mem.write_at(off, data)
    }

    fn len(&self) -> Result<u64> {
        self.mem.len()
    }

    fn describe(&self) -> String {
        format!(
            "remote (p50 {:?}, p99 {:?}, {} slots, fault {})",
            self.cfg.first_byte_p50,
            self.cfg.first_byte_p99,
            self.cfg.request_slots,
            if self.cfg.fault_every_nth > 0 {
                format!("1/{}", self.cfg.fault_every_nth)
            } else {
                format!("{:.1}%", self.cfg.fault_rate * 100.0)
            }
        )
    }

    fn cost_hint(&self) -> Option<CostHint> {
        Some(CostHint {
            seek_secs: self.cfg.first_byte_p50.as_secs_f64(),
            read_mbps: self.cfg.read_mbps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(seed: u64) -> RemoteConfig {
        RemoteConfig { seed, ..RemoteConfig::default() }
    }

    #[test]
    fn data_path_is_exact_without_faults() {
        let d = RemoteDevice::new(quiet(3), 0.0);
        d.write_at(7, b"remote payload").unwrap();
        let mut buf = [0u8; 14];
        d.read_at(7, &mut buf).unwrap();
        assert_eq!(&buf, b"remote payload");
        let st = d.device_stats();
        assert_eq!((st.reads, st.writes), (1, 1));
        assert_eq!(st.faults, 0);
    }

    #[test]
    fn latency_distribution_matches_knobs() {
        let d = RemoteDevice::new(quiet(9), 0.0);
        let mut draws: Vec<f64> =
            (0..2000).map(|i| d.first_byte(i).as_secs_f64()).collect();
        draws.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = draws[draws.len() / 2];
        let p99 = draws[draws.len() * 99 / 100];
        let want50 = d.cfg.first_byte_p50.as_secs_f64();
        let want99 = d.cfg.first_byte_p99.as_secs_f64();
        assert!((p50 / want50 - 1.0).abs() < 0.25, "p50 {p50} vs {want50}");
        assert!(p99 / want99 > 0.5 && p99 / want99 < 2.0, "p99 {p99} vs {want99}");
        assert!(p99 > p50 * 2.0, "heavy tail required");
    }

    #[test]
    fn every_nth_fault_count_is_exact() {
        let cfg = RemoteConfig {
            fault_every_nth: 4,
            // all faults retryable for a simple count
            timeout_weight: 0.0,
            short_read_weight: 0.0,
            stuck_weight: 0.0,
            ..quiet(5)
        };
        let d = RemoteDevice::new(cfg, 0.0);
        d.preload(0, &[9u8; 1024]).unwrap();
        let mut errs = 0;
        let mut buf = [0u8; 16];
        for i in 0..40u64 {
            match d.read_at((i % 8) * 16, &mut buf) {
                Ok(()) => assert_eq!(buf, [9u8; 16]),
                Err(e) => {
                    assert!(e.is_transient(), "retryable fault must be transient: {e}");
                    errs += 1;
                }
            }
        }
        assert_eq!(errs, 10, "exactly every 4th of 40 requests faults");
        assert_eq!(d.device_stats().faults, 10);
    }

    #[test]
    fn seeded_rate_faults_are_deterministic() {
        let run = || {
            let cfg = RemoteConfig { fault_rate: 0.2, ..quiet(21) };
            let d = RemoteDevice::new(cfg, 0.0);
            d.preload(0, &[1u8; 4096]).unwrap();
            let mut buf = [0u8; 32];
            (0..100u64).map(|i| d.read_at(i * 32, &mut buf).is_err()).collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed, same fault schedule");
        let n = a.iter().filter(|&&x| x).count();
        assert!((5..=50).contains(&n), "rate 0.2 over 100 requests, saw {n}");
    }

    #[test]
    fn deadline_cuts_slow_requests() {
        // time_scale 1.0 with tiny latencies: p50 2ms, p99 6ms.
        let cfg = RemoteConfig {
            first_byte_p50: Duration::from_millis(2),
            first_byte_p99: Duration::from_millis(6),
            ..quiet(13)
        };
        let d = RemoteDevice::new(cfg, 1.0);
        d.preload(0, &[4u8; 64]).unwrap();
        let mut buf = [0u8; 16];
        // An impossible deadline: every request misses it.
        let hints = IoHints { deadline: Some(Duration::from_nanos(1)), ..Default::default() };
        let err = d.read_at_opts(0, &mut buf, hints).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "got {err}");
        assert!(err.is_transient());
        assert_eq!(d.device_stats().timeouts, 1);
        // Without a deadline the same request succeeds.
        d.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [4u8; 16]);
    }

    #[test]
    fn slots_bound_concurrency_and_record_wait() {
        use std::sync::Arc;
        let cfg = RemoteConfig {
            request_slots: 1,
            first_byte_p50: Duration::from_millis(3),
            first_byte_p99: Duration::from_millis(4),
            ..quiet(2)
        };
        let d = Arc::new(RemoteDevice::new(cfg, 1.0));
        d.preload(0, &[0u8; 64]).unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let d = d.clone();
                std::thread::spawn(move || {
                    let mut buf = [0u8; 16];
                    d.read_at(0, &mut buf).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let st = d.device_stats();
        assert_eq!(st.reads, 4);
        assert!(
            st.queue_wait > Duration::ZERO,
            "single slot must have queued someone: {:?}",
            st.queue_wait
        );
    }
}
