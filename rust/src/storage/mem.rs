//! Plain in-memory backend (no cost model) — the substrate for
//! `TMemFile` buffers and for unit tests.

use std::sync::RwLock;

use crate::error::{Error, Result};

use super::Backend;

/// Growable in-memory byte device.
pub struct MemBackend {
    data: RwLock<Vec<u8>>,
}

impl MemBackend {
    pub fn new() -> Self {
        MemBackend { data: RwLock::new(Vec::new()) }
    }

    pub fn from_vec(v: Vec<u8>) -> Self {
        MemBackend { data: RwLock::new(v) }
    }

    /// Consume into the underlying buffer (used when shipping a
    /// TMemFile's contents to the merger queue). Tolerates a poisoned
    /// lock: the bytes themselves are always intact.
    pub fn into_vec(self) -> Vec<u8> {
        self.data.into_inner().unwrap_or_else(|p| p.into_inner())
    }

    /// Snapshot of the current contents (poison-tolerant, like
    /// [`MemBackend::into_vec`]).
    pub fn to_vec(&self) -> Vec<u8> {
        self.data.read().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

impl Default for MemBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for MemBackend {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        let data =
            self.data.read().map_err(|_| Error::Sync("mem backend lock poisoned".into()))?;
        let off = off as usize;
        if off + buf.len() > data.len() {
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("read {}..{} beyond end {}", off, off + buf.len(), data.len()),
            )));
        }
        buf.copy_from_slice(&data[off..off + buf.len()]);
        Ok(())
    }

    fn write_at(&self, off: u64, src: &[u8]) -> Result<()> {
        let mut data =
            self.data.write().map_err(|_| Error::Sync("mem backend lock poisoned".into()))?;
        let off = off as usize;
        if off + src.len() > data.len() {
            data.resize(off + src.len(), 0);
        }
        data[off..off + src.len()].copy_from_slice(src);
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        Ok(self
            .data
            .read()
            .map_err(|_| Error::Sync("mem backend lock poisoned".into()))?
            .len() as u64)
    }

    fn describe(&self) -> String {
        "mem".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_extend() {
        let m = MemBackend::new();
        m.write_at(0, b"abc").unwrap();
        m.write_at(10, b"xyz").unwrap();
        assert_eq!(m.len().unwrap(), 13);
        let mut buf = [0u8; 3];
        m.read_at(10, &mut buf).unwrap();
        assert_eq!(&buf, b"xyz");
        // the gap is zero-filled
        m.read_at(3, &mut buf).unwrap();
        assert_eq!(&buf, &[0, 0, 0]);
    }

    #[test]
    fn read_past_end_errors() {
        let m = MemBackend::new();
        m.write_at(0, b"ab").unwrap();
        let mut buf = [0u8; 3];
        assert!(m.read_at(0, &mut buf).is_err());
        assert!(m.read_at(100, &mut buf[..1]).is_err());
    }

    #[test]
    fn overwrite() {
        let m = MemBackend::from_vec(b"hello world".to_vec());
        m.write_at(6, b"rust!").unwrap();
        assert_eq!(m.to_vec(), b"hello rust!");
    }
}
