//! Resilience wrapper: deadlines, retry with backoff, hedged reads,
//! and a circuit breaker over any [`BackendRef`].
//!
//! Remote storage fails in ways a local disk does not: requests blip
//! (5xx), time out, or get stuck far beyond p99. A
//! [`ResilientBackend`] absorbs those faults so the layers above — the
//! prefetcher, the write sink — see either clean data or one final
//! error:
//!
//! * **Deadlines** — every attempt carries a per-request deadline
//!   ([`IoHints::deadline`], the tighter of the caller's and the
//!   configured one); a device that models service time fails the
//!   attempt with [`Error::Timeout`] instead of stalling the pipeline.
//! * **Retry with backoff** — transient failures
//!   ([`Error::is_transient`]) are retried up to
//!   [`RetryPolicy::max_attempts`] with exponential backoff and
//!   seeded, deterministic jitter. Permanent errors surface at once.
//! * **Hedged reads** — when a read has not responded after
//!   [`HedgePolicy::after`] (set it near the device's p99), a
//!   duplicate is launched and the first responder wins; the loser's
//!   slot is released when it finishes. Hedges draw from a bounded
//!   [`MemberBudget`] (the session's `max_hedged_reads`), so tail
//!   rescue can never double the device load.
//! * **Circuit breaker** — a rolling error-rate window; when it trips,
//!   speculative [`ReadPriority::ReadAhead`] traffic is shed with
//!   [`Error::Shed`] while consumer-demanded head reads keep flowing
//!   as half-open probes. The prefetcher reacts to the
//!   [`BackendHealth::Degraded`] signal by shrinking to head-only
//!   fetching instead of erroring.
//!
//! Everything is deterministic in tests: jitter comes from the seeded
//! SplitMix hash, never the wall clock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::imt::{IoBudget, MemberBudget};
use crate::metrics::{Recorder, SpanKind};
use crate::session::Session;

use super::fault::{mix, unit};
use super::sim::lock;
use super::{Backend, BackendHealth, BackendRef, CostHint, IoHints, ReadPriority, ResilienceStats};

/// Retry schedule for transient failures.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Jitter fraction in [0, 1]: each backoff is scaled by a seeded
    /// uniform draw from [1 - jitter, 1].
    pub jitter: f64,
    /// Seed for the jitter draws (deterministic in tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
            jitter: 0.5,
            seed: 0,
        }
    }
}

/// Hedged-read policy: duplicate a read that has not responded after
/// `after` (typically the device's p99 first-byte latency).
#[derive(Clone, Copy, Debug)]
pub struct HedgePolicy {
    /// How long to wait for the primary before hedging.
    pub after: Duration,
}

impl HedgePolicy {
    /// Hedge at the device's p99: by definition ~1% of requests get a
    /// duplicate, the textbook tail-rescue operating point.
    pub fn at_p99(p99: Duration) -> Self {
        HedgePolicy { after: p99 }
    }
}

/// Circuit-breaker tuning.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Rolling outcome window length.
    pub window: usize,
    /// Minimum outcomes before the breaker may judge.
    pub min_samples: usize,
    /// Error fraction (of the window) that opens the breaker.
    pub open_error_rate: f64,
    /// How long the breaker stays open before probing (half-open).
    pub cooldown: Duration,
    /// Consecutive half-open successes required to close again.
    pub half_open_probes: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            window: 32,
            min_samples: 8,
            open_error_rate: 0.5,
            cooldown: Duration::from_millis(100),
            half_open_probes: 3,
        }
    }
}

/// Full configuration of a [`ResilientBackend`].
#[derive(Clone, Copy, Debug)]
pub struct ResilientConfig {
    pub retry: RetryPolicy,
    /// `None` disables hedging (retry-only policy).
    pub hedge: Option<HedgePolicy>,
    /// Per-attempt deadline handed to the device; `None` leaves only
    /// whatever deadline the caller put in its own [`IoHints`].
    pub deadline: Option<Duration>,
    pub breaker: BreakerConfig,
    /// Hedged duplicates this backend may have in flight at once
    /// (also the standalone hedge-budget size when not attached to a
    /// session).
    pub max_hedged_reads: usize,
}

impl Default for ResilientConfig {
    fn default() -> Self {
        ResilientConfig {
            retry: RetryPolicy::default(),
            hedge: None,
            deadline: None,
            breaker: BreakerConfig::default(),
            max_hedged_reads: 4,
        }
    }
}

#[derive(Clone, Copy)]
enum BreakerState {
    Closed,
    Open { until: Instant },
    HalfOpen { successes: usize },
}

struct BreakerWindow {
    state: BreakerState,
    outcomes: VecDeque<bool>,
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    attempts: AtomicU64,
    retries: AtomicU64,
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
    deadline_misses: AtomicU64,
    breaker_opens: AtomicU64,
    shed: AtomicU64,
    write_retries: AtomicU64,
    exhausted: AtomicU64,
}

/// The resilience wrapper. Construct standalone ([`ResilientBackend::new`])
/// or attached to a session's shared hedge budget
/// ([`ResilientBackend::in_session`]).
pub struct ResilientBackend {
    inner: BackendRef,
    cfg: ResilientConfig,
    /// Bounded hedged-read slots (session-shared or standalone).
    hedge_slots: MemberBudget,
    /// Test/operator override: behave as if the breaker were open.
    forced_open: AtomicBool,
    requests: AtomicU64,
    breaker: Mutex<BreakerWindow>,
    stats: Counters,
    /// Session recorder (disabled when standalone): retry backoffs and
    /// hedge races emit spans, breaker transitions emit marks.
    recorder: Recorder,
}

impl ResilientBackend {
    /// Standalone wrapper with a private hedge budget of
    /// `cfg.max_hedged_reads` slots.
    pub fn new(inner: BackendRef, cfg: ResilientConfig) -> Self {
        let cap = cfg.max_hedged_reads.max(1);
        // The member handle keeps the budget's inner state alive, so
        // the wrapper IoBudget can be dropped here.
        let hedge_slots = IoBudget::new(cap, None).register(cap);
        ResilientBackend::with_hedge_slots(inner, cfg, hedge_slots, Recorder::disabled())
    }

    /// Wrapper drawing hedge slots from `session`'s shared hedged-read
    /// budget ([`crate::session::SessionConfig::max_hedged_reads`]) —
    /// and, when the session is traced, emitting retry/hedge spans and
    /// breaker-transition marks into the session recorder.
    pub fn in_session(inner: BackendRef, cfg: ResilientConfig, session: &Session) -> Self {
        let cap = cfg.max_hedged_reads.max(1);
        ResilientBackend::with_hedge_slots(
            inner,
            cfg,
            session.register_hedger(cap),
            session.recorder().clone(),
        )
    }

    fn with_hedge_slots(
        inner: BackendRef,
        cfg: ResilientConfig,
        hedge_slots: MemberBudget,
        recorder: Recorder,
    ) -> Self {
        ResilientBackend {
            inner,
            cfg,
            hedge_slots,
            forced_open: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            breaker: Mutex::new(BreakerWindow {
                state: BreakerState::Closed,
                outcomes: VecDeque::new(),
            }),
            stats: Counters::default(),
            recorder,
        }
    }

    pub fn config(&self) -> &ResilientConfig {
        &self.cfg
    }

    /// Force the breaker open (or release the override): lets tests
    /// and operators exercise the degraded path on demand.
    pub fn force_breaker(&self, open: bool) {
        self.forced_open.store(open, Ordering::SeqCst);
    }

    /// Snapshot of the wrapper's counters.
    pub fn stats(&self) -> ResilienceStats {
        ResilienceStats {
            requests: self.stats.requests.load(Ordering::SeqCst),
            attempts: self.stats.attempts.load(Ordering::SeqCst),
            retries: self.stats.retries.load(Ordering::SeqCst),
            hedges: self.stats.hedges.load(Ordering::SeqCst),
            hedge_wins: self.stats.hedge_wins.load(Ordering::SeqCst),
            deadline_misses: self.stats.deadline_misses.load(Ordering::SeqCst),
            breaker_opens: self.stats.breaker_opens.load(Ordering::SeqCst),
            shed: self.stats.shed.load(Ordering::SeqCst),
            write_retries: self.stats.write_retries.load(Ordering::SeqCst),
            exhausted: self.stats.exhausted.load(Ordering::SeqCst),
        }
    }

    /// Tighter of the caller's and the configured per-attempt deadline.
    fn effective_hints(&self, h: IoHints) -> IoHints {
        let deadline = match (h.deadline, self.cfg.deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        IoHints { priority: h.priority, deadline }
    }

    /// Seeded backoff before retry number `attempt` (1-based) of
    /// logical request `req`.
    fn backoff(&self, req: u64, attempt: u32) -> Duration {
        let p = &self.cfg.retry;
        let exp = p.base_backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(16));
        let capped = exp.min(p.max_backoff);
        let u = unit(mix(p.seed ^ mix(req.wrapping_mul(8) + attempt as u64)));
        capped.mul_f64(1.0 - p.jitter.clamp(0.0, 1.0) * u)
    }

    /// Breaker admission: sheds only speculative read-ahead; head
    /// traffic always passes (it doubles as the half-open probe).
    fn gate(&self, priority: ReadPriority) -> Result<()> {
        let shed = |stats: &Counters| -> Error {
            stats.shed.fetch_add(1, Ordering::SeqCst);
            Error::Shed("circuit breaker open: read-ahead shed".into())
        };
        if self.forced_open.load(Ordering::SeqCst) {
            if priority == ReadPriority::ReadAhead {
                return Err(shed(&self.stats));
            }
            return Ok(());
        }
        let mut b = lock(&self.breaker)?;
        if let BreakerState::Open { until } = b.state {
            if Instant::now() >= until {
                b.state = BreakerState::HalfOpen { successes: 0 };
            }
        }
        match b.state {
            BreakerState::Closed => Ok(()),
            BreakerState::Open { .. } | BreakerState::HalfOpen { .. } => {
                if priority == ReadPriority::ReadAhead {
                    Err(shed(&self.stats))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Record one attempt outcome into the breaker.
    fn record(&self, ok: bool) {
        let Ok(mut b) = self.breaker.lock() else { return };
        let cfg = &self.cfg.breaker;
        match b.state {
            BreakerState::HalfOpen { successes } => {
                if ok {
                    if successes + 1 >= cfg.half_open_probes.max(1) {
                        b.state = BreakerState::Closed;
                        b.outcomes.clear();
                        self.recorder.mark(SpanKind::BreakerTrip);
                    } else {
                        b.state = BreakerState::HalfOpen { successes: successes + 1 };
                    }
                } else {
                    b.state = BreakerState::Open { until: Instant::now() + cfg.cooldown };
                    self.stats.breaker_opens.fetch_add(1, Ordering::SeqCst);
                    self.recorder.mark(SpanKind::BreakerTrip);
                }
            }
            BreakerState::Open { .. } => {}
            BreakerState::Closed => {
                b.outcomes.push_back(ok);
                while b.outcomes.len() > cfg.window.max(1) {
                    b.outcomes.pop_front();
                }
                if b.outcomes.len() >= cfg.min_samples.max(1) {
                    let errs = b.outcomes.iter().filter(|&&x| !x).count();
                    if errs as f64 >= cfg.open_error_rate * b.outcomes.len() as f64 {
                        b.state = BreakerState::Open { until: Instant::now() + cfg.cooldown };
                        b.outcomes.clear();
                        self.stats.breaker_opens.fetch_add(1, Ordering::SeqCst);
                        self.recorder.mark(SpanKind::BreakerTrip);
                    }
                }
            }
        }
    }

    /// One read attempt with hedging: the primary runs on a helper
    /// thread; if it has not responded after `hedge.after`, a duplicate
    /// is launched (budget permitting) and the first responder wins.
    /// The loser keeps running detached and releases its hedge slot
    /// when it finishes — that is the cancellation accounting: slots,
    /// not threads, are what the budget bounds.
    fn read_once_hedged(
        &self,
        off: u64,
        len: usize,
        hints: IoHints,
        hedge: &HedgePolicy,
    ) -> Result<Vec<u8>> {
        let (tx, rx) = mpsc::channel();
        let spawn_attempt = |tag: u8, slot: Option<crate::imt::ClusterGuard>| {
            let inner = self.inner.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _slot = slot;
                let mut buf = vec![0u8; len];
                let r = inner.read_at_opts(off, &mut buf, hints).map(|_| buf);
                let _ = tx.send((tag, r));
            });
        };
        self.stats.attempts.fetch_add(1, Ordering::SeqCst);
        spawn_attempt(0, None);
        let mut outstanding = 1usize;
        let mut hedged = false;
        // Span from the hedge launch to the race's resolution — the
        // window a duplicate was genuinely in flight.
        let mut hedge_start: Option<Duration> = None;
        let mut last_err: Option<Error> = None;
        let finish_hedge_span = |start: Option<Duration>| {
            if let Some(s) = start {
                self.recorder.push(SpanKind::Hedge, s, self.recorder.elapsed());
            }
        };
        loop {
            let msg = if hedged {
                rx.recv().ok()
            } else {
                match rx.recv_timeout(hedge.after) {
                    Ok(m) => Some(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        hedged = true;
                        if let Some(slot) = self.hedge_slots.try_acquire() {
                            self.stats.hedges.fetch_add(1, Ordering::SeqCst);
                            self.stats.attempts.fetch_add(1, Ordering::SeqCst);
                            hedge_start = self
                                .recorder
                                .is_enabled()
                                .then(|| self.recorder.elapsed());
                            spawn_attempt(1, Some(slot));
                            outstanding += 1;
                        }
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => None,
                }
            };
            let Some((tag, result)) = msg else {
                finish_hedge_span(hedge_start.take());
                return Err(last_err
                    .unwrap_or_else(|| Error::Sync("hedged read lost both attempts".into())));
            };
            outstanding -= 1;
            match result {
                Ok(data) => {
                    if tag == 1 {
                        self.stats.hedge_wins.fetch_add(1, Ordering::SeqCst);
                    }
                    finish_hedge_span(hedge_start.take());
                    return Ok(data);
                }
                Err(e) => {
                    last_err = Some(e);
                    if outstanding == 0 {
                        finish_hedge_span(hedge_start.take());
                        return Err(last_err.take().expect("error just stored"));
                    }
                }
            }
        }
    }
}

impl Backend for ResilientBackend {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.read_at_opts(off, buf, IoHints::default())
    }

    fn read_at_opts(&self, off: u64, buf: &mut [u8], hints: IoHints) -> Result<()> {
        self.stats.requests.fetch_add(1, Ordering::SeqCst);
        let req = self.requests.fetch_add(1, Ordering::SeqCst);
        self.gate(hints.priority)?;
        let hints = self.effective_hints(hints);
        let mut attempt = 0u32;
        loop {
            let result = if let Some(h) = self.cfg.hedge {
                self.read_once_hedged(off, buf.len(), hints, &h).map(|data| {
                    buf.copy_from_slice(&data);
                })
            } else {
                self.stats.attempts.fetch_add(1, Ordering::SeqCst);
                self.inner.read_at_opts(off, buf, hints)
            };
            match result {
                Ok(()) => {
                    self.record(true);
                    return Ok(());
                }
                Err(e) => {
                    if matches!(e, Error::Timeout(_)) {
                        self.stats.deadline_misses.fetch_add(1, Ordering::SeqCst);
                    }
                    self.record(false);
                    attempt += 1;
                    if !e.is_transient() {
                        return Err(e);
                    }
                    if attempt >= self.cfg.retry.max_attempts.max(1) {
                        self.stats.exhausted.fetch_add(1, Ordering::SeqCst);
                        return Err(e);
                    }
                    self.stats.retries.fetch_add(1, Ordering::SeqCst);
                    let retry_start =
                        self.recorder.is_enabled().then(|| self.recorder.elapsed());
                    std::thread::sleep(self.backoff(req, attempt));
                    if let Some(start) = retry_start {
                        self.recorder.push(SpanKind::Retry, start, self.recorder.elapsed());
                    }
                }
            }
        }
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        // Writes are always demanded (never shed) and never hedged —
        // a duplicate write races its twin for no latency benefit.
        // Retrying at this layer is what keeps ordered appends
        // byte-identical: the offset was already reserved above us, so
        // every attempt lands on the same range.
        self.stats.requests.fetch_add(1, Ordering::SeqCst);
        let req = self.requests.fetch_add(1, Ordering::SeqCst);
        let mut attempt = 0u32;
        loop {
            self.stats.attempts.fetch_add(1, Ordering::SeqCst);
            match self.inner.write_at(off, data) {
                Ok(()) => {
                    self.record(true);
                    return Ok(());
                }
                Err(e) => {
                    if matches!(e, Error::Timeout(_)) {
                        self.stats.deadline_misses.fetch_add(1, Ordering::SeqCst);
                    }
                    self.record(false);
                    attempt += 1;
                    if !e.is_transient() {
                        return Err(e);
                    }
                    if attempt >= self.cfg.retry.max_attempts.max(1) {
                        self.stats.exhausted.fetch_add(1, Ordering::SeqCst);
                        return Err(e);
                    }
                    self.stats.write_retries.fetch_add(1, Ordering::SeqCst);
                    let retry_start =
                        self.recorder.is_enabled().then(|| self.recorder.elapsed());
                    std::thread::sleep(self.backoff(req, attempt));
                    if let Some(start) = retry_start {
                        self.recorder.push(SpanKind::Retry, start, self.recorder.elapsed());
                    }
                }
            }
        }
    }

    fn len(&self) -> Result<u64> {
        self.inner.len()
    }

    fn sync(&self) -> Result<()> {
        self.inner.sync()
    }

    fn describe(&self) -> String {
        format!(
            "resilient({}, attempts {}, hedge {})",
            self.inner.describe(),
            self.cfg.retry.max_attempts,
            match self.cfg.hedge {
                Some(h) => format!("after {:?}", h.after),
                None => "off".into(),
            }
        )
    }

    fn health(&self) -> BackendHealth {
        if self.forced_open.load(Ordering::SeqCst) {
            return BackendHealth::Degraded;
        }
        match self.breaker.lock() {
            Ok(b) => match b.state {
                BreakerState::Closed => BackendHealth::Healthy,
                _ => BackendHealth::Degraded,
            },
            Err(_) => BackendHealth::Degraded,
        }
    }

    fn cost_hint(&self) -> Option<CostHint> {
        self.inner.cost_hint()
    }

    fn resilience(&self) -> Option<ResilienceStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::fault::{FaultDirection, FaultKind, FaultPlan, FaultyBackend};
    use crate::storage::mem::MemBackend;
    use crate::storage::remote::{RemoteConfig, RemoteDevice};
    use std::sync::Arc;

    fn mem_with(pattern: u8, len: usize) -> BackendRef {
        Arc::new(MemBackend::from_vec(vec![pattern; len]))
    }

    fn fast_retry() -> RetryPolicy {
        RetryPolicy {
            base_backoff: Duration::from_micros(50),
            max_backoff: Duration::from_micros(200),
            ..RetryPolicy::default()
        }
    }

    #[test]
    fn retries_recover_transient_faults_byte_identical() {
        let flaky: BackendRef = Arc::new(FaultyBackend::new(
            mem_with(0x5A, 4096),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::EveryNth(3),
        ));
        let be = ResilientBackend::new(
            flaky,
            ResilientConfig { retry: fast_retry(), ..Default::default() },
        );
        let mut buf = [0u8; 64];
        for i in 0..12u64 {
            be.read_at(i * 64, &mut buf).unwrap();
            assert_eq!(buf, [0x5A; 64], "range {i}");
        }
        let st = be.stats();
        assert_eq!(st.requests, 12);
        assert!(st.retries >= 4, "every 3rd inner request faults: {st:?}");
        assert_eq!(st.exhausted, 0);
        assert!(st.attempts > st.requests);
    }

    #[test]
    fn permanent_errors_surface_without_retry() {
        let dead: BackendRef = Arc::new(FaultyBackend::new(
            mem_with(0, 64),
            FaultKind::Hard,
            FaultDirection::Reads,
            FaultPlan::AfterN(0),
        ));
        let be = ResilientBackend::new(
            dead,
            ResilientConfig { retry: fast_retry(), ..Default::default() },
        );
        let mut buf = [0u8; 16];
        assert!(be.read_at(0, &mut buf).is_err());
        let st = be.stats();
        assert_eq!(st.retries, 0, "hard faults must not be retried");
        assert_eq!(st.attempts, 1);
    }

    #[test]
    fn transient_faults_exhaust_after_max_attempts() {
        let flaky: BackendRef = Arc::new(FaultyBackend::new(
            mem_with(0, 64),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::EveryNth(1), // every request faults
        ));
        let be = ResilientBackend::new(
            flaky,
            ResilientConfig {
                retry: RetryPolicy { max_attempts: 3, ..fast_retry() },
                ..Default::default()
            },
        );
        let mut buf = [0u8; 16];
        let err = be.read_at(0, &mut buf).unwrap_err();
        assert!(err.is_transient());
        let st = be.stats();
        assert_eq!(st.attempts, 3);
        assert_eq!(st.retries, 2);
        assert_eq!(st.exhausted, 1);
    }

    #[test]
    fn hedge_rescues_stuck_requests() {
        // Every 2nd remote request is stuck at 30x service time; the
        // hedge launches after ~p99 and wins with a normal draw.
        let cfg = RemoteConfig {
            first_byte_p50: Duration::from_millis(1),
            first_byte_p99: Duration::from_millis(3),
            fault_every_nth: 2,
            timeout_weight: 0.0,
            short_read_weight: 0.0,
            stuck_weight: 1.0,
            stuck_factor: 30.0,
            seed: 7,
            ..RemoteConfig::default()
        };
        let remote = Arc::new(RemoteDevice::new(cfg, 1.0));
        remote.preload(0, &[0xC3; 1024]).unwrap();
        let be = ResilientBackend::new(
            remote.clone() as BackendRef,
            ResilientConfig {
                retry: fast_retry(),
                hedge: Some(HedgePolicy::at_p99(Duration::from_millis(5))),
                ..Default::default()
            },
        );
        let mut buf = [0u8; 128];
        for i in 0..4u64 {
            be.read_at(i * 128, &mut buf).unwrap();
            assert_eq!(buf, [0xC3; 128]);
        }
        let st = be.stats();
        assert!(st.hedges >= 1, "stuck requests must trigger hedges: {st:?}");
        assert!(st.hedge_wins >= 1, "a hedge must beat a stuck primary: {st:?}");
        assert!(remote.device_stats().stuck >= 1);
    }

    #[test]
    fn deadline_misses_count_and_retry() {
        let cfg = RemoteConfig {
            first_byte_p50: Duration::from_millis(1),
            first_byte_p99: Duration::from_millis(3),
            fault_every_nth: 3,
            timeout_weight: 1.0,
            short_read_weight: 0.0,
            stuck_weight: 0.0,
            seed: 4,
            ..RemoteConfig::default()
        };
        let remote = Arc::new(RemoteDevice::new(cfg, 1.0));
        remote.preload(0, &[0x11; 1024]).unwrap();
        let be = ResilientBackend::new(
            remote as BackendRef,
            ResilientConfig {
                retry: fast_retry(),
                deadline: Some(Duration::from_millis(20)),
                ..Default::default()
            },
        );
        let mut buf = [0u8; 64];
        for i in 0..6u64 {
            be.read_at(i * 64, &mut buf).unwrap();
            assert_eq!(buf, [0x11; 64]);
        }
        let st = be.stats();
        assert!(st.deadline_misses >= 1, "timeout faults must miss the deadline: {st:?}");
        assert!(st.retries >= 1);
    }

    #[test]
    fn breaker_opens_sheds_read_ahead_and_recovers() {
        let flaky = Arc::new(FaultyBackend::new(
            mem_with(0x77, 1024),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::AfterN(0), // every read faults until re-armed
        ));
        let be = ResilientBackend::new(
            flaky.clone() as BackendRef,
            ResilientConfig {
                retry: RetryPolicy { max_attempts: 1, ..fast_retry() },
                breaker: BreakerConfig {
                    window: 8,
                    min_samples: 4,
                    open_error_rate: 0.5,
                    cooldown: Duration::from_millis(5),
                    half_open_probes: 2,
                },
                ..Default::default()
            },
        );
        let mut buf = [0u8; 16];
        for _ in 0..4 {
            assert!(be.read_at(0, &mut buf).is_err());
        }
        assert_eq!(be.health(), BackendHealth::Degraded, "breaker must open");
        assert!(be.stats().breaker_opens >= 1);
        // Read-ahead is shed without touching the device...
        let inner_before = flaky.injected();
        let err = be
            .read_at_opts(0, &mut buf, IoHints::read_ahead())
            .unwrap_err();
        assert!(matches!(err, Error::Shed(_)), "got {err}");
        assert_eq!(flaky.injected(), inner_before, "shed requests never reach the device");
        assert!(be.stats().shed >= 1);
        // ...while head reads keep probing. Heal the device, wait out
        // the cooldown, and the half-open probes close the breaker.
        flaky.arm(i64::MAX);
        std::thread::sleep(Duration::from_millis(10));
        for _ in 0..3 {
            be.read_at(0, &mut buf).unwrap();
        }
        assert_eq!(be.health(), BackendHealth::Healthy, "probes must close the breaker");
        be.read_at_opts(0, &mut buf, IoHints::read_ahead()).unwrap();
        assert_eq!(buf, [0x77; 16]);
    }

    #[test]
    fn forced_breaker_sheds_only_read_ahead() {
        let be = ResilientBackend::new(mem_with(0x2B, 256), ResilientConfig::default());
        be.force_breaker(true);
        assert_eq!(be.health(), BackendHealth::Degraded);
        let mut buf = [0u8; 16];
        assert!(matches!(
            be.read_at_opts(0, &mut buf, IoHints::read_ahead()),
            Err(Error::Shed(_))
        ));
        be.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [0x2B; 16], "head reads always pass");
        be.force_breaker(false);
        be.read_at_opts(0, &mut buf, IoHints::read_ahead()).unwrap();
        assert_eq!(be.health(), BackendHealth::Healthy);
    }

    #[test]
    fn writes_retry_to_byte_identical_content() {
        let flaky: BackendRef = Arc::new(FaultyBackend::new(
            Arc::new(MemBackend::new()),
            FaultKind::Transient,
            FaultDirection::Writes,
            FaultPlan::EveryNth(2),
        ));
        let be = ResilientBackend::new(
            flaky,
            ResilientConfig { retry: fast_retry(), ..Default::default() },
        );
        for i in 0..8u64 {
            be.write_at(i * 32, &[i as u8; 32]).unwrap();
        }
        let st = be.stats();
        assert!(st.write_retries >= 3, "every 2nd write attempt faults: {st:?}");
        let mut buf = [0u8; 32];
        for i in 0..8u64 {
            be.read_at(i * 32, &mut buf).unwrap();
            assert_eq!(buf, [i as u8; 32], "write {i} must be byte-identical");
        }
    }

    #[test]
    fn hedge_slots_stay_bounded_and_release() {
        let session = Session::new(crate::session::SessionConfig::default());
        let be = ResilientBackend::in_session(
            mem_with(9, 512),
            ResilientConfig {
                hedge: Some(HedgePolicy { after: Duration::from_micros(1) }),
                ..Default::default()
            },
            &session,
        );
        let mut buf = [0u8; 32];
        for i in 0..8u64 {
            be.read_at(i * 32, &mut buf).unwrap();
        }
        // Even with an absurdly eager hedge delay, slots drain back as
        // the losing duplicates finish (give them a moment to land).
        for _ in 0..1000 {
            if session.stats().in_flight_hedges == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(session.stats().in_flight_hedges, 0, "hedge slots must not leak");
        assert_eq!(session.stats().hedge_limit, 4);
    }
}
