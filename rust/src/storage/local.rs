//! Real file backend (positioned I/O on the host filesystem).

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use crate::error::Result;

use super::{Backend, IoHints};

/// A file on the host filesystem, accessed with pread/pwrite so
/// concurrent readers need no seek coordination.
pub struct LocalFile {
    file: File,
    path: PathBuf,
}

impl LocalFile {
    /// Create (truncate) a file for writing and reading back.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(&path)?;
        Ok(LocalFile { file, path })
    }

    /// Open an existing file read-only (writes will fail at the OS level).
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new().read(true).open(&path)?;
        Ok(LocalFile { file, path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Backend for LocalFile {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.file.read_exact_at(buf, off)?;
        Ok(())
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        self.file.write_all_at(data, off)?;
        Ok(())
    }

    /// One positional `pread` per coalesced fetch range, straight on
    /// the shared handle: no seek lock, no per-range dispatch through
    /// the trait-object default — concurrent windows of a
    /// [`crate::cache::ClusterStream`] never serialise on each other.
    fn read_scatter(&self, ranges: &mut [(u64, &mut [u8])], _hints: IoHints) -> Result<()> {
        for (off, buf) in ranges.iter_mut() {
            self.file.read_exact_at(buf, *off)?;
        }
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }

    fn sync(&self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }

    fn describe(&self) -> String {
        format!("local:{}", self.path.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_write_read() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rootio-local-{}.bin", std::process::id()));
        let f = LocalFile::create(&path).unwrap();
        f.write_at(0, b"header").unwrap();
        f.write_at(100, b"tail").unwrap();
        f.sync().unwrap();
        assert_eq!(f.len().unwrap(), 104);
        let mut buf = [0u8; 4];
        f.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"tail");
        drop(f);

        let r = LocalFile::open(&path).unwrap();
        let mut buf = [0u8; 6];
        r.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"header");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_missing_is_error() {
        assert!(LocalFile::open("/nonexistent/dir/nope.bin").is_err());
    }
}
