//! Real file backend (positioned I/O on the host filesystem).

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;

use super::{Backend, IoHints};

/// Most buffer segments handed to one vectored read (the kernel caps
/// an iovec list at `IOV_MAX`, 1024 on Linux).
const MAX_IOV: usize = 1024;

/// Minimal `preadv(2)` binding: the crate links no FFI helper crates
/// and std has no *positioned* vectored read, so declare the one
/// symbol directly against the platform libc.
#[cfg(target_os = "linux")]
mod vectored {
    /// Matches C `struct iovec { void *iov_base; size_t iov_len; }`.
    #[repr(C)]
    pub struct IoVec {
        pub base: *mut u8,
        pub len: usize,
    }

    extern "C" {
        pub fn preadv(fd: i32, iov: *const IoVec, iovcnt: i32, offset: i64) -> isize;
    }
}

/// A file on the host filesystem, accessed with pread/pwrite so
/// concurrent readers need no seek coordination.
pub struct LocalFile {
    file: File,
    path: PathBuf,
    /// Syscalls issued by [`Backend::read_scatter`].
    scatter_syscalls: AtomicU64,
    /// Buffer ranges served by [`Backend::read_scatter`]. With
    /// vectored I/O, `scatter_syscalls` stays well below this whenever
    /// the fetch plan coalesces adjacent baskets.
    scatter_ranges: AtomicU64,
}

impl LocalFile {
    /// Create (truncate) a file for writing and reading back.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(&path)?;
        Ok(LocalFile::wrap(file, path))
    }

    /// Open an existing file read-only (writes will fail at the OS level).
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new().read(true).open(&path)?;
        Ok(LocalFile::wrap(file, path))
    }

    fn wrap(file: File, path: PathBuf) -> Self {
        LocalFile {
            file,
            path,
            scatter_syscalls: AtomicU64::new(0),
            scatter_ranges: AtomicU64::new(0),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Scatter-read accounting: `(syscalls, ranges)` served through
    /// [`Backend::read_scatter`] so far. One contiguous run of ranges
    /// costs one syscall on Linux, so `syscalls < ranges` measures the
    /// coalescing win directly.
    pub fn scatter_stats(&self) -> (u64, u64) {
        (
            self.scatter_syscalls.load(Ordering::Relaxed),
            self.scatter_ranges.load(Ordering::Relaxed),
        )
    }

    /// Fill one device-contiguous run of buffers starting at
    /// `run[0].0` with a single `preadv` (re-issued past partial reads
    /// and `EINTR`, never re-reading filled bytes).
    #[cfg(target_os = "linux")]
    fn read_run(&self, run: &mut [(u64, &mut [u8])]) -> Result<()> {
        use std::os::unix::io::AsRawFd;
        let fd = self.file.as_raw_fd();
        let total: usize = run.iter().map(|(_, b)| b.len()).sum();
        let mut offset = run[0].0;
        let mut done = 0usize;
        while done < total {
            // Rebuild the iovec list past the already-filled prefix.
            let mut iov: Vec<vectored::IoVec> = Vec::with_capacity(run.len());
            let mut skip = done;
            for (_, buf) in run.iter_mut() {
                if skip >= buf.len() {
                    skip -= buf.len();
                    continue;
                }
                let b = &mut buf[skip..];
                iov.push(vectored::IoVec { base: b.as_mut_ptr(), len: b.len() });
                skip = 0;
            }
            // SAFETY: every iovec points into a live &mut [u8] borrowed
            // for this loop iteration, and iovcnt matches the list.
            let n = unsafe {
                vectored::preadv(fd, iov.as_ptr(), iov.len() as i32, offset as i64)
            };
            self.scatter_syscalls.fetch_add(1, Ordering::Relaxed);
            if n < 0 {
                let err = std::io::Error::last_os_error();
                if err.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err.into());
            }
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "preadv reached end of file mid-run",
                )
                .into());
            }
            done += n as usize;
            offset += n as u64;
        }
        Ok(())
    }

    /// Portable fallback: one `pread` per range.
    #[cfg(not(target_os = "linux"))]
    fn read_run(&self, run: &mut [(u64, &mut [u8])]) -> Result<()> {
        for (off, buf) in run.iter_mut() {
            self.file.read_exact_at(buf, *off)?;
            self.scatter_syscalls.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

impl Backend for LocalFile {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.file.read_exact_at(buf, off)?;
        Ok(())
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        self.file.write_all_at(data, off)?;
        Ok(())
    }

    /// Vectored scatter read on the shared handle: device-contiguous
    /// runs of ranges (a coalesced fetch split into per-basket
    /// buffers) are grouped and served by a single `preadv` each, so a
    /// whole coalesced plan costs one syscall per run instead of one
    /// per basket — no seek lock, no per-range dispatch through the
    /// trait-object default, and concurrent windows of a
    /// [`crate::cache::ClusterStream`] never serialise on each other.
    /// [`LocalFile::scatter_stats`] counts the syscall drop.
    fn read_scatter(&self, ranges: &mut [(u64, &mut [u8])], _hints: IoHints) -> Result<()> {
        self.scatter_ranges.fetch_add(ranges.len() as u64, Ordering::Relaxed);
        let mut i = 0;
        while i < ranges.len() {
            let mut j = i + 1;
            let mut next_off = ranges[i].0 + ranges[i].1.len() as u64;
            while j < ranges.len() && ranges[j].0 == next_off && j - i < MAX_IOV {
                next_off += ranges[j].1.len() as u64;
                j += 1;
            }
            self.read_run(&mut ranges[i..j])?;
            i = j;
        }
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }

    fn sync(&self) -> Result<()> {
        self.file.sync_data()?;
        Ok(())
    }

    fn describe(&self) -> String {
        format!("local:{}", self.path.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_write_read() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rootio-local-{}.bin", std::process::id()));
        let f = LocalFile::create(&path).unwrap();
        f.write_at(0, b"header").unwrap();
        f.write_at(100, b"tail").unwrap();
        f.sync().unwrap();
        assert_eq!(f.len().unwrap(), 104);
        let mut buf = [0u8; 4];
        f.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"tail");
        drop(f);

        let r = LocalFile::open(&path).unwrap();
        let mut buf = [0u8; 6];
        r.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf, b"header");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_missing_is_error() {
        assert!(LocalFile::open("/nonexistent/dir/nope.bin").is_err());
    }

    #[test]
    fn scatter_serves_contiguous_runs_with_one_syscall_each() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rootio-scatter-{}.bin", std::process::id()));
        let f = LocalFile::create(&path).unwrap();
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        f.write_at(0, &data).unwrap();

        let mut a = vec![0u8; 100];
        let mut b = vec![0u8; 200];
        let mut c = vec![0u8; 50];
        {
            let mut ranges: Vec<(u64, &mut [u8])> = vec![
                (10, &mut a[..]),
                (110, &mut b[..]), // back-to-back with the first
                (700, &mut c[..]), // separate run
            ];
            f.read_scatter(&mut ranges, IoHints::default()).unwrap();
        }
        assert_eq!(&a[..], &data[10..110]);
        assert_eq!(&b[..], &data[110..310]);
        assert_eq!(&c[..], &data[700..750]);

        let (syscalls, ranges) = f.scatter_stats();
        assert_eq!(ranges, 3);
        #[cfg(target_os = "linux")]
        assert_eq!(syscalls, 2, "two contiguous runs must cost two preadv calls");
        #[cfg(not(target_os = "linux"))]
        assert_eq!(syscalls, 3);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn scatter_past_eof_is_an_error() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rootio-scatter-eof-{}.bin", std::process::id()));
        let f = LocalFile::create(&path).unwrap();
        f.write_at(0, &[7u8; 64]).unwrap();
        let mut buf = vec![0u8; 32];
        let mut ranges: Vec<(u64, &mut [u8])> = vec![(60, &mut buf[..])];
        assert!(f.read_scatter(&mut ranges, IoHints::default()).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
