//! Storage backends.
//!
//! The paper's Figure 6 compares writing through `TBufferMerger` to a
//! hard-disk drive, a SATA SSD, an NVMe SSD and tmpfs. We do not have
//! those devices, so alongside a real [`local::LocalFile`] backend there
//! is a deterministic simulated device ([`sim::SimDevice`]) with a
//! seek-latency + sustained-bandwidth + single-issue-queue cost model,
//! calibrated to the era's hardware regimes (see [`sim::DeviceModel`]).
//! The simulation preserves exactly what the experiment measures: which
//! side — CPU compression or device bandwidth — is the bottleneck at a
//! given thread count.
//!
//! Remote storage (ISSUE 6) layers on top of this: [`remote::RemoteDevice`]
//! models an object store with heavy-tailed first-byte latency, bounded
//! request slots, and injectable transient faults, while
//! [`resilient::ResilientBackend`] wraps any backend with deadlines,
//! retry-with-backoff, hedged reads, and a circuit breaker. The shared
//! seeded fault plan lives in [`fault::FaultyBackend`].

pub mod fault;
pub mod local;
pub mod mem;
pub mod remote;
pub mod resilient;
pub mod sim;

use crate::error::Result;
use std::sync::Arc;
use std::time::Duration;

/// Scheduling class of a read: whether a consumer is blocked on it
/// right now or it is speculative read-ahead. Resilience layers use
/// this to decide what may be shed when the backend degrades — the
/// head window is *never* shed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPriority {
    /// A consumer is (or is about to be) blocked on this data.
    #[default]
    Head,
    /// Speculative prefetch; may be shed or degraded under faults.
    ReadAhead,
}

/// Per-request options threaded through [`Backend::read_at_opts`].
/// Plain `read_at` is equivalent to default hints (head priority, no
/// deadline).
#[derive(Clone, Copy, Debug, Default)]
pub struct IoHints {
    pub priority: ReadPriority,
    /// Cooperative per-request deadline. Devices that model service
    /// time (e.g. [`remote::RemoteDevice`]) fail the request with
    /// [`crate::error::Error::Timeout`] when the modelled service time
    /// exceeds it, *without* sleeping out the full latency.
    pub deadline: Option<Duration>,
}

impl IoHints {
    pub fn read_ahead() -> Self {
        IoHints { priority: ReadPriority::ReadAhead, deadline: None }
    }
}

/// Coarse backend health, surfaced by resilience wrappers so the
/// prefetcher can shrink its window before errors even reach it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendHealth {
    #[default]
    Healthy,
    /// Error rate spiked (circuit breaker open / half-open): callers
    /// should stop speculating and fetch only what they need.
    Degraded,
}

/// Observed per-request cost, for adaptive coalescing: how expensive
/// is *starting* a request versus streaming more bytes on one.
#[derive(Clone, Copy, Debug)]
pub struct CostHint {
    /// Fixed cost to begin a request (seek / first byte), seconds.
    pub seek_secs: f64,
    /// Sustained read bandwidth, MB/s.
    pub read_mbps: f64,
}

/// Counters a [`resilient::ResilientBackend`] maintains; other
/// backends return `None` from [`Backend::resilience`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ResilienceStats {
    /// Logical requests entering the wrapper.
    pub requests: u64,
    /// Physical attempts issued (>= requests; includes hedges).
    pub attempts: u64,
    /// Sequential re-attempts after a transient failure.
    pub retries: u64,
    /// Hedged duplicates launched.
    pub hedges: u64,
    /// Hedges that beat the primary attempt.
    pub hedge_wins: u64,
    /// Attempts that failed their per-request deadline.
    pub deadline_misses: u64,
    /// Times the circuit breaker transitioned closed -> open.
    pub breaker_opens: u64,
    /// Read-ahead requests refused while the breaker was open.
    pub shed: u64,
    /// Write attempts retried after a transient fault.
    pub write_retries: u64,
    /// Requests that exhausted every attempt and surfaced an error.
    pub exhausted: u64,
}

impl ResilienceStats {
    /// Counters accumulated since the `earlier` snapshot.
    pub fn since(&self, earlier: &ResilienceStats) -> ResilienceStats {
        ResilienceStats {
            requests: self.requests - earlier.requests,
            attempts: self.attempts - earlier.attempts,
            retries: self.retries - earlier.retries,
            hedges: self.hedges - earlier.hedges,
            hedge_wins: self.hedge_wins - earlier.hedge_wins,
            deadline_misses: self.deadline_misses - earlier.deadline_misses,
            breaker_opens: self.breaker_opens - earlier.breaker_opens,
            shed: self.shed - earlier.shed,
            write_retries: self.write_retries - earlier.write_retries,
            exhausted: self.exhausted - earlier.exhausted,
        }
    }
}

/// A byte-addressable storage device. Implementations must be
/// thread-safe: the merger's output thread and readers may touch the
/// same backend concurrently.
pub trait Backend: Send + Sync {
    /// Read exactly `buf.len()` bytes at `off`.
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()>;
    /// Write all of `data` at `off`, extending the device if needed.
    fn write_at(&self, off: u64, data: &[u8]) -> Result<()>;
    /// Current device size in bytes.
    fn len(&self) -> Result<u64>;
    /// Durability barrier (no-op for memory/sim devices).
    fn sync(&self) -> Result<()> {
        Ok(())
    }
    /// Human-readable description for logs/benches.
    fn describe(&self) -> String;

    /// `read_at` with per-request hints (priority, deadline). The
    /// default ignores the hints — only devices that model service
    /// time or shed load override this.
    fn read_at_opts(&self, off: u64, buf: &mut [u8], hints: IoHints) -> Result<()> {
        let _ = hints;
        self.read_at(off, buf)
    }

    /// Read a batch of coalesced ranges, one positional read each.
    /// The default loops [`Backend::read_at_opts`]; file-backed
    /// devices override it to issue one `pread` per range on a shared
    /// handle with no seek lock (the PR 5 follow-up).
    fn read_scatter(&self, ranges: &mut [(u64, &mut [u8])], hints: IoHints) -> Result<()> {
        for (off, buf) in ranges.iter_mut() {
            self.read_at_opts(*off, &mut **buf, hints)?;
        }
        Ok(())
    }

    /// Coarse health signal (always [`BackendHealth::Healthy`] unless
    /// a resilience wrapper knows better).
    fn health(&self) -> BackendHealth {
        BackendHealth::Healthy
    }

    /// Observed per-request cost for adaptive coalescing, if the
    /// device can estimate it.
    fn cost_hint(&self) -> Option<CostHint> {
        None
    }

    /// Retry/hedge/breaker counters, if this backend is (or wraps) a
    /// [`resilient::ResilientBackend`].
    fn resilience(&self) -> Option<ResilienceStats> {
        None
    }
}

/// Shared handle alias used throughout the library.
pub type BackendRef = Arc<dyn Backend>;

/// Well-known device configurations for experiments.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceSpec {
    /// A real file on the host filesystem.
    Local(std::path::PathBuf),
    /// Plain in-memory buffer, no cost model.
    Mem,
    /// Simulated spinning disk.
    Hdd,
    /// Simulated SATA SSD.
    Ssd,
    /// Simulated NVMe SSD.
    Nvme,
    /// Simulated RAM-backed filesystem.
    Tmpfs,
    /// Simulated remote object store (default [`remote::RemoteConfig`]:
    /// WAN-ish latency distribution, no injected faults).
    Remote,
}

impl DeviceSpec {
    /// Open/construct the backend. `time_scale` scales all simulated
    /// latencies (1.0 = real time; smaller = faster experiments with
    /// identical *relative* behaviour). Ignored for Local/Mem.
    pub fn open(&self, time_scale: f64) -> Result<BackendRef> {
        Ok(match self {
            DeviceSpec::Local(p) => Arc::new(local::LocalFile::create(p)?),
            DeviceSpec::Mem => Arc::new(mem::MemBackend::new()),
            DeviceSpec::Hdd => Arc::new(sim::SimDevice::new(sim::DeviceModel::hdd(), time_scale)),
            DeviceSpec::Ssd => Arc::new(sim::SimDevice::new(sim::DeviceModel::ssd(), time_scale)),
            DeviceSpec::Nvme => {
                Arc::new(sim::SimDevice::new(sim::DeviceModel::nvme(), time_scale))
            }
            DeviceSpec::Tmpfs => {
                Arc::new(sim::SimDevice::new(sim::DeviceModel::tmpfs(), time_scale))
            }
            DeviceSpec::Remote => {
                Arc::new(remote::RemoteDevice::new(remote::RemoteConfig::default(), time_scale))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceSpec::Local(_) => "local",
            DeviceSpec::Mem => "mem",
            DeviceSpec::Hdd => "hdd",
            DeviceSpec::Ssd => "ssd",
            DeviceSpec::Nvme => "nvme",
            DeviceSpec::Tmpfs => "tmpfs",
            DeviceSpec::Remote => "remote",
        }
    }
}

impl std::str::FromStr for DeviceSpec {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "mem" => DeviceSpec::Mem,
            "hdd" => DeviceSpec::Hdd,
            "ssd" => DeviceSpec::Ssd,
            "nvme" => DeviceSpec::Nvme,
            "tmpfs" => DeviceSpec::Tmpfs,
            "remote" => DeviceSpec::Remote,
            path => DeviceSpec::Local(path.into()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse() {
        assert_eq!("hdd".parse::<DeviceSpec>().unwrap(), DeviceSpec::Hdd);
        assert_eq!("nvme".parse::<DeviceSpec>().unwrap(), DeviceSpec::Nvme);
        assert!(matches!("/tmp/x.rntf".parse::<DeviceSpec>().unwrap(), DeviceSpec::Local(_)));
    }

    #[test]
    fn all_specs_open_and_roundtrip() {
        let dir = std::env::temp_dir().join(format!("rootio-spec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let specs = [
            DeviceSpec::Local(dir.join("t.bin")),
            DeviceSpec::Mem,
            DeviceSpec::Hdd,
            DeviceSpec::Ssd,
            DeviceSpec::Nvme,
            DeviceSpec::Tmpfs,
            DeviceSpec::Remote,
        ];
        for spec in specs {
            let b = spec.open(0.0).unwrap();
            b.write_at(3, b"hello").unwrap();
            let mut buf = [0u8; 5];
            b.read_at(3, &mut buf).unwrap();
            assert_eq!(&buf, b"hello", "{}", spec.name());
            assert_eq!(b.len().unwrap(), 8);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
