//! Storage backends.
//!
//! The paper's Figure 6 compares writing through `TBufferMerger` to a
//! hard-disk drive, a SATA SSD, an NVMe SSD and tmpfs. We do not have
//! those devices, so alongside a real [`local::LocalFile`] backend there
//! is a deterministic simulated device ([`sim::SimDevice`]) with a
//! seek-latency + sustained-bandwidth + single-issue-queue cost model,
//! calibrated to the era's hardware regimes (see [`sim::DeviceModel`]).
//! The simulation preserves exactly what the experiment measures: which
//! side — CPU compression or device bandwidth — is the bottleneck at a
//! given thread count.

pub mod local;
pub mod mem;
pub mod sim;

use crate::error::Result;
use std::sync::Arc;

/// A byte-addressable storage device. Implementations must be
/// thread-safe: the merger's output thread and readers may touch the
/// same backend concurrently.
pub trait Backend: Send + Sync {
    /// Read exactly `buf.len()` bytes at `off`.
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()>;
    /// Write all of `data` at `off`, extending the device if needed.
    fn write_at(&self, off: u64, data: &[u8]) -> Result<()>;
    /// Current device size in bytes.
    fn len(&self) -> Result<u64>;
    /// Durability barrier (no-op for memory/sim devices).
    fn sync(&self) -> Result<()> {
        Ok(())
    }
    /// Human-readable description for logs/benches.
    fn describe(&self) -> String;
}

/// Shared handle alias used throughout the library.
pub type BackendRef = Arc<dyn Backend>;

/// Well-known device configurations for experiments.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceSpec {
    /// A real file on the host filesystem.
    Local(std::path::PathBuf),
    /// Plain in-memory buffer, no cost model.
    Mem,
    /// Simulated spinning disk.
    Hdd,
    /// Simulated SATA SSD.
    Ssd,
    /// Simulated NVMe SSD.
    Nvme,
    /// Simulated RAM-backed filesystem.
    Tmpfs,
}

impl DeviceSpec {
    /// Open/construct the backend. `time_scale` scales all simulated
    /// latencies (1.0 = real time; smaller = faster experiments with
    /// identical *relative* behaviour). Ignored for Local/Mem.
    pub fn open(&self, time_scale: f64) -> Result<BackendRef> {
        Ok(match self {
            DeviceSpec::Local(p) => Arc::new(local::LocalFile::create(p)?),
            DeviceSpec::Mem => Arc::new(mem::MemBackend::new()),
            DeviceSpec::Hdd => Arc::new(sim::SimDevice::new(sim::DeviceModel::hdd(), time_scale)),
            DeviceSpec::Ssd => Arc::new(sim::SimDevice::new(sim::DeviceModel::ssd(), time_scale)),
            DeviceSpec::Nvme => {
                Arc::new(sim::SimDevice::new(sim::DeviceModel::nvme(), time_scale))
            }
            DeviceSpec::Tmpfs => {
                Arc::new(sim::SimDevice::new(sim::DeviceModel::tmpfs(), time_scale))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceSpec::Local(_) => "local",
            DeviceSpec::Mem => "mem",
            DeviceSpec::Hdd => "hdd",
            DeviceSpec::Ssd => "ssd",
            DeviceSpec::Nvme => "nvme",
            DeviceSpec::Tmpfs => "tmpfs",
        }
    }
}

impl std::str::FromStr for DeviceSpec {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "mem" => DeviceSpec::Mem,
            "hdd" => DeviceSpec::Hdd,
            "ssd" => DeviceSpec::Ssd,
            "nvme" => DeviceSpec::Nvme,
            "tmpfs" => DeviceSpec::Tmpfs,
            path => DeviceSpec::Local(path.into()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse() {
        assert_eq!("hdd".parse::<DeviceSpec>().unwrap(), DeviceSpec::Hdd);
        assert_eq!("nvme".parse::<DeviceSpec>().unwrap(), DeviceSpec::Nvme);
        assert!(matches!("/tmp/x.rntf".parse::<DeviceSpec>().unwrap(), DeviceSpec::Local(_)));
    }

    #[test]
    fn all_specs_open_and_roundtrip() {
        let dir = std::env::temp_dir().join(format!("rootio-spec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let specs = [
            DeviceSpec::Local(dir.join("t.bin")),
            DeviceSpec::Mem,
            DeviceSpec::Hdd,
            DeviceSpec::Ssd,
            DeviceSpec::Nvme,
            DeviceSpec::Tmpfs,
        ];
        for spec in specs {
            let b = spec.open(0.0).unwrap();
            b.write_at(3, b"hello").unwrap();
            let mut buf = [0u8; 5];
            b.read_at(3, &mut buf).unwrap();
            assert_eq!(&buf, b"hello", "{}", spec.name());
            assert_eq!(b.len().unwrap(), 8);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
