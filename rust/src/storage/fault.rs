//! Reusable seeded fault injection for any backend.
//!
//! Promoted out of `tests/failure_injection.rs` (the old ad-hoc
//! `FlakyBackend`) so the test double and the library share one
//! implementation. A [`FaultyBackend`] wraps an inner [`BackendRef`]
//! and injects faults according to a deterministic [`FaultPlan`]:
//!
//! * [`FaultPlan::AfterN`] — `n` healthy calls, then every later call
//!   faults (the original mid-stream device-death scenario). Can be
//!   re-armed after construction via [`FaultyBackend::arm`], e.g. to
//!   let the open path through before killing the device.
//! * [`FaultPlan::EveryNth`] — every `n`-th matching request faults,
//!   counted with a global atomic, so the *number* of faults a test
//!   sees is a pure function of the number of requests — independent
//!   of thread interleaving.
//! * [`FaultPlan::SeededRate`] — a seeded hash of `(offset, len)`
//!   marks a fraction of ranges as cursed; the *first* attempt on a
//!   cursed range faults, every retry succeeds. This keeps
//!   retry-equipped readers deterministic (they always recover) while
//!   still exercising the fault path at a controlled rate.
//!
//! All plans are deterministic: no wall clock, no OS randomness.

use std::collections::HashSet;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};

use super::{Backend, BackendHealth, BackendRef, CostHint, IoHints, ResilienceStats};

/// SplitMix64 finalizer — the library's standard cheap determinstic
/// hash, used here to derive fault decisions from (seed, offset, len).
pub(crate) fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to a uniform f64 in [0, 1).
pub(crate) fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// What a triggered fault does to the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Hard, permanent device error (not transient — retries fail).
    Hard,
    /// 5xx-style retryable blip (`ConnectionReset`; satisfies
    /// [`Error::is_transient`]).
    Transient,
    /// The device reports it delivered fewer bytes than asked
    /// (`Interrupted`, transient — a retry re-reads the range).
    ShortRead,
    /// Deliver only half the requested bytes but report success; the
    /// rest of the buffer keeps its previous contents. Only checksum
    /// verification can catch this one.
    SilentShortRead,
}

/// Which traffic direction the plan applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultDirection {
    Reads,
    Writes,
    Both,
}

enum PlanState {
    AfterN(AtomicI64),
    EveryNth { n: u64, counter: AtomicU64 },
    SeededRate { seed: u64, rate: f64, forgiven: Mutex<HashSet<(u64, usize)>> },
}

/// Deterministic fault schedule for a [`FaultyBackend`].
#[derive(Clone, Copy, Debug)]
pub enum FaultPlan {
    /// `n` healthy matching calls succeed, all later ones fault.
    AfterN(i64),
    /// Every `n`-th matching call faults (1-based: `EveryNth(4)`
    /// faults calls 4, 8, 12, ...). `n == 0` never faults.
    EveryNth(u64),
    /// A seeded fraction `rate` of distinct `(offset, len)` ranges
    /// fault on their first attempt only.
    SeededRate { seed: u64, rate: f64 },
}

/// Backend wrapper injecting deterministic faults per [`FaultPlan`].
pub struct FaultyBackend {
    inner: BackendRef,
    kind: FaultKind,
    direction: FaultDirection,
    plan: PlanState,
    injected: AtomicU64,
}

impl FaultyBackend {
    pub fn new(inner: BackendRef, kind: FaultKind, direction: FaultDirection, plan: FaultPlan) -> Self {
        let plan = match plan {
            FaultPlan::AfterN(n) => PlanState::AfterN(AtomicI64::new(n)),
            FaultPlan::EveryNth(n) => PlanState::EveryNth { n, counter: AtomicU64::new(0) },
            FaultPlan::SeededRate { seed, rate } => {
                PlanState::SeededRate { seed, rate, forgiven: Mutex::new(HashSet::new()) }
            }
        };
        FaultyBackend { inner, kind, direction, plan, injected: AtomicU64::new(0) }
    }

    /// Shorthand for the classic mid-stream failure: `n` healthy reads
    /// then hard errors (or silent short reads).
    pub fn fail_reads_after(inner: BackendRef, n: i64, silent_short: bool) -> Self {
        let kind = if silent_short { FaultKind::SilentShortRead } else { FaultKind::Hard };
        FaultyBackend::new(inner, kind, FaultDirection::Reads, FaultPlan::AfterN(n))
    }

    /// Re-arm an [`FaultPlan::AfterN`] budget after construction (no
    /// effect on other plans): lets a test open a file through the
    /// wrapper, then schedule the fault mid-stream.
    pub fn arm(&self, n: i64) {
        if let PlanState::AfterN(budget) = &self.plan {
            budget.store(n, Ordering::SeqCst);
        }
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    fn applies(&self, is_write: bool) -> bool {
        match self.direction {
            FaultDirection::Both => true,
            FaultDirection::Reads => !is_write,
            FaultDirection::Writes => is_write,
        }
    }

    /// Decide whether this request faults, advancing plan state.
    fn trips(&self, off: u64, len: usize, is_write: bool) -> bool {
        if !self.applies(is_write) {
            return false;
        }
        let hit = match &self.plan {
            PlanState::AfterN(budget) => budget.fetch_sub(1, Ordering::SeqCst) <= 0,
            PlanState::EveryNth { n, counter } => {
                *n > 0 && counter.fetch_add(1, Ordering::SeqCst) % *n == *n - 1
            }
            PlanState::SeededRate { seed, rate, forgiven } => {
                let cursed =
                    unit(mix(seed ^ mix(off).wrapping_add(mix(len as u64)))) < *rate;
                if !cursed {
                    false
                } else {
                    // First attempt on a cursed range faults; retries
                    // are forgiven so recovery always succeeds.
                    match forgiven.lock() {
                        Ok(mut seen) => seen.insert((off, len)),
                        Err(_) => false,
                    }
                }
            }
        };
        if hit {
            self.injected.fetch_add(1, Ordering::SeqCst);
        }
        hit
    }

    fn fault_error(&self) -> Error {
        use std::io::ErrorKind;
        match self.kind {
            FaultKind::Hard => Error::Io(std::io::Error::other("injected device failure")),
            FaultKind::Transient => Error::Io(std::io::Error::new(
                ErrorKind::ConnectionReset,
                "injected transient fault",
            )),
            FaultKind::ShortRead | FaultKind::SilentShortRead => Error::Io(std::io::Error::new(
                ErrorKind::Interrupted,
                "injected short read",
            )),
        }
    }
}

impl Backend for FaultyBackend {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.read_at_opts(off, buf, IoHints::default())
    }

    fn read_at_opts(&self, off: u64, buf: &mut [u8], hints: IoHints) -> Result<()> {
        if self.trips(off, buf.len(), false) {
            if self.kind == FaultKind::SilentShortRead {
                let half = buf.len() / 2;
                return self.inner.read_at_opts(off, &mut buf[..half], hints);
            }
            return Err(self.fault_error());
        }
        self.inner.read_at_opts(off, buf, hints)
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        if self.trips(off, data.len(), true) {
            // Never a *silent* short write: the point of write faults
            // is testing retry-to-byte-identity, so the device either
            // writes everything or reports failure.
            return Err(self.fault_error());
        }
        self.inner.write_at(off, data)
    }

    fn len(&self) -> Result<u64> {
        self.inner.len()
    }

    fn sync(&self) -> Result<()> {
        self.inner.sync()
    }

    fn describe(&self) -> String {
        format!("faulty({})", self.inner.describe())
    }

    fn health(&self) -> BackendHealth {
        self.inner.health()
    }

    fn cost_hint(&self) -> Option<CostHint> {
        self.inner.cost_hint()
    }

    fn resilience(&self) -> Option<ResilienceStats> {
        self.inner.resilience()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemBackend;
    use std::sync::Arc;

    fn mem_with(data: &[u8]) -> BackendRef {
        Arc::new(MemBackend::from_vec(data.to_vec()))
    }

    #[test]
    fn after_n_lets_n_calls_through_then_fails() {
        let be = FaultyBackend::new(
            mem_with(&[7u8; 64]),
            FaultKind::Hard,
            FaultDirection::Reads,
            FaultPlan::AfterN(2),
        );
        let mut buf = [0u8; 8];
        assert!(be.read_at(0, &mut buf).is_ok());
        assert!(be.read_at(8, &mut buf).is_ok());
        assert!(be.read_at(16, &mut buf).is_err());
        assert!(be.read_at(24, &mut buf).is_err(), "AfterN stays failed");
        assert_eq!(be.injected(), 2);
        // writes untouched by a Reads-direction plan
        assert!(be.write_at(0, &[1, 2]).is_ok());
    }

    #[test]
    fn every_nth_faults_deterministic_count() {
        let be = FaultyBackend::new(
            mem_with(&[0u8; 256]),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::EveryNth(4),
        );
        let mut buf = [0u8; 4];
        let mut errs = 0;
        for i in 0..20 {
            if be.read_at(i * 4, &mut buf).is_err() {
                errs += 1;
            }
        }
        assert_eq!(errs, 5, "exactly every 4th of 20 reads faults");
        assert_eq!(be.injected(), 5);
        let e = be.read_at(0, &mut buf).err();
        assert!(e.is_none(), "21st call (index 20) is healthy");
    }

    #[test]
    fn transient_faults_are_transient_hard_are_not() {
        let t = FaultyBackend::new(
            mem_with(&[0u8; 8]),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::AfterN(0),
        );
        let h = FaultyBackend::new(
            mem_with(&[0u8; 8]),
            FaultKind::Hard,
            FaultDirection::Reads,
            FaultPlan::AfterN(0),
        );
        let mut buf = [0u8; 4];
        assert!(t.read_at(0, &mut buf).unwrap_err().is_transient());
        assert!(!h.read_at(0, &mut buf).unwrap_err().is_transient());
    }

    #[test]
    fn seeded_rate_faults_first_attempt_only() {
        let be = FaultyBackend::new(
            mem_with(&[3u8; 4096]),
            FaultKind::Transient,
            FaultDirection::Reads,
            FaultPlan::SeededRate { seed: 11, rate: 0.5 },
        );
        let mut buf = [0u8; 16];
        let mut faulted = Vec::new();
        for i in 0..64u64 {
            if be.read_at(i * 16, &mut buf).is_err() {
                faulted.push(i);
            }
        }
        assert!(!faulted.is_empty(), "rate 0.5 over 64 ranges must curse some");
        assert!(faulted.len() < 64, "...but not all");
        // every cursed range succeeds on retry
        for &i in &faulted {
            assert!(be.read_at(i * 16, &mut buf).is_ok(), "retry of range {i}");
            assert_eq!(buf, [3u8; 16]);
        }
    }

    #[test]
    fn silent_short_read_truncates_but_reports_ok() {
        let data: Vec<u8> = (0..32).collect();
        let be = FaultyBackend::fail_reads_after(mem_with(&data), 0, true);
        let mut buf = [0xAAu8; 8];
        be.read_at(0, &mut buf).unwrap();
        assert_eq!(&buf[..4], &[0, 1, 2, 3], "first half delivered");
        assert_eq!(&buf[4..], &[0xAA; 4], "second half untouched");
    }

    #[test]
    fn arm_rearms_after_n_budget() {
        let be = FaultyBackend::fail_reads_after(mem_with(&[0u8; 32]), i64::MAX, false);
        let mut buf = [0u8; 4];
        assert!(be.read_at(0, &mut buf).is_ok());
        be.arm(1);
        assert!(be.read_at(0, &mut buf).is_ok());
        assert!(be.read_at(0, &mut buf).is_err());
    }
}
