//! Deterministic simulated storage devices.
//!
//! Cost model per operation: a single device queue (one op in flight,
//! like a disk's command queue drained serially) charging
//! `seek + bytes / bandwidth`, where seek applies when the op is not
//! sequential with the previous one. Contents live in memory, so the
//! *data* path is exact and only the *timing* is modelled.
//!
//! Calibration (sustained large-block write/read, circa the paper's
//! 2017/2018 testbeds):
//!
//! | device | bw write | bw read | seek   |
//! |--------|----------|---------|--------|
//! | HDD    | 150 MB/s | 160 MB/s| 8 ms   |
//! | SSD    | 350 MB/s | 480 MB/s| 80 µs  |
//! | NVMe   | 1400 MB/s| 2500 MB/s| 20 µs |
//! | tmpfs  | 8 GB/s   | 10 GB/s | ~0     |
//!
//! The SSD write figure makes the paper's "over 320 MB/s ... near the
//! hardware limit" observation reproducible, and NVMe/HDD ≈ 4–9× apart
//! brackets the paper's "four times faster" compressed-write gap.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::mem::MemBackend;
use super::{Backend, CostHint};

/// Lock helper: a poisoned device lock surfaces as [`Error::Sync`]
/// instead of cascading the panic into every later caller.
pub(crate) fn lock<T>(m: &Mutex<T>) -> Result<std::sync::MutexGuard<'_, T>> {
    m.lock().map_err(|_| Error::Sync("storage device lock poisoned".into()))
}

/// Device timing parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    pub write_mbps: f64,
    pub read_mbps: f64,
    pub seek: Duration,
}

impl DeviceModel {
    pub fn hdd() -> Self {
        DeviceModel {
            name: "hdd",
            write_mbps: 150.0,
            read_mbps: 160.0,
            seek: Duration::from_millis(8),
        }
    }

    pub fn ssd() -> Self {
        DeviceModel {
            name: "ssd",
            write_mbps: 350.0,
            read_mbps: 480.0,
            seek: Duration::from_micros(80),
        }
    }

    pub fn nvme() -> Self {
        DeviceModel {
            name: "nvme",
            write_mbps: 1400.0,
            read_mbps: 2500.0,
            seek: Duration::from_micros(20),
        }
    }

    pub fn tmpfs() -> Self {
        DeviceModel {
            name: "tmpfs",
            write_mbps: 8000.0,
            read_mbps: 10000.0,
            seek: Duration::from_micros(1),
        }
    }
}

struct QueueState {
    /// When the device becomes free (virtual deadline).
    available_at: Option<Instant>,
    /// End offset of the previous op, for sequentiality detection.
    last_end: u64,
    /// Accumulated busy time (for utilisation reporting).
    busy: Duration,
}

/// In-memory device with the [`DeviceModel`] timing applied.
pub struct SimDevice {
    mem: MemBackend,
    model: DeviceModel,
    time_scale: f64,
    queue: Mutex<QueueState>,
    stats: Mutex<DeviceStats>,
}

/// Operation counters for experiment reporting (the historical
/// aggregate view; [`SimDevice::device_stats`] splits directions and
/// adds queueing).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub ops: u64,
    pub seeks: u64,
}

/// Per-device fetch counters ([`SimDevice::device_stats`]): reads and
/// writes split out, bytes per direction, seeks, and accumulated
/// queue wait — enough for the read-prefetch experiment to report the
/// **coalescing factor** (device reads issued before vs after basket
/// coalescing) and how backed up the single-issue queue ran.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceStats {
    /// Read operations issued.
    pub reads: u64,
    /// Write operations issued.
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Ops that paid a seek (non-sequential with their predecessor).
    pub seeks: u64,
    /// Scaled wall time operations spent queued behind the device's
    /// single-issue queue before their own service began (zero in
    /// pure accounting mode, `time_scale` = 0).
    pub queue_wait: Duration,
    /// Modelled (unscaled) time spent on seeks / first-byte latency.
    pub seek_time: Duration,
    /// Modelled (unscaled) time spent streaming bytes.
    pub transfer_time: Duration,
    /// Injected transient faults delivered (remote device only):
    /// 5xx-style retryable errors.
    pub faults: u64,
    /// Requests failed because modelled service time exceeded the
    /// caller's deadline, or an injected timeout fault fired.
    pub timeouts: u64,
    /// Injected short reads (fewer bytes than requested delivered).
    pub short_reads: u64,
    /// Requests that got stuck (served, but far beyond p99 — the case
    /// hedging rescues).
    pub stuck: u64,
}

impl DeviceStats {
    /// Counters accumulated since the `earlier` snapshot — how
    /// experiments isolate one phase (e.g. the read sweep after the
    /// file was written).
    pub fn since(&self, earlier: &DeviceStats) -> DeviceStats {
        DeviceStats {
            reads: self.reads - earlier.reads,
            writes: self.writes - earlier.writes,
            bytes_read: self.bytes_read - earlier.bytes_read,
            bytes_written: self.bytes_written - earlier.bytes_written,
            seeks: self.seeks - earlier.seeks,
            queue_wait: self.queue_wait.saturating_sub(earlier.queue_wait),
            seek_time: self.seek_time.saturating_sub(earlier.seek_time),
            transfer_time: self.transfer_time.saturating_sub(earlier.transfer_time),
            faults: self.faults - earlier.faults,
            timeouts: self.timeouts - earlier.timeouts,
            short_reads: self.short_reads - earlier.short_reads,
            stuck: self.stuck - earlier.stuck,
        }
    }

    /// Observed per-request cost: mean seek time over ops that paid
    /// one, and achieved bandwidth from transfer time. `None` until
    /// there is at least one seek and one transferred byte.
    pub fn cost_hint(&self) -> Option<CostHint> {
        let bytes = self.bytes_read + self.bytes_written;
        if self.seeks == 0 || bytes == 0 || self.transfer_time.is_zero() {
            return None;
        }
        Some(CostHint {
            seek_secs: self.seek_time.as_secs_f64() / self.seeks as f64,
            read_mbps: bytes as f64 / 1e6 / self.transfer_time.as_secs_f64(),
        })
    }
}

impl SimDevice {
    /// `time_scale` multiplies all modelled costs. 1.0 = real time;
    /// 0.0 = count costs but never sleep (pure accounting mode).
    pub fn new(model: DeviceModel, time_scale: f64) -> Self {
        SimDevice {
            mem: MemBackend::new(),
            model,
            time_scale,
            queue: Mutex::new(QueueState {
                available_at: None,
                last_end: u64::MAX,
                busy: Duration::ZERO,
            }),
            stats: Mutex::new(DeviceStats::default()),
        }
    }

    pub fn model(&self) -> &DeviceModel {
        &self.model
    }

    /// Aggregate op counters (the historical view).
    pub fn stats(&self) -> SimStats {
        let d = self.device_stats();
        SimStats {
            bytes_written: d.bytes_written,
            bytes_read: d.bytes_read,
            ops: d.reads + d.writes,
            seeks: d.seeks,
        }
    }

    /// Direction-split fetch counters incl. queue wait (see
    /// [`DeviceStats`]).
    pub fn device_stats(&self) -> DeviceStats {
        *self.stats.lock().unwrap()
    }

    /// Total modelled busy time (unscaled).
    pub fn busy_time(&self) -> Duration {
        self.queue.lock().unwrap().busy
    }

    fn charge(&self, off: u64, len: usize, mbps: f64, is_write: bool) -> Result<()> {
        let transfer = Duration::from_secs_f64(len as f64 / (mbps * 1e6));
        let (cost, _deadline) = {
            let mut q = lock(&self.queue)?;
            let seek = if q.last_end == off { Duration::ZERO } else { self.model.seek };
            let cost = seek + transfer;
            q.last_end = off + len as u64;
            q.busy += cost;
            // Single-issue queue: ops serialise on the device.
            let scaled = cost.mul_f64(self.time_scale.max(0.0));
            let now = Instant::now();
            let start = match q.available_at {
                Some(t) if t > now => t,
                _ => now,
            };
            let deadline = start + scaled;
            q.available_at = Some(deadline);
            let mut st = lock(&self.stats)?;
            if seek > Duration::ZERO {
                st.seeks += 1;
                st.seek_time += seek;
            }
            st.transfer_time += transfer;
            if is_write {
                st.writes += 1;
                st.bytes_written += len as u64;
            } else {
                st.reads += 1;
                st.bytes_read += len as u64;
            }
            st.queue_wait += start.saturating_duration_since(now);
            (scaled, deadline)
        };
        if self.time_scale > 0.0 {
            // Sleep outside the lock: concurrent callers pile onto the
            // device queue exactly like blocked writers on one disk.
            let target = {
                let q = lock(&self.queue)?;
                q.available_at
            };
            if let Some(t) = target {
                let now = Instant::now();
                if t > now {
                    std::thread::sleep(t - now);
                }
            }
            let _ = cost;
        }
        Ok(())
    }
}

impl Backend for SimDevice {
    fn read_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        self.charge(off, buf.len(), self.model.read_mbps, false)?;
        self.mem.read_at(off, buf)
    }

    fn write_at(&self, off: u64, data: &[u8]) -> Result<()> {
        self.charge(off, data.len(), self.model.write_mbps, true)?;
        self.mem.write_at(off, data)
    }

    fn len(&self) -> Result<u64> {
        self.mem.len()
    }

    fn describe(&self) -> String {
        format!("sim:{} ({} MB/s write)", self.model.name, self.model.write_mbps)
    }

    fn cost_hint(&self) -> Option<CostHint> {
        // Prefer observed costs; fall back to the model so adaptive
        // coalescing works before any traffic has flowed.
        self.device_stats().cost_hint().or(Some(CostHint {
            seek_secs: self.model.seek.as_secs_f64(),
            read_mbps: self.model.read_mbps,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_path_is_exact() {
        let d = SimDevice::new(DeviceModel::nvme(), 0.0);
        d.write_at(5, b"payload").unwrap();
        let mut buf = [0u8; 7];
        d.read_at(5, &mut buf).unwrap();
        assert_eq!(&buf, b"payload");
    }

    #[test]
    fn sequential_writes_skip_seeks() {
        let d = SimDevice::new(DeviceModel::hdd(), 0.0);
        d.write_at(0, &[0u8; 100]).unwrap();
        d.write_at(100, &[0u8; 100]).unwrap();
        d.write_at(200, &[0u8; 100]).unwrap();
        d.write_at(1000, &[0u8; 100]).unwrap(); // seek
        let st = d.stats();
        assert_eq!(st.ops, 4);
        assert_eq!(st.seeks, 2); // first op + the jump
        assert_eq!(st.bytes_written, 400);
    }

    #[test]
    fn busy_time_scales_with_bytes_and_bandwidth() {
        let hdd = SimDevice::new(DeviceModel::hdd(), 0.0);
        let nvme = SimDevice::new(DeviceModel::nvme(), 0.0);
        let blob = vec![0u8; 10_000_000];
        hdd.write_at(0, &blob).unwrap();
        nvme.write_at(0, &blob).unwrap();
        let r = hdd.busy_time().as_secs_f64() / nvme.busy_time().as_secs_f64();
        // 1400/150 ≈ 9.3, seek adds a bit on top for the hdd
        assert!(r > 8.0 && r < 11.0, "ratio {r}");
    }

    #[test]
    fn device_stats_split_directions_and_diff_snapshots() {
        let d = SimDevice::new(DeviceModel::ssd(), 0.0);
        d.write_at(0, &[0u8; 100]).unwrap();
        let mut buf = [0u8; 50];
        d.read_at(0, &mut buf).unwrap();
        d.read_at(50, &mut buf).unwrap();
        let st = d.device_stats();
        assert_eq!((st.writes, st.reads), (1, 2));
        assert_eq!((st.bytes_written, st.bytes_read), (100, 100));
        // the legacy aggregate view stays consistent
        let legacy = d.stats();
        assert_eq!(legacy.ops, 3);
        assert_eq!(legacy.bytes_read, 100);
        // phase isolation via snapshots
        let before = d.device_stats();
        d.read_at(0, &mut buf).unwrap();
        let delta = d.device_stats().since(&before);
        assert_eq!((delta.reads, delta.writes, delta.bytes_read), (1, 0, 50));
        assert_eq!(delta.seeks, 1, "rewind to offset 0 seeks");
    }

    #[test]
    fn queue_wait_accumulates_when_ops_pile_up() {
        use std::sync::{Arc, Barrier};
        // Four writers released together: the single-issue queue
        // serialises their ~15 ms ops (1 MB at 150 MB/s + 8 ms seek),
        // so at least one arrival lands while the device is busy and
        // its wait is accounted. Spuriously passing zero wait would
        // require *every* later thread to be descheduled past the
        // whole backlog ahead of it (>= 15/30/45 ms independently) —
        // far beyond ordinary CI jitter.
        let d = Arc::new(SimDevice::new(DeviceModel::hdd(), 1.0));
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let d = d.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let buf = vec![0u8; 1_000_000];
                    barrier.wait();
                    d.write_at(i * 50_000_000, &buf).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let st = d.device_stats();
        assert_eq!(st.writes, 4);
        assert!(
            st.queue_wait >= Duration::from_millis(1),
            "later ops must have queued: waited only {:?}",
            st.queue_wait
        );
    }

    #[test]
    fn real_sleep_when_scaled() {
        let d = SimDevice::new(DeviceModel::hdd(), 1.0);
        let t0 = Instant::now();
        // 1.5 MB at 150 MB/s = 10 ms + 8 ms seek
        d.write_at(0, &vec![0u8; 1_500_000]).unwrap();
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(15), "slept only {dt:?}");
    }

    #[test]
    fn queue_serialises_concurrent_writers() {
        use std::sync::Arc;
        let d = Arc::new(SimDevice::new(DeviceModel::hdd(), 1.0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let d = d.clone();
                std::thread::spawn(move || {
                    // 0.75 MB each at 150 MB/s = 5 ms + seek
                    d.write_at(i * 10_000_000, &vec![0u8; 750_000]).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let dt = t0.elapsed();
        // 4 ops serialised: >= 4 * (5 ms + 8 ms seek) minus tolerance
        assert!(dt >= Duration::from_millis(40), "took only {dt:?}");
    }
}
