//! Row ⇄ column streamer: ROOT's "splitting" of objects into branches.

use crate::error::{Error, Result};

use super::column::ColumnData;
use super::schema::Schema;
use super::value::{Row, Value};

/// Splits rows into per-field column accumulators and reassembles rows
/// from decoded columns. One streamer per tree.
#[derive(Clone, Debug)]
pub struct Streamer {
    schema: Schema,
}

impl Streamer {
    pub fn new(schema: Schema) -> Self {
        Streamer { schema }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Fresh, empty column accumulators in schema order.
    pub fn make_columns(&self) -> Vec<ColumnData> {
        self.schema.fields.iter().map(|f| ColumnData::new(f.ty)).collect()
    }

    /// Split one row into the accumulators (type-checked).
    pub fn fill(&self, cols: &mut [ColumnData], row: Row) -> Result<()> {
        if row.len() != self.schema.len() {
            return Err(Error::Schema(format!(
                "row has {} cells, schema has {} fields",
                row.len(),
                self.schema.len()
            )));
        }
        for (col, cell) in cols.iter_mut().zip(row) {
            col.push(cell)?;
        }
        Ok(())
    }

    /// Reassemble row `i` from decoded columns.
    pub fn assemble(&self, cols: &[ColumnData], i: usize) -> Result<Row> {
        cols.iter()
            .map(|c| {
                c.get(i).ok_or_else(|| {
                    Error::Schema(format!("entry {i} out of range (len {})", c.len()))
                })
            })
            .collect()
    }

    /// Convenience: split a batch of rows into fresh columns.
    pub fn split(&self, rows: Vec<Row>) -> Result<Vec<ColumnData>> {
        let mut cols = self.make_columns();
        for row in rows {
            self.fill(&mut cols, row)?;
        }
        Ok(cols)
    }

    /// Convenience: reassemble all rows from columns.
    pub fn unsplit(&self, cols: &[ColumnData]) -> Result<Vec<Row>> {
        let n = cols.first().map(|c| c.len()).unwrap_or(0);
        for (c, f) in cols.iter().zip(&self.schema.fields) {
            if c.len() != n {
                return Err(Error::Schema(format!(
                    "column '{}' has {} entries, expected {n}",
                    f.name,
                    c.len()
                )));
            }
        }
        (0..n).map(|i| self.assemble(cols, i)).collect()
    }
}

/// Build a row from plain values: `row![1i32, 2.5f32, "tag"]`-style helper.
pub fn row(values: Vec<Value>) -> Row {
    values
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::schema::{ColumnType, Field};

    fn schema() -> Schema {
        Schema::new(vec![
            Field::new("id", ColumnType::I32),
            Field::new("e", ColumnType::F64),
            Field::new("name", ColumnType::Bytes),
        ])
    }

    fn rows() -> Vec<Row> {
        (0..50)
            .map(|i| {
                vec![
                    Value::I32(i),
                    Value::F64(i as f64 * 0.5),
                    Value::Bytes(format!("evt{i}").into_bytes()),
                ]
            })
            .collect()
    }

    #[test]
    fn split_unsplit_roundtrip() {
        let st = Streamer::new(schema());
        let original = rows();
        let cols = st.split(original.clone()).unwrap();
        assert_eq!(cols.len(), 3);
        assert_eq!(cols[0].len(), 50);
        let back = st.unsplit(&cols).unwrap();
        assert_eq!(back, original);
    }

    #[test]
    fn wire_roundtrip_per_column() {
        // The full path a basket takes: split -> encode -> decode -> unsplit.
        let st = Streamer::new(schema());
        let original = rows();
        let cols = st.split(original.clone()).unwrap();
        let decoded: Vec<ColumnData> = cols
            .iter()
            .zip(&st.schema().fields)
            .map(|(c, f)| ColumnData::decode(f.ty, &c.encode(), c.len()).unwrap())
            .collect();
        assert_eq!(st.unsplit(&decoded).unwrap(), original);
    }

    #[test]
    fn fill_rejects_wrong_arity_and_type() {
        let st = Streamer::new(schema());
        let mut cols = st.make_columns();
        assert!(st.fill(&mut cols, vec![Value::I32(1)]).is_err());
        assert!(st
            .fill(
                &mut cols,
                vec![Value::F32(1.0), Value::F64(1.0), Value::Bytes(vec![])]
            )
            .is_err());
    }

    #[test]
    fn unsplit_rejects_ragged_columns() {
        let st = Streamer::new(schema());
        let mut cols = st.make_columns();
        cols[0].push(Value::I32(1)).unwrap();
        assert!(st.unsplit(&cols).is_err());
    }

    #[test]
    fn empty_batch() {
        let st = Streamer::new(schema());
        let cols = st.split(vec![]).unwrap();
        assert_eq!(st.unsplit(&cols).unwrap(), Vec::<Row>::new());
    }
}
