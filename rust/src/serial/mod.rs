//! Object streamers: the (de)serialisation phase of ROOT I/O.
//!
//! ROOT auto-generates streamers that split C++ objects into per-member
//! columns ("splitting"). Here a [`schema::Schema`] plays the role of the
//! streamer-info dictionary: it describes an event record as a list of
//! typed fields, and [`streamer::Streamer`] turns batches of rows into
//! per-column byte buffers (big-endian, like ROOT's on-disk format) and
//! back.
//!
//! Serialisation and deserialisation of *different columns are
//! independent* — this is precisely the property the paper exploits to
//! parallelise both directions (§2.1, §3.1).

pub mod column;
pub mod schema;
pub mod streamer;
pub mod value;

pub use column::ColumnData;
pub use schema::{ColumnType, Field, Schema};
pub use streamer::Streamer;
pub use value::{Row, Value};
