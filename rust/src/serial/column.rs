//! Typed column buffers: the in-memory and on-wire form of one branch's
//! data for a range of entries.

use crate::error::{Error, Result};

use super::schema::ColumnType;
use super::value::Value;

/// Decoded column data for a contiguous entry range.
#[derive(Clone, Debug, PartialEq)]
pub enum ColumnData {
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
    U8(Vec<u8>),
    Bytes(Vec<Vec<u8>>),
    ListF32(Vec<Vec<f32>>),
}

impl ColumnData {
    pub fn new(ty: ColumnType) -> Self {
        match ty {
            ColumnType::I32 => ColumnData::I32(Vec::new()),
            ColumnType::I64 => ColumnData::I64(Vec::new()),
            ColumnType::F32 => ColumnData::F32(Vec::new()),
            ColumnType::F64 => ColumnData::F64(Vec::new()),
            ColumnType::U8 => ColumnData::U8(Vec::new()),
            ColumnType::Bytes => ColumnData::Bytes(Vec::new()),
            ColumnType::ListF32 => ColumnData::ListF32(Vec::new()),
        }
    }

    pub fn column_type(&self) -> ColumnType {
        match self {
            ColumnData::I32(_) => ColumnType::I32,
            ColumnData::I64(_) => ColumnType::I64,
            ColumnData::F32(_) => ColumnType::F32,
            ColumnData::F64(_) => ColumnType::F64,
            ColumnData::U8(_) => ColumnType::U8,
            ColumnData::Bytes(_) => ColumnType::Bytes,
            ColumnData::ListF32(_) => ColumnType::ListF32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ColumnData::I32(v) => v.len(),
            ColumnData::I64(v) => v.len(),
            ColumnData::F32(v) => v.len(),
            ColumnData::F64(v) => v.len(),
            ColumnData::U8(v) => v.len(),
            ColumnData::Bytes(v) => v.len(),
            ColumnData::ListF32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate in-memory payload bytes (used for basket sizing).
    pub fn byte_len(&self) -> usize {
        match self {
            ColumnData::I32(v) => v.len() * 4,
            ColumnData::I64(v) => v.len() * 8,
            ColumnData::F32(v) => v.len() * 4,
            ColumnData::F64(v) => v.len() * 8,
            ColumnData::U8(v) => v.len(),
            ColumnData::Bytes(v) => v.iter().map(|b| 4 + b.len()).sum(),
            ColumnData::ListF32(v) => v.iter().map(|l| 4 + 4 * l.len()).sum(),
        }
    }

    pub fn push(&mut self, v: Value) -> Result<()> {
        match (self, v) {
            (ColumnData::I32(c), Value::I32(x)) => c.push(x),
            (ColumnData::I64(c), Value::I64(x)) => c.push(x),
            (ColumnData::F32(c), Value::F32(x)) => c.push(x),
            (ColumnData::F64(c), Value::F64(x)) => c.push(x),
            (ColumnData::U8(c), Value::U8(x)) => c.push(x),
            (ColumnData::Bytes(c), Value::Bytes(x)) => c.push(x),
            (ColumnData::ListF32(c), Value::ListF32(x)) => c.push(x),
            (c, v) => {
                return Err(Error::Schema(format!(
                    "type mismatch: column {:?}, value {:?}",
                    c.column_type(),
                    v.column_type()
                )))
            }
        }
        Ok(())
    }

    pub fn get(&self, i: usize) -> Option<Value> {
        match self {
            ColumnData::I32(v) => v.get(i).map(|&x| Value::I32(x)),
            ColumnData::I64(v) => v.get(i).map(|&x| Value::I64(x)),
            ColumnData::F32(v) => v.get(i).map(|&x| Value::F32(x)),
            ColumnData::F64(v) => v.get(i).map(|&x| Value::F64(x)),
            ColumnData::U8(v) => v.get(i).map(|&x| Value::U8(x)),
            ColumnData::Bytes(v) => v.get(i).map(|x| Value::Bytes(x.clone())),
            ColumnData::ListF32(v) => v.get(i).map(|x| Value::ListF32(x.clone())),
        }
    }

    pub fn clear(&mut self) {
        match self {
            ColumnData::I32(v) => v.clear(),
            ColumnData::I64(v) => v.clear(),
            ColumnData::F32(v) => v.clear(),
            ColumnData::F64(v) => v.clear(),
            ColumnData::U8(v) => v.clear(),
            ColumnData::Bytes(v) => v.clear(),
            ColumnData::ListF32(v) => v.clear(),
        }
    }

    /// Serialise to the on-wire (big-endian) representation, appending
    /// to `out` (typically a pooled scratch buffer — see
    /// [`crate::compress::pool`] — so steady-state flushes do not
    /// allocate).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.byte_len());
        match self {
            ColumnData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_be_bytes());
                }
            }
            ColumnData::I64(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_be_bytes());
                }
            }
            ColumnData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_be_bytes());
                }
            }
            ColumnData::F64(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_be_bytes());
                }
            }
            ColumnData::U8(v) => out.extend_from_slice(v),
            ColumnData::Bytes(v) => {
                for b in v {
                    out.extend_from_slice(&(b.len() as u32).to_be_bytes());
                    out.extend_from_slice(b);
                }
            }
            ColumnData::ListF32(v) => {
                for l in v {
                    out.extend_from_slice(&(l.len() as u32).to_be_bytes());
                    for x in l {
                        out.extend_from_slice(&x.to_be_bytes());
                    }
                }
            }
        }
    }

    /// Serialise to a fresh on-wire buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len());
        self.encode_into(&mut out);
        out
    }

    /// Deserialise `count` entries of type `ty` from wire bytes.
    pub fn decode(ty: ColumnType, buf: &[u8], count: usize) -> Result<Self> {
        let err = |m: String| Error::Schema(format!("column decode: {m}"));
        fn fixed<T, const W: usize>(
            buf: &[u8],
            count: usize,
            f: impl Fn([u8; W]) -> T,
        ) -> Result<Vec<T>> {
            if buf.len() != count * W {
                return Err(Error::Schema(format!(
                    "column decode: want {} bytes, have {}",
                    count * W,
                    buf.len()
                )));
            }
            Ok(buf.chunks_exact(W).map(|c| f(c.try_into().unwrap())).collect())
        }
        Ok(match ty {
            ColumnType::I32 => ColumnData::I32(fixed(buf, count, i32::from_be_bytes)?),
            ColumnType::I64 => ColumnData::I64(fixed(buf, count, i64::from_be_bytes)?),
            ColumnType::F32 => ColumnData::F32(fixed(buf, count, f32::from_be_bytes)?),
            ColumnType::F64 => ColumnData::F64(fixed(buf, count, f64::from_be_bytes)?),
            ColumnType::U8 => {
                if buf.len() != count {
                    return Err(err(format!("want {} bytes, have {}", count, buf.len())));
                }
                ColumnData::U8(buf.to_vec())
            }
            ColumnType::Bytes => {
                let mut v = Vec::with_capacity(count);
                let mut pos = 0usize;
                for _ in 0..count {
                    if pos + 4 > buf.len() {
                        return Err(err("truncated length prefix".into()));
                    }
                    let n = u32::from_be_bytes([
                        buf[pos],
                        buf[pos + 1],
                        buf[pos + 2],
                        buf[pos + 3],
                    ]) as usize;
                    pos += 4;
                    if pos + n > buf.len() {
                        return Err(err("truncated payload".into()));
                    }
                    v.push(buf[pos..pos + n].to_vec());
                    pos += n;
                }
                if pos != buf.len() {
                    return Err(err("trailing bytes".into()));
                }
                ColumnData::Bytes(v)
            }
            ColumnType::ListF32 => {
                let mut v = Vec::with_capacity(count);
                let mut pos = 0usize;
                for _ in 0..count {
                    if pos + 4 > buf.len() {
                        return Err(err("truncated length prefix".into()));
                    }
                    let n = u32::from_be_bytes([
                        buf[pos],
                        buf[pos + 1],
                        buf[pos + 2],
                        buf[pos + 3],
                    ]) as usize;
                    pos += 4;
                    if pos + 4 * n > buf.len() {
                        return Err(err("truncated payload".into()));
                    }
                    v.push(
                        buf[pos..pos + 4 * n]
                            .chunks_exact(4)
                            .map(|c| f32::from_be_bytes(c.try_into().unwrap()))
                            .collect(),
                    );
                    pos += 4 * n;
                }
                if pos != buf.len() {
                    return Err(err("trailing bytes".into()));
                }
                ColumnData::ListF32(v)
            }
        })
    }

    /// Append all entries of `other` (same type) — used by hadd/merger.
    pub fn append(&mut self, other: &ColumnData) -> Result<()> {
        match (self, other) {
            (ColumnData::I32(a), ColumnData::I32(b)) => a.extend_from_slice(b),
            (ColumnData::I64(a), ColumnData::I64(b)) => a.extend_from_slice(b),
            (ColumnData::F32(a), ColumnData::F32(b)) => a.extend_from_slice(b),
            (ColumnData::F64(a), ColumnData::F64(b)) => a.extend_from_slice(b),
            (ColumnData::U8(a), ColumnData::U8(b)) => a.extend_from_slice(b),
            (ColumnData::Bytes(a), ColumnData::Bytes(b)) => a.extend_from_slice(b),
            (ColumnData::ListF32(a), ColumnData::ListF32(b)) => a.extend_from_slice(b),
            (a, b) => {
                return Err(Error::Schema(format!(
                    "append type mismatch: {:?} vs {:?}",
                    a.column_type(),
                    b.column_type()
                )))
            }
        }
        Ok(())
    }

    /// Remove and return the first `n` entries (basket chunking).
    pub fn drain_front(&mut self, n: usize) -> ColumnData {
        match self {
            ColumnData::I32(v) => ColumnData::I32(v.drain(..n).collect()),
            ColumnData::I64(v) => ColumnData::I64(v.drain(..n).collect()),
            ColumnData::F32(v) => ColumnData::F32(v.drain(..n).collect()),
            ColumnData::F64(v) => ColumnData::F64(v.drain(..n).collect()),
            ColumnData::U8(v) => ColumnData::U8(v.drain(..n).collect()),
            ColumnData::Bytes(v) => ColumnData::Bytes(v.drain(..n).collect()),
            ColumnData::ListF32(v) => ColumnData::ListF32(v.drain(..n).collect()),
        }
    }

    /// View as f32 slice (the PJRT hand-off path for analysis columns).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            ColumnData::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Split a `ListF32` column into the v3 paged pair: an `I64` offset
    /// column of *page-relative* end offsets (one per row) and an `F32`
    /// element column of the flattened values. Page-relative offsets
    /// keep stored pages position-independent, so hadd can raw-copy
    /// them without rewriting payload bytes.
    pub fn split_list(self) -> Result<(ColumnData, ColumnData)> {
        let rows = match self {
            ColumnData::ListF32(rows) => rows,
            other => {
                return Err(Error::Schema(format!(
                    "split_list on {:?} column",
                    other.column_type()
                )))
            }
        };
        let total: usize = rows.iter().map(|r| r.len()).sum();
        let mut offsets = Vec::with_capacity(rows.len());
        let mut elems = Vec::with_capacity(total);
        let mut end = 0i64;
        for r in rows {
            end += r.len() as i64;
            offsets.push(end);
            elems.extend_from_slice(&r);
        }
        Ok((ColumnData::I64(offsets), ColumnData::F32(elems)))
    }

    /// Reassemble a `ListF32` column from a decoded offset/element page
    /// pair (the inverse of [`ColumnData::split_list`]).
    pub fn zip_list(offsets: &ColumnData, elems: &ColumnData) -> Result<ColumnData> {
        let err = |m: String| Error::Format(format!("list page decode: {m}"));
        let (offs, els) = match (offsets, elems) {
            (ColumnData::I64(o), ColumnData::F32(e)) => (o, e),
            (o, e) => {
                return Err(err(format!(
                    "want i64 offsets + f32 elements, got {:?} + {:?}",
                    o.column_type(),
                    e.column_type()
                )))
            }
        };
        let mut rows = Vec::with_capacity(offs.len());
        let mut start = 0usize;
        for (i, &end) in offs.iter().enumerate() {
            let end = usize::try_from(end)
                .map_err(|_| err(format!("negative end offset at row {i}")))?;
            if end < start || end > els.len() {
                return Err(err(format!(
                    "row {i} spans {start}..{end} of {} elements",
                    els.len()
                )));
            }
            rows.push(els[start..end].to_vec());
            start = end;
        }
        if start != els.len() {
            return Err(err(format!(
                "offsets cover {start} of {} elements",
                els.len()
            )));
        }
        Ok(ColumnData::ListF32(rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(col: ColumnData) {
        let n = col.len();
        let wire = col.encode();
        let back = ColumnData::decode(col.column_type(), &wire, n).unwrap();
        assert_eq!(back, col);
    }

    #[test]
    fn all_types_roundtrip() {
        roundtrip(ColumnData::I32(vec![1, -2, i32::MAX, i32::MIN]));
        roundtrip(ColumnData::I64(vec![1, -2, i64::MAX, i64::MIN]));
        roundtrip(ColumnData::F32(vec![0.0, -1.5, f32::MAX, f32::MIN_POSITIVE]));
        roundtrip(ColumnData::F64(vec![0.0, 2.5e300, f64::MIN_POSITIVE]));
        roundtrip(ColumnData::U8(vec![0, 255, 7]));
        roundtrip(ColumnData::Bytes(vec![b"".to_vec(), b"hello".to_vec(), vec![0u8; 1000]]));
        roundtrip(ColumnData::ListF32(vec![vec![], vec![1.5, -2.5], vec![0.0; 500]]));
    }

    #[test]
    fn list_split_zip_roundtrip() {
        let col = ColumnData::ListF32(vec![vec![1.0, 2.0], vec![], vec![3.0]]);
        let (offs, els) = col.clone().split_list().unwrap();
        assert_eq!(offs, ColumnData::I64(vec![2, 2, 3]));
        assert_eq!(els, ColumnData::F32(vec![1.0, 2.0, 3.0]));
        assert_eq!(ColumnData::zip_list(&offs, &els).unwrap(), col);
        // empty column splits to empty pair and zips back
        let empty = ColumnData::ListF32(vec![]);
        let (o, e) = empty.clone().split_list().unwrap();
        assert_eq!(ColumnData::zip_list(&o, &e).unwrap(), empty);
    }

    #[test]
    fn zip_list_rejects_bad_offsets() {
        let els = ColumnData::F32(vec![1.0, 2.0]);
        // decreasing offsets
        assert!(ColumnData::zip_list(&ColumnData::I64(vec![2, 1]), &els).is_err());
        // past the end
        assert!(ColumnData::zip_list(&ColumnData::I64(vec![3]), &els).is_err());
        // elements left uncovered
        assert!(ColumnData::zip_list(&ColumnData::I64(vec![1]), &els).is_err());
        // negative
        assert!(ColumnData::zip_list(&ColumnData::I64(vec![-1]), &els).is_err());
        // wrong types
        assert!(ColumnData::zip_list(&ColumnData::F32(vec![]), &els).is_err());
        assert!(ColumnData::split_list(ColumnData::F32(vec![])).is_err());
    }

    #[test]
    fn nan_roundtrips_bitwise() {
        let col = ColumnData::F32(vec![f32::NAN]);
        let wire = col.encode();
        let back = ColumnData::decode(ColumnType::F32, &wire, 1).unwrap();
        if let ColumnData::F32(v) = back {
            assert!(v[0].is_nan());
        } else {
            panic!("wrong type");
        }
    }

    #[test]
    fn push_type_safety() {
        let mut col = ColumnData::new(ColumnType::F32);
        col.push(Value::F32(1.0)).unwrap();
        assert!(col.push(Value::I32(1)).is_err());
        assert_eq!(col.len(), 1);
    }

    #[test]
    fn decode_wrong_sizes() {
        assert!(ColumnData::decode(ColumnType::I32, &[0u8; 7], 2).is_err());
        assert!(ColumnData::decode(ColumnType::Bytes, &[0, 0, 0, 5, b'a'], 1).is_err());
        // trailing garbage after var column
        let mut wire = ColumnData::Bytes(vec![b"ab".to_vec()]).encode();
        wire.push(0);
        assert!(ColumnData::decode(ColumnType::Bytes, &wire, 1).is_err());
    }

    #[test]
    fn append_and_get() {
        let mut a = ColumnData::I32(vec![1, 2]);
        let b = ColumnData::I32(vec![3]);
        a.append(&b).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a.get(2), Some(Value::I32(3)));
        assert_eq!(a.get(3), None);
        assert!(a.append(&ColumnData::F32(vec![1.0])).is_err());
    }

    #[test]
    fn byte_len_matches_encoding() {
        let cols = [
            ColumnData::I32(vec![5; 10]),
            ColumnData::F64(vec![1.0; 3]),
            ColumnData::Bytes(vec![b"xy".to_vec(), b"".to_vec()]),
        ];
        for c in cols {
            assert_eq!(c.byte_len(), c.encode().len());
        }
    }
}
