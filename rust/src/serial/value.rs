//! Dynamically-typed cell values and rows (the user-facing fill API).

use super::schema::ColumnType;

/// One cell of an event record.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    I32(i32),
    I64(i64),
    F32(f32),
    F64(f64),
    U8(u8),
    Bytes(Vec<u8>),
    ListF32(Vec<f32>),
}

impl Value {
    pub fn column_type(&self) -> ColumnType {
        match self {
            Value::I32(_) => ColumnType::I32,
            Value::I64(_) => ColumnType::I64,
            Value::F32(_) => ColumnType::F32,
            Value::F64(_) => ColumnType::F64,
            Value::U8(_) => ColumnType::U8,
            Value::Bytes(_) => ColumnType::Bytes,
            Value::ListF32(_) => ColumnType::ListF32,
        }
    }
}

impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::I32(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}
impl From<f32> for Value {
    fn from(v: f32) -> Self {
        Value::F32(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}
impl From<u8> for Value {
    fn from(v: u8) -> Self {
        Value::U8(v)
    }
}
impl From<Vec<u8>> for Value {
    fn from(v: Vec<u8>) -> Self {
        Value::Bytes(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Bytes(v.as_bytes().to_vec())
    }
}
impl From<Vec<f32>> for Value {
    fn from(v: Vec<f32>) -> Self {
        Value::ListF32(v)
    }
}

/// One event record: a cell per schema field, in schema order.
pub type Row = Vec<Value>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_impls() {
        assert_eq!(Value::from(1i32), Value::I32(1));
        assert_eq!(Value::from(1i64), Value::I64(1));
        assert_eq!(Value::from(1.5f32), Value::F32(1.5));
        assert_eq!(Value::from(2.5f64), Value::F64(2.5));
        assert_eq!(Value::from(7u8), Value::U8(7));
        assert_eq!(Value::from("hi"), Value::Bytes(b"hi".to_vec()));
        assert_eq!(Value::from(vec![1.0f32, 2.0]), Value::ListF32(vec![1.0, 2.0]));
    }

    #[test]
    fn column_types() {
        assert_eq!(Value::I32(0).column_type(), ColumnType::I32);
        assert_eq!(Value::Bytes(vec![]).column_type(), ColumnType::Bytes);
    }
}
