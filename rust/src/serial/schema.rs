//! Event-record schemas (ROOT streamer-info analogue).

use crate::error::{Error, Result};

/// Column (leaf) types. Fixed-width types serialise big-endian like
/// ROOT's on-disk representation; `Bytes` is a variable-length payload
/// with a u32 length prefix (TString analogue); `ListF32` is a
/// variable-length collection of f32 (std::vector<float> analogue) —
/// inline-coded in classic baskets, split into offset+element page
/// pairs by the v3 paged layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ColumnType {
    I32,
    I64,
    F32,
    F64,
    U8,
    Bytes,
    ListF32,
}

impl ColumnType {
    pub fn code(self) -> u8 {
        match self {
            ColumnType::I32 => 0,
            ColumnType::I64 => 1,
            ColumnType::F32 => 2,
            ColumnType::F64 => 3,
            ColumnType::U8 => 4,
            ColumnType::Bytes => 5,
            ColumnType::ListF32 => 6,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => ColumnType::I32,
            1 => ColumnType::I64,
            2 => ColumnType::F32,
            3 => ColumnType::F64,
            4 => ColumnType::U8,
            5 => ColumnType::Bytes,
            6 => ColumnType::ListF32,
            other => return Err(Error::Schema(format!("bad column type code {other}"))),
        })
    }

    /// Fixed on-disk width, or None for variable-length columns.
    pub fn width(self) -> Option<usize> {
        match self {
            ColumnType::I32 | ColumnType::F32 => Some(4),
            ColumnType::I64 | ColumnType::F64 => Some(8),
            ColumnType::U8 => Some(1),
            ColumnType::Bytes | ColumnType::ListF32 => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ColumnType::I32 => "i32",
            ColumnType::I64 => "i64",
            ColumnType::F32 => "f32",
            ColumnType::F64 => "f64",
            ColumnType::U8 => "u8",
            ColumnType::Bytes => "bytes",
            ColumnType::ListF32 => "list<f32>",
        }
    }
}

/// One named column (TBranch/TLeaf analogue).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub ty: ColumnType,
}

impl Field {
    pub fn new(name: impl Into<String>, ty: ColumnType) -> Self {
        Field { name: name.into(), ty }
    }
}

/// An ordered list of fields describing one event record.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Self {
        Schema { fields }
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|f| f.name == name)
    }

    /// A schema of `n` f32 columns named `<prefix>0..n` — the shape of
    /// the synthetic CMS/ATLAS-like datasets.
    pub fn flat_f32(prefix: &str, n: usize) -> Self {
        Schema {
            fields: (0..n).map(|i| Field::new(format!("{prefix}{i}"), ColumnType::F32)).collect(),
        }
    }

    /// Serialise the schema itself (stored in the file footer).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.fields.len() as u32).to_be_bytes());
        for f in &self.fields {
            out.push(f.ty.code());
            let name = f.name.as_bytes();
            out.extend_from_slice(&(name.len() as u16).to_be_bytes());
            out.extend_from_slice(name);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<(Self, usize)> {
        let err = |m: &str| Error::Schema(format!("schema decode: {m}"));
        if buf.len() < 4 {
            return Err(err("truncated count"));
        }
        let n = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        let mut pos = 4usize;
        let mut fields = Vec::with_capacity(n);
        for _ in 0..n {
            if pos + 3 > buf.len() {
                return Err(err("truncated field"));
            }
            let ty = ColumnType::from_code(buf[pos])?;
            let nlen = u16::from_be_bytes([buf[pos + 1], buf[pos + 2]]) as usize;
            pos += 3;
            if pos + nlen > buf.len() {
                return Err(err("truncated name"));
            }
            let name = std::str::from_utf8(&buf[pos..pos + nlen])
                .map_err(|_| err("name not utf8"))?
                .to_string();
            pos += nlen;
            fields.push(Field { name, ty });
        }
        Ok((Schema { fields }, pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Schema {
        Schema::new(vec![
            Field::new("run", ColumnType::I32),
            Field::new("event", ColumnType::I64),
            Field::new("pt", ColumnType::F32),
            Field::new("weight", ColumnType::F64),
            Field::new("flag", ColumnType::U8),
            Field::new("tag", ColumnType::Bytes),
            Field::new("hits", ColumnType::ListF32),
        ])
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = sample();
        let enc = s.encode();
        let (dec, used) = Schema::decode(&enc).unwrap();
        assert_eq!(dec, s);
        assert_eq!(used, enc.len());
    }

    #[test]
    fn decode_with_trailing_data() {
        let s = sample();
        let mut enc = s.encode();
        let schema_len = enc.len();
        enc.extend_from_slice(b"TRAILER");
        let (dec, used) = Schema::decode(&enc).unwrap();
        assert_eq!(dec, s);
        assert_eq!(used, schema_len);
    }

    #[test]
    fn truncation_errors() {
        let enc = sample().encode();
        for cut in [0, 2, 5, enc.len() - 1] {
            assert!(Schema::decode(&enc[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn flat_f32_shape() {
        let s = Schema::flat_f32("col", 70);
        assert_eq!(s.len(), 70);
        assert_eq!(s.fields[69].name, "col69");
        assert!(s.fields.iter().all(|f| f.ty == ColumnType::F32));
        assert_eq!(s.index_of("col13"), Some(13));
        assert_eq!(s.index_of("nope"), None);
    }

    #[test]
    fn type_codes_roundtrip() {
        for ty in [
            ColumnType::I32,
            ColumnType::I64,
            ColumnType::F32,
            ColumnType::F64,
            ColumnType::U8,
            ColumnType::Bytes,
            ColumnType::ListF32,
        ] {
            assert_eq!(ColumnType::from_code(ty.code()).unwrap(), ty);
        }
        assert!(ColumnType::from_code(99).is_err());
    }
}
