//! The scoped task pool backing IMT.
//!
//! Safety model: [`Pool::scope`] erases the lifetime of spawned closures
//! (they borrow from the caller's stack) but guarantees every spawned
//! job has finished before `scope` returns — the standard
//! scoped-threadpool construction. Panics inside jobs are caught,
//! recorded, and re-thrown at the scope join point.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool with a shared FIFO queue.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    nthreads: usize,
}

impl Pool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("imt-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn imt worker")
            })
            .collect();
        Pool { shared, workers, nthreads: n }
    }

    pub fn threads(&self) -> usize {
        self.nthreads
    }

    fn push(&self, job: Job) {
        self.shared.queue.lock().unwrap().push_back(job);
        self.shared.work_cv.notify_one();
    }

    fn try_pop(&self) -> Option<Job> {
        self.shared.queue.lock().unwrap().pop_front()
    }

    /// Run a scope: closures spawned on `Scope` may borrow from the
    /// caller; all of them complete before `scope` returns.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env, '_>) -> R,
    {
        let state = Arc::new(GroupState {
            pending: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            panicked: AtomicBool::new(false),
        });
        let scope = Scope { pool: self, state: state.clone(), _marker: std::marker::PhantomData };
        let out = f(&scope);
        // Help execute queued work while waiting for our jobs.
        while state.pending.load(Ordering::Acquire) > 0 {
            if let Some(job) = self.try_pop() {
                job();
            } else {
                let g = state.done_mx.lock().unwrap();
                if state.pending.load(Ordering::Acquire) > 0 {
                    let _ = state.done_cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
                }
            }
        }
        if state.panicked.load(Ordering::Acquire) {
            panic!("task in imt scope panicked");
        }
        out
    }

    /// `f(i)` for all `i in 0..n`, chunked across the pool.
    pub fn parallel_for<F>(&self, n: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // ~4 chunks per worker balances scheduling overhead vs skew.
        let chunks = (self.nthreads * 4).min(n);
        let chunk = n.div_ceil(chunks);
        self.scope(|s| {
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                s.spawn(move || {
                    for i in start..end {
                        f(i);
                    }
                });
                start = end;
            }
        });
    }

    /// Ordered parallel map.
    pub fn parallel_map<T, F>(&self, n: usize, f: &F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots = out.as_mut_ptr() as usize;
            self.scope(|s| {
                for i in 0..n {
                    s.spawn(move || {
                        // SAFETY: each task writes a distinct slot, and the
                        // scope joins before `out` is read or dropped.
                        unsafe {
                            let p = (slots as *mut Option<T>).add(i);
                            std::ptr::write(p, Some(f(i)));
                        }
                    });
                }
            });
        }
        out.into_iter().map(|v| v.expect("slot filled")).collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if sh.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = sh.work_cv.wait(q).unwrap();
            }
        };
        job();
    }
}

struct GroupState {
    pending: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
    panicked: AtomicBool,
}

/// Handle for spawning borrowing jobs inside [`Pool::scope`].
pub struct Scope<'env, 'p> {
    pool: &'p Pool,
    state: Arc<GroupState>,
    _marker: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'env, 'p> Scope<'env, 'p> {
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.state.pending.fetch_add(1, Ordering::AcqRel);
        let state = self.state.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if catch_unwind(AssertUnwindSafe(f)).is_err() {
                state.panicked.store(true, Ordering::Release);
            }
            let _g = state.done_mx.lock().unwrap();
            state.pending.fetch_sub(1, Ordering::AcqRel);
            state.done_cv.notify_all();
        });
        // SAFETY: Pool::scope joins all jobs before 'env ends.
        let job: Job = unsafe { std::mem::transmute(job) };
        self.pool.push(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_borrows_stack_data() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(100) {
                let sum = &sum;
                s.spawn(move || {
                    sum.fetch_add(chunk.iter().sum::<u64>(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000 * 999 / 2);
    }

    #[test]
    fn parallel_map_order() {
        let pool = Pool::new(8);
        let v = pool.parallel_map(257, &|i| i as u32 * 3);
        assert_eq!(v, (0..257u32).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_covers_all_once() {
        let pool = Pool::new(3);
        let flags: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(500, &|i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let total = &total;
                let pool_ref = &pool;
                s.spawn(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "task in imt scope panicked")]
    fn panic_propagates_at_join() {
        let pool = Pool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn zero_items_is_fine() {
        let pool = Pool::new(2);
        pool.parallel_for(0, &|_| panic!("must not run"));
        let v: Vec<u8> = pool.parallel_map(0, &|_| 0u8);
        assert!(v.is_empty());
    }

    #[test]
    fn many_small_scopes() {
        let pool = Pool::new(4);
        for round in 0..100 {
            let n = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..8 {
                    let n = &n;
                    s.spawn(move || {
                        n.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(n.load(Ordering::Relaxed), 8, "round {round}");
        }
    }
}
