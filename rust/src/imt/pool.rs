//! The scoped task pool backing IMT — a work-stealing scheduler.
//!
//! Topology: every worker owns a deque (local push/pop at the back =
//! LIFO, steals from the front = FIFO) and the pool keeps one shared
//! FIFO *injector* queue for jobs submitted from non-worker threads.
//! LIFO local execution keeps nested task trees cache-hot and bounds
//! queue growth (depth-first), while FIFO stealing takes the oldest —
//! typically largest — subtree, which is the classic Cilk/TBB policy
//! the paper's IMT engine relies on.
//!
//! Wakeups are event-count style: sleepers park on one condvar and the
//! producer side only touches the sleep mutex when `sleepers > 0`, so
//! the uncontended spawn path is queue-lock + atomic. There is no
//! polling loop anywhere (the old implementation woke every waiter each
//! millisecond).
//!
//! Safety model: [`Pool::scope`] erases the lifetime of spawned
//! closures (they borrow from the caller's stack) but guarantees every
//! spawned job has finished before `scope` returns — the standard
//! scoped-threadpool construction. The scope owner *helps execute*
//! queued jobs while it waits, so nested scopes cannot deadlock and a
//! blocked caller still contributes CPU. Panics inside jobs are caught,
//! recorded, and re-thrown at the scope join point.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::metrics::{Recorder, SpanKind};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Worker identity of the current thread: (shared-state address, index
/// + 1). Lets `push` route jobs to the local deque and `scope` steal
/// with the right rotation, without any global registry.
thread_local! {
    static WORKER_ID: Cell<(usize, usize)> = const { Cell::new((0, 0)) };
}

struct Shared {
    /// FIFO queue for jobs submitted from threads outside the pool.
    injector: Mutex<VecDeque<Job>>,
    /// Per-worker deques: owner pushes/pops at the back, thieves pop
    /// the front.
    locals: Vec<Mutex<VecDeque<Job>>>,
    /// Total jobs currently queued across injector + locals. Producers
    /// increment *before* enqueuing, consumers decrement *after*
    /// dequeuing, so a non-zero count is visible to any sleeper that
    /// races with an in-flight push.
    queued: AtomicUsize,
    /// Number of threads parked on `work_cv` (workers and helping
    /// scope owners alike).
    sleepers: AtomicUsize,
    sleep_mx: Mutex<()>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// Rotation seed so external stealers don't all hammer worker 0.
    next_steal: AtomicUsize,
    /// `true` while a session has a recorder installed: the job-run
    /// sites check this one relaxed flag before touching `recorder`,
    /// so untraced pools pay a single load per job.
    traced: AtomicBool,
    /// Recorder installed by a traced session (disabled otherwise).
    /// Jobs executed while it is installed are wrapped in
    /// [`SpanKind::Task`] container spans.
    recorder: Mutex<Recorder>,
}

impl Shared {
    fn id(&self) -> usize {
        self as *const Shared as usize
    }

    /// Worker index of the current thread *in this pool*, if any.
    fn current_worker(&self) -> Option<usize> {
        WORKER_ID.with(|w| {
            let (pool, idx) = w.get();
            if pool == self.id() && idx > 0 {
                Some(idx - 1)
            } else {
                None
            }
        })
    }

    /// Enqueue one job: local deque when called from a worker of this
    /// pool (LIFO execution order), injector otherwise.
    fn push(&self, job: Job) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        match self.current_worker() {
            Some(i) => self.locals[i].lock().unwrap().push_back(job),
            None => self.injector.lock().unwrap().push_back(job),
        }
        self.notify_one();
    }

    /// Wake one sleeper if anyone is parked. The mutex acquisition
    /// orders the notify against a sleeper that is between its
    /// `sleepers` increment and its `wait`, closing the lost-wakeup
    /// window.
    fn notify_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep_mx.lock().unwrap();
            self.work_cv.notify_one();
        }
    }

    fn notify_all(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep_mx.lock().unwrap();
            self.work_cv.notify_all();
        }
    }

    /// Unconditional wake-everyone, used only at shutdown where the
    /// `sleepers > 0` fast-path check could race with a worker that is
    /// about to park.
    fn notify_all_unconditional(&self) {
        let _g = self.sleep_mx.lock().unwrap();
        self.work_cv.notify_all();
    }

    /// Pop one job: own deque back (LIFO), then injector front, then
    /// steal the front of the other workers' deques (FIFO), rotating
    /// the start position.
    fn find_job(&self, me: Option<usize>) -> Option<Job> {
        if let Some(i) = me {
            if let Some(j) = self.locals[i].lock().unwrap().pop_back() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                return Some(j);
            }
        }
        if let Some(j) = self.injector.lock().unwrap().pop_front() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
            return Some(j);
        }
        let n = self.locals.len();
        let start = match me {
            Some(i) => i + 1,
            None => self.next_steal.fetch_add(1, Ordering::Relaxed),
        };
        for d in 0..n {
            let v = (start + d) % n;
            if Some(v) == me {
                continue;
            }
            if let Some(j) = self.locals[v].lock().unwrap().pop_front() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                return Some(j);
            }
        }
        None
    }

    /// Execute one dequeued job, wrapped in a [`SpanKind::Task`]
    /// container span when a session recorder is installed. The
    /// untraced fast path is one relaxed load.
    fn run_job(&self, job: Job) {
        if !self.traced.load(Ordering::Relaxed) {
            job();
            return;
        }
        let r = self.recorder.lock().unwrap_or_else(|p| p.into_inner()).clone();
        r.record(SpanKind::Task, job);
    }
}

/// Fixed-size work-stealing worker pool.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    nthreads: usize,
}

impl Pool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            sleep_mx: Mutex::new(()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_steal: AtomicUsize::new(0),
            traced: AtomicBool::new(false),
            recorder: Mutex::new(Recorder::disabled()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("imt-{i}"))
                    .spawn(move || worker_loop(&sh, i))
                    .expect("spawn imt worker")
            })
            .collect();
        Pool { shared, workers, nthreads: n }
    }

    pub fn threads(&self) -> usize {
        self.nthreads
    }

    /// Run a scope: closures spawned on `Scope` may borrow from the
    /// caller; all of them complete before `scope` returns.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'env, '_>) -> R,
    {
        let state = Arc::new(GroupState {
            pending: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        let scope = Scope { pool: self, state: state.clone(), _marker: std::marker::PhantomData };
        // Catch an unwind of the scope closure itself: jobs it already
        // spawned borrow the caller's frame, so we must run the join
        // loop below before letting the panic continue (otherwise a
        // worker could execute a job against a destroyed stack frame).
        let out = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Help execute queued work until all our jobs have finished
        // (same help-then-park loop the task groups use).
        self.wait_pending(&state.pending, 0);
        let out = match out {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        };
        if state.panicked.load(Ordering::SeqCst) {
            panic!("task in imt scope panicked");
        }
        out
    }

    /// `f(i)` for all `i in 0..n`, chunked across the pool.
    pub fn parallel_for<F>(&self, n: usize, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        // ~4 chunks per worker balances scheduling overhead vs skew;
        // work stealing absorbs whatever skew remains.
        let chunks = (self.nthreads * 4).min(n);
        let chunk = n.div_ceil(chunks);
        self.scope(|s| {
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                s.spawn(move || {
                    for i in start..end {
                        f(i);
                    }
                });
                start = end;
            }
        });
    }

    /// Ordered parallel map. Each task writes its own slot through a
    /// dedicated `Mutex<Option<T>>` cell — fully safe (no raw-pointer
    /// aliasing), and the per-slot locks are uncontended by
    /// construction (exactly one task touches each slot).
    pub fn parallel_map<T, F>(&self, n: usize, f: &F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.parallel_for(n, &|i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("parallel_map slot filled"))
            .collect()
    }

    /// Help execute queued jobs until `pending` drops to `limit` or
    /// below. Used by [`TaskGroup`] joins and backpressure waits: the
    /// waiter contributes CPU instead of blocking, and parks on the
    /// pool condvar when nothing is runnable (no polling).
    pub(crate) fn wait_pending(&self, pending: &AtomicUsize, limit: usize) {
        let sh = &self.shared;
        let me = sh.current_worker();
        while pending.load(Ordering::SeqCst) > limit {
            if let Some(job) = sh.find_job(me) {
                sh.run_job(job);
                continue;
            }
            // Nothing runnable: park until some job completes (group
            // jobs notify on every completion) or new work arrives.
            let g = sh.sleep_mx.lock().unwrap();
            sh.sleepers.fetch_add(1, Ordering::SeqCst);
            if pending.load(Ordering::SeqCst) <= limit
                || sh.queued.load(Ordering::SeqCst) > 0
            {
                sh.sleepers.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let g = sh.work_cv.wait(g).unwrap();
            sh.sleepers.fetch_sub(1, Ordering::SeqCst);
            drop(g);
        }
        // A wake meant for queued work may have landed on us while our
        // last job completed; pass it on so that job is not stranded.
        if sh.queued.load(Ordering::SeqCst) > 0 {
            sh.notify_one();
        }
    }

    /// Help execute queued jobs until `pred()` holds. This is the
    /// predicate-shaped sibling of [`Pool::wait_pending`], used by the
    /// session write budget whose admission condition spans several
    /// counters (global in-flight, per-writer in-flight, fair share).
    /// The park carries a short timeout: budget guards may be released
    /// from outside any job of *this* pool (e.g. after the global pool
    /// was swapped), and the timeout turns that pathological race into
    /// a bounded re-check instead of a lost wakeup.
    pub(crate) fn wait_until(&self, pred: &dyn Fn() -> bool) {
        let sh = &self.shared;
        let me = sh.current_worker();
        while !pred() {
            if let Some(job) = sh.find_job(me) {
                sh.run_job(job);
                continue;
            }
            let g = sh.sleep_mx.lock().unwrap();
            sh.sleepers.fetch_add(1, Ordering::SeqCst);
            if pred() || sh.queued.load(Ordering::SeqCst) > 0 {
                sh.sleepers.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            let (g, _) = sh
                .work_cv
                .wait_timeout(g, std::time::Duration::from_millis(20))
                .unwrap();
            sh.sleepers.fetch_sub(1, Ordering::SeqCst);
            drop(g);
        }
        if sh.queued.load(Ordering::SeqCst) > 0 {
            sh.notify_one();
        }
    }

    /// Wake every thread parked on the pool condvar. Budget guards call
    /// this when in-flight capacity frees up, so producers blocked in
    /// admission re-evaluate without polling.
    pub(crate) fn notify_waiters(&self) {
        self.shared.notify_all();
    }

    /// Install a session recorder: every job the pool executes from now
    /// on is wrapped in a [`SpanKind::Task`] container span. Disabled
    /// recorders are ignored (installing one would only add overhead).
    /// Last installer wins when sessions overlap on a shared pool.
    pub fn install_recorder(&self, recorder: &Recorder) {
        if !recorder.is_enabled() {
            return;
        }
        *self.shared.recorder.lock().unwrap_or_else(|p| p.into_inner()) = recorder.clone();
        self.shared.traced.store(true, Ordering::SeqCst);
    }

    /// Uninstall `recorder` if it is the one currently installed
    /// (identity-compared, so one session's teardown cannot clobber a
    /// recorder a later session installed on the same shared pool).
    pub fn clear_recorder(&self, recorder: &Recorder) {
        let mut g = self.shared.recorder.lock().unwrap_or_else(|p| p.into_inner());
        if g.same(recorder) {
            *g = Recorder::disabled();
            self.shared.traced.store(false, Ordering::SeqCst);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.notify_all_unconditional();
        let current = std::thread::current().id();
        for w in self.workers.drain(..) {
            // If the last reference to the pool is dropped from inside
            // one of its own workers (e.g. a nested job held the final
            // Arc), joining ourselves would deadlock — detach instead;
            // the worker exits on its own via the shutdown flag.
            if w.thread().id() == current {
                continue;
            }
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: &Arc<Shared>, me: usize) {
    WORKER_ID.with(|w| w.set((sh.id(), me + 1)));
    loop {
        if let Some(job) = sh.find_job(Some(me)) {
            sh.run_job(job);
            continue;
        }
        if sh.shutdown.load(Ordering::SeqCst) {
            // Drain: jobs enqueued before shutdown must still run, or a
            // scope owner would be left waiting on work nobody takes.
            while let Some(job) = sh.find_job(Some(me)) {
                sh.run_job(job);
            }
            break;
        }
        // Park. The `sleepers` increment happens under the sleep mutex
        // and is re-checked by producers, so a push that raced with us
        // either sees the increment (and notifies) or enqueued before
        // our `queued` check below (and we skip the wait).
        let g = sh.sleep_mx.lock().unwrap();
        sh.sleepers.fetch_add(1, Ordering::SeqCst);
        if sh.queued.load(Ordering::SeqCst) > 0 || sh.shutdown.load(Ordering::SeqCst) {
            sh.sleepers.fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        let g = sh.work_cv.wait(g).unwrap();
        sh.sleepers.fetch_sub(1, Ordering::SeqCst);
        drop(g);
    }
    WORKER_ID.with(|w| w.set((0, 0)));
}

struct GroupState {
    pending: AtomicUsize,
    panicked: AtomicBool,
}

/// Handle for spawning borrowing jobs inside [`Pool::scope`].
pub struct Scope<'env, 'p> {
    pool: &'p Pool,
    state: Arc<GroupState>,
    _marker: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'env, 'p> Scope<'env, 'p> {
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        self.state.pending.fetch_add(1, Ordering::SeqCst);
        let state = self.state.clone();
        let shared = self.pool.shared.clone();
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if catch_unwind(AssertUnwindSafe(f)).is_err() {
                state.panicked.store(true, Ordering::SeqCst);
            }
            // Last job out wakes the (possibly parked) scope owner.
            if state.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                shared.notify_all();
            }
        });
        // SAFETY: Pool::scope joins all jobs before 'env ends, and the
        // wrapper only touches 'env-borrowed data inside `f`.
        let job: Job = unsafe { std::mem::transmute(job) };
        self.pool.shared.push(job);
    }
}

/// A completion-tracked set of `'static` jobs — the submit-now,
/// join-later primitive behind the pipelined write path (and any other
/// producer that must keep working while earlier work drains).
///
/// Unlike [`Pool::scope`], `spawn` returns immediately and jobs own
/// their data instead of borrowing the caller's stack; the submitter
/// joins whenever it likes (possibly after spawning more). Cloning the
/// group yields another handle to the *same* completion set — jobs use
/// this to spawn subtasks (e.g. per-block compression inside a basket
/// flush) that the final join still covers.
///
/// The group binds to a pool at construction ([`TaskGroup::with_pool`])
/// or lazily to the global IMT pool at first spawn; with IMT disabled
/// jobs run inline, giving callers serial semantics from the same code
/// path. Job panics are caught, recorded, and surfaced by
/// [`TaskGroup::join`] as an error — they never unwind across the pool
/// or hang the joiner.
#[derive(Clone, Default)]
pub struct TaskGroup {
    inner: Arc<GroupInner>,
}

#[derive(Default)]
struct GroupInner {
    /// Bound pool (None until first spawn; stays None — inline
    /// execution — while IMT is off).
    pool: Mutex<Option<Arc<Pool>>>,
    pending: AtomicUsize,
    panicked: AtomicBool,
}

impl TaskGroup {
    /// Group bound lazily to the global IMT pool (inline when off).
    pub fn new() -> Self {
        TaskGroup::default()
    }

    /// Group bound to a specific pool (dedicated pools, hermetic tests).
    pub fn with_pool(pool: Arc<Pool>) -> Self {
        let group = TaskGroup::default();
        *group.inner.pool.lock().unwrap() = Some(pool);
        group
    }

    /// Group bound to `pool` when one is given, otherwise lazily to the
    /// global IMT pool — the binding an [`crate::session::Session`]
    /// hands to every writer it opens.
    pub fn bound(pool: Option<Arc<Pool>>) -> Self {
        match pool {
            Some(p) => TaskGroup::with_pool(p),
            None => TaskGroup::new(),
        }
    }

    /// Jobs spawned but not yet finished.
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::SeqCst)
    }

    /// Is this the only handle left, with nothing in flight? In-flight
    /// jobs hold a clone of the group, so an orphaned group can never
    /// spawn or complete anything again. Sessions use this to prune
    /// their completion-domain roster as writers close.
    pub fn is_orphaned(&self) -> bool {
        Arc::strong_count(&self.inner) == 1 && self.pending() == 0
    }

    /// Has any job of this group panicked so far?
    pub fn panicked(&self) -> bool {
        self.inner.panicked.load(Ordering::SeqCst)
    }

    fn bind(&self) -> Option<Arc<Pool>> {
        let mut g = self.inner.pool.lock().unwrap();
        if g.is_none() {
            *g = crate::imt::pool();
        }
        g.clone()
    }

    /// The pool this group is currently bound to: `None` before the
    /// first spawn binds it, or while IMT is off (jobs ran inline).
    /// Waiters that poll group-side state (the prefetch consumer) park
    /// on *this* pool — the one the jobs actually run on — rather than
    /// whatever the global pool happens to be right now.
    pub(crate) fn bound_pool(&self) -> Option<Arc<Pool>> {
        self.inner.pool.lock().unwrap().clone()
    }

    /// Enqueue one job; returns immediately when a pool is bound, runs
    /// the job inline otherwise.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'static,
    {
        match self.bind() {
            Some(pool) => {
                self.inner.pending.fetch_add(1, Ordering::SeqCst);
                let inner = self.inner.clone();
                let shared = pool.shared.clone();
                pool.shared.push(Box::new(move || {
                    if catch_unwind(AssertUnwindSafe(f)).is_err() {
                        inner.panicked.store(true, Ordering::SeqCst);
                    }
                    inner.pending.fetch_sub(1, Ordering::SeqCst);
                    // Every completion wakes waiters: a join targets
                    // pending == 0, backpressure targets a threshold.
                    shared.notify_all();
                }));
            }
            None => {
                if catch_unwind(AssertUnwindSafe(f)).is_err() {
                    self.inner.panicked.store(true, Ordering::SeqCst);
                }
            }
        }
    }

    /// Block — helping execute pool jobs — until at most `limit` jobs
    /// of this group remain in flight (the write path's backpressure).
    pub fn wait_below(&self, limit: usize) {
        if self.inner.pending.load(Ordering::SeqCst) <= limit {
            return;
        }
        let pool = self.inner.pool.lock().unwrap().clone();
        if let Some(p) = pool {
            p.wait_pending(&self.inner.pending, limit);
        }
    }

    /// Wait for every spawned job; job panics surface here as an
    /// error. Non-consuming — a group may be joined and reused.
    pub fn join(&self) -> Result<()> {
        self.wait_below(0);
        if self.panicked() {
            Err(Error::Sync("task in imt group panicked".into()))
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_borrows_stack_data() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(100) {
                let sum = &sum;
                s.spawn(move || {
                    sum.fetch_add(chunk.iter().sum::<u64>(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000 * 999 / 2);
    }

    #[test]
    fn parallel_map_order() {
        let pool = Pool::new(8);
        let v = pool.parallel_map(257, &|i| i as u32 * 3);
        assert_eq!(v, (0..257u32).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_boxed_values_no_unsafe() {
        // Non-Copy, heap-owning values through the safe slot cells —
        // runs clean under Miri (no raw-pointer writes involved).
        let pool = Pool::new(4);
        let v = pool.parallel_map(100, &|i| Box::new(format!("item-{i}")));
        for (i, s) in v.iter().enumerate() {
            assert_eq!(**s, format!("item-{i}"));
        }
    }

    #[test]
    fn parallel_for_covers_all_once() {
        let pool = Pool::new(3);
        let flags: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(500, &|i| {
            flags[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                let total = &total;
                let pool_ref = &pool;
                s.spawn(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn deeply_nested_scopes_on_one_worker() {
        // Depth 5 on a single-thread pool: only the helping scope
        // owners can make progress — exercises LIFO local execution.
        let pool = Pool::new(1);
        fn recurse(pool: &Pool, depth: usize, count: &AtomicUsize) {
            if depth == 0 {
                count.fetch_add(1, Ordering::Relaxed);
                return;
            }
            pool.scope(|s| {
                for _ in 0..2 {
                    s.spawn(move || recurse(pool, depth - 1, count));
                }
            });
        }
        let count = AtomicUsize::new(0);
        recurse(&pool, 5, &count);
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    #[should_panic(expected = "task in imt scope panicked")]
    fn panic_propagates_at_join() {
        let pool = Pool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn scope_closure_panic_still_joins_jobs() {
        // If the scope body itself unwinds, already-spawned jobs
        // borrow the (unwinding) caller frame — scope must join them
        // before the panic propagates.
        let pool = Pool::new(2);
        let n = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                for _ in 0..16 {
                    let n = &n;
                    s.spawn(move || {
                        n.fetch_add(1, Ordering::Relaxed);
                    });
                }
                panic!("scope body panics");
            });
        }));
        assert!(r.is_err());
        assert_eq!(n.load(Ordering::Relaxed), 16, "all jobs joined before unwind");
    }

    #[test]
    fn zero_items_is_fine() {
        let pool = Pool::new(2);
        pool.parallel_for(0, &|_| panic!("must not run"));
        let v: Vec<u8> = pool.parallel_map(0, &|_| 0u8);
        assert!(v.is_empty());
    }

    #[test]
    fn many_small_scopes() {
        let pool = Pool::new(4);
        for round in 0..100 {
            let n = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..8 {
                    let n = &n;
                    s.spawn(move || {
                        n.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(n.load(Ordering::Relaxed), 8, "round {round}");
        }
    }

    #[test]
    fn steal_balances_skewed_load() {
        // One long task plus many short ones: with stealing, the short
        // ones complete on other workers while the long one runs.
        let pool = Pool::new(4);
        let done = AtomicUsize::new(0);
        pool.scope(|s| {
            let done = &done;
            s.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(30));
                done.fetch_add(1, Ordering::Relaxed);
            });
            for _ in 0..64 {
                s.spawn(move || {
                    done.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 65);
    }

    #[test]
    fn drop_after_heavy_load_is_clean() {
        // Shutdown must not strand queued jobs (drain-on-shutdown) and
        // must not hang the dropping thread.
        for _ in 0..20 {
            let pool = Pool::new(3);
            let n = AtomicUsize::new(0);
            pool.scope(|s| {
                for _ in 0..128 {
                    let n = &n;
                    s.spawn(move || {
                        n.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(n.load(Ordering::Relaxed), 128);
            drop(pool);
        }
    }

    #[test]
    fn task_group_joins_all_jobs() {
        let pool = Arc::new(Pool::new(3));
        let group = TaskGroup::with_pool(pool);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let hits = hits.clone();
            group.spawn(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        group.join().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 64);
        assert_eq!(group.pending(), 0);
        // the group is reusable after a join
        let hits2 = hits.clone();
        group.spawn(move || {
            hits2.fetch_add(1, Ordering::Relaxed);
        });
        group.join().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 65);
    }

    #[test]
    fn task_group_backpressure_wait_below() {
        let pool = Arc::new(Pool::new(2));
        let group = TaskGroup::with_pool(pool);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let done = done.clone();
            group.spawn(move || {
                std::thread::sleep(std::time::Duration::from_micros(200));
                done.fetch_add(1, Ordering::Relaxed);
            });
        }
        group.wait_below(8);
        assert!(group.pending() <= 8);
        assert!(done.load(Ordering::Relaxed) >= 24);
        group.join().unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn task_group_reports_panics_as_error() {
        let pool = Arc::new(Pool::new(2));
        let group = TaskGroup::with_pool(pool);
        let ok = Arc::new(AtomicUsize::new(0));
        for i in 0..16 {
            let ok = ok.clone();
            group.spawn(move || {
                if i % 4 == 0 {
                    panic!("injected task panic");
                }
                ok.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert!(group.join().is_err(), "panicked jobs must surface at join");
        assert!(group.panicked());
        assert_eq!(ok.load(Ordering::Relaxed), 12, "healthy jobs still ran");
    }

    #[test]
    fn task_group_jobs_can_spawn_subtasks() {
        // A job fans out subtasks into the same group; the final join
        // covers them (the per-block compression pattern).
        let pool = Arc::new(Pool::new(3));
        let group = TaskGroup::with_pool(pool);
        let total = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let g = group.clone();
            let total = total.clone();
            group.spawn(move || {
                for _ in 0..4 {
                    let total = total.clone();
                    g.spawn(move || {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
        group.join().unwrap();
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn task_group_without_pool_runs_inline() {
        // No bound pool and (possibly) no global pool: spawn degrades
        // to inline execution; join still reports panics.
        let group = TaskGroup::new();
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let hits = hits.clone();
            group.spawn(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        group.join().unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn external_thread_scopes_run_concurrently() {
        // Several non-worker threads drive scopes on one pool at once;
        // all their jobs land in the injector and must all complete.
        let pool = Arc::new(Pool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    pool.scope(|s| {
                        for _ in 0..8 {
                            let total = &*total;
                            s.spawn(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 10 * 8);
    }
}
