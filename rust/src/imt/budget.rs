//! Shared in-flight I/O budget — the admission half of an I/O
//! session ([`crate::session`]).
//!
//! Before this existed every [`crate::tree::writer::TreeWriter`]
//! bounded only its *own* in-flight clusters, so N concurrent writers
//! could queue N × `max_inflight_clusters` clusters on one IMT pool:
//! oversubscription Riley & Jones identify as the scaling killer for
//! many-output-module jobs. An [`IoBudget`] is one global cap shared
//! by every member of a session, with **per-member fair admission**:
//!
//! * a member may hold at most `min(its own cap, limit / active)`
//!   clusters in flight (max-min fair share, never below 1), so a
//!   fat-basket writer cannot monopolise the budget — narrow writers
//!   always find their share available;
//! * the global total never exceeds `limit`, bounding buffered memory
//!   across the whole session;
//! * admission waits *help execute pool jobs* (via
//!   [`Pool::wait_until`]) instead of blocking, so a stalled producer
//!   still contributes CPU to draining the very backlog it waits on.
//!
//! The budget is direction-agnostic: a "cluster in flight" is any unit
//! of buffered I/O memory. The write path admits compressing clusters
//! ([`crate::session::Session::register_writer`]); the read-ahead
//! cache ([`crate::cache`]) admits prefetched cluster windows through
//! a second budget instance on the same session
//! ([`crate::session::Session::register_reader`]), so N streaming
//! readers cannot oversubscribe the pool or the scratch pool any more
//! than N writers can. `WriteBudget` / `WriterBudget` remain as
//! aliases from the budget's write-only era.
//!
//! Accounting is RAII: [`MemberBudget::acquire`] returns a
//! [`ClusterGuard`] that the member threads through every task of the
//! cluster; the slot is released when the last task drops its guard —
//! including on panic, since unwinding drops the closure's captures.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::pool::Pool;
use crate::metrics::{Recorder, SpanKind};

/// Counters of the shared budget, snapshotted by [`IoBudget::stats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct BudgetStats {
    /// Clusters admitted so far (lifetime).
    pub admissions: u64,
    /// Admissions that had to wait for capacity (contention signal).
    pub waits: u64,
    /// Members (writers or readers) currently registered.
    pub active_writers: usize,
    /// Clusters currently in flight across all members.
    pub in_flight: usize,
    /// The global cap.
    pub limit: usize,
}

struct BudgetInner {
    /// Global cap on clusters in flight across all members.
    limit: usize,
    total: AtomicUsize,
    /// Registered members (drives each member's fair share).
    active: AtomicUsize,
    /// Pool whose jobs admission waiters help execute and whose condvar
    /// guard drops notify; `None` falls back to the global IMT pool at
    /// use time (and to `idle_cv` when IMT is off entirely).
    explicit_pool: Option<Arc<Pool>>,
    /// Fallback park for waiters when no pool is reachable.
    idle_mx: Mutex<()>,
    idle_cv: Condvar,
    admissions: AtomicU64,
    waits: AtomicU64,
    /// Session recorder: admission waits that actually block emit an
    /// `AdmissionWait` span (disabled recorder = one branch, no clock).
    recorder: Recorder,
}

impl BudgetInner {
    fn pool(&self) -> Option<Arc<Pool>> {
        self.explicit_pool.clone().or_else(crate::imt::pool)
    }

    /// Wake admission waiters after capacity changed (guard dropped,
    /// speculative admission rolled back, member deregistered).
    fn notify(&self) {
        if let Some(p) = self.pool() {
            p.notify_waiters();
        }
        let _g = self.idle_mx.lock().unwrap_or_else(|p| p.into_inner());
        self.idle_cv.notify_all();
    }
}

/// The session-wide shared budget. Members join via
/// [`IoBudget::register`].
pub struct IoBudget {
    inner: Arc<BudgetInner>,
}

/// The budget under its original write-side name ([`IoBudget`] is the
/// direction-neutral one).
pub type WriteBudget = IoBudget;

impl IoBudget {
    /// Budget capped at `limit` clusters in flight (min 1). Waiters
    /// help execute on `pool` when given, else on the global IMT pool.
    pub fn new(limit: usize, pool: Option<Arc<Pool>>) -> Self {
        IoBudget::traced(limit, pool, Recorder::disabled())
    }

    /// Like [`IoBudget::new`], but admission waits that block emit
    /// [`SpanKind::AdmissionWait`] spans on `recorder` when it is
    /// enabled. [`crate::session::Session`] builds all its budgets
    /// through this so backpressure stalls show up in traces.
    pub fn traced(limit: usize, pool: Option<Arc<Pool>>, recorder: Recorder) -> Self {
        IoBudget {
            inner: Arc::new(BudgetInner {
                limit: limit.max(1),
                total: AtomicUsize::new(0),
                active: AtomicUsize::new(0),
                explicit_pool: pool,
                idle_mx: Mutex::new(()),
                idle_cv: Condvar::new(),
                admissions: AtomicU64::new(0),
                waits: AtomicU64::new(0),
                recorder,
            }),
        }
    }

    /// Register one member. `cap` is the member's own in-flight limit
    /// (a writer's `max_inflight_clusters`, a prefetcher's maximum
    /// window); effective admission is the tighter of `cap` and the
    /// current fair share.
    pub fn register(&self, cap: usize) -> MemberBudget {
        self.inner.active.fetch_add(1, Ordering::SeqCst);
        MemberBudget {
            budget: self.inner.clone(),
            state: Arc::new(MemberState::default()),
            cap: cap.max(1),
        }
    }

    /// The global in-flight cap.
    pub fn limit(&self) -> usize {
        self.inner.limit
    }

    /// Clusters currently in flight across all members.
    pub fn in_flight(&self) -> usize {
        self.inner.total.load(Ordering::SeqCst)
    }

    pub fn stats(&self) -> BudgetStats {
        BudgetStats {
            admissions: self.inner.admissions.load(Ordering::Relaxed),
            waits: self.inner.waits.load(Ordering::Relaxed),
            active_writers: self.inner.active.load(Ordering::SeqCst),
            in_flight: self.in_flight(),
            limit: self.inner.limit,
        }
    }
}

/// Per-member in-flight accounting.
#[derive(Default)]
struct MemberState {
    inflight: AtomicUsize,
    /// Highest concurrent in-flight count this member ever reached —
    /// the fairness invariant tests assert it never exceeds the share.
    high_water: AtomicUsize,
    /// Admissions of *this* member that had to wait for capacity —
    /// the per-member admission-pressure signal the adaptive cluster
    /// sizer ([`crate::tree::sizer`]) and the prefetch window
    /// controller ([`crate::cache::window`]) feed on.
    waits: AtomicU64,
}

/// One member's handle on the shared budget. Dropping it deregisters
/// the member (growing the remaining members' fair share); guards it
/// issued stay valid and release capacity as their clusters complete.
pub struct MemberBudget {
    budget: Arc<BudgetInner>,
    state: Arc<MemberState>,
    cap: usize,
}

/// The member handle under its original write-side name
/// ([`MemberBudget`] is the direction-neutral one).
pub type WriterBudget = MemberBudget;

impl MemberBudget {
    /// This member's current fair share of the budget:
    /// `max(1, limit / active_members)`, additionally clamped to the
    /// member's own cap.
    pub fn fair_share(&self) -> usize {
        let active = self.budget.active.load(Ordering::SeqCst).max(1);
        // `cap` is >= 1 by construction, so the clamp bounds are sane.
        (self.budget.limit / active).clamp(1, self.cap)
    }

    /// Highest in-flight count this member ever held.
    pub fn high_water(&self) -> usize {
        self.state.high_water.load(Ordering::SeqCst)
    }

    /// Clusters this member currently has in flight.
    pub fn in_flight(&self) -> usize {
        self.state.inflight.load(Ordering::SeqCst)
    }

    /// Admissions of this member that had to wait for capacity (the
    /// per-member slice of [`BudgetStats::waits`]).
    pub fn waits(&self) -> u64 {
        self.state.waits.load(Ordering::Relaxed)
    }

    /// Loose admission check (no side effects) for wait predicates.
    fn admittable(&self) -> bool {
        self.state.inflight.load(Ordering::SeqCst) < self.fair_share()
            && self.budget.total.load(Ordering::SeqCst) < self.budget.limit
    }

    /// Speculative admission: increment both counters, roll back (and
    /// notify, so a racer that saw the inflated totals re-checks) when
    /// either bound is exceeded.
    fn try_admit(&self) -> Option<ClusterGuard> {
        let mine = self.state.inflight.fetch_add(1, Ordering::SeqCst);
        let total = self.budget.total.fetch_add(1, Ordering::SeqCst);
        if mine >= self.fair_share() || total >= self.budget.limit {
            self.state.inflight.fetch_sub(1, Ordering::SeqCst);
            self.budget.total.fetch_sub(1, Ordering::SeqCst);
            self.budget.notify();
            return None;
        }
        self.state.high_water.fetch_max(mine + 1, Ordering::SeqCst);
        self.budget.admissions.fetch_add(1, Ordering::Relaxed);
        Some(ClusterGuard { budget: self.budget.clone(), state: self.state.clone() })
    }

    /// Non-blocking admission (tests, opportunistic flushes, and the
    /// prefetcher's read-ahead beyond the cluster it needs next).
    pub fn try_acquire(&self) -> Option<ClusterGuard> {
        self.try_admit()
    }

    /// Admit one cluster, blocking (and helping execute pool jobs)
    /// until the member is within both the global budget and its fair
    /// share. Time spent here is the producer's backpressure stall.
    pub fn acquire(&self) -> ClusterGuard {
        if let Some(g) = self.try_admit() {
            return g;
        }
        self.budget.waits.fetch_add(1, Ordering::Relaxed);
        self.state.waits.fetch_add(1, Ordering::Relaxed);
        let wait_start = self.budget.recorder.is_enabled().then(|| self.budget.recorder.elapsed());
        let guard = loop {
            match self.budget.pool() {
                Some(p) => p.wait_until(&|| self.admittable()),
                None => {
                    // No pool anywhere: tasks run inline, so capacity
                    // can only be held by *other threads'* members.
                    // Park briefly on the budget condvar (guard drops
                    // notify it) and re-check.
                    let g = self.budget.idle_mx.lock().unwrap_or_else(|p| p.into_inner());
                    if !self.admittable() {
                        let _ = self
                            .budget
                            .idle_cv
                            .wait_timeout(g, std::time::Duration::from_millis(10))
                            .unwrap_or_else(|p| p.into_inner());
                    }
                }
            }
            if let Some(g) = self.try_admit() {
                break g;
            }
        };
        if let Some(start) = wait_start {
            self.budget.recorder.push(SpanKind::AdmissionWait, start, self.budget.recorder.elapsed());
        }
        guard
    }
}

impl Drop for MemberBudget {
    fn drop(&mut self) {
        self.budget.active.fetch_sub(1, Ordering::SeqCst);
        // The survivors' fair share just grew: let waiters re-check.
        self.budget.notify();
    }
}

/// RAII admission slot for one in-flight cluster. The member wraps it
/// in an `Arc` shared by every task of the cluster; the last task to
/// finish (or unwind) releases the slot and wakes admission waiters.
pub struct ClusterGuard {
    budget: Arc<BudgetInner>,
    state: Arc<MemberState>,
}

impl Drop for ClusterGuard {
    fn drop(&mut self) {
        self.state.inflight.fetch_sub(1, Ordering::SeqCst);
        self.budget.total.fetch_sub(1, Ordering::SeqCst);
        self.budget.notify();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic fairness invariants, no timing involved: a member
    /// cannot exceed its fair share while others are registered, and
    /// the freed capacity of a deregistered member flows to survivors.
    #[test]
    fn fair_share_caps_each_writer() {
        let budget = WriteBudget::new(4, None);
        let fat = budget.register(8);
        let narrow = budget.register(8);
        assert_eq!(fat.fair_share(), 2, "limit 4 over 2 writers");

        // The fat writer saturates its share, not the whole budget.
        let f1 = fat.try_acquire().expect("first slot");
        let f2 = fat.try_acquire().expect("second slot (share = 2)");
        assert!(fat.try_acquire().is_none(), "share exhausted");
        assert_eq!(fat.high_water(), 2);

        // The narrow writer's share is untouched.
        let n1 = narrow.try_acquire().expect("narrow slot 1");
        let n2 = narrow.try_acquire().expect("narrow slot 2");
        assert!(narrow.try_acquire().is_none(), "global limit reached");
        assert_eq!(budget.in_flight(), 4);

        // Releasing a fat slot does not let the narrow writer exceed
        // its own share...
        drop(f1);
        assert!(narrow.try_acquire().is_none(), "narrow share still 2");
        // ...but the fat writer can re-take it.
        let f3 = fat.try_acquire().expect("fat re-admission");
        drop((f2, f3, n1, n2));
        assert_eq!(budget.in_flight(), 0);
    }

    #[test]
    fn deregistration_grows_the_survivors_share() {
        let budget = WriteBudget::new(4, None);
        let a = budget.register(8);
        let b = budget.register(8);
        assert_eq!(a.fair_share(), 2);
        drop(b);
        assert_eq!(a.fair_share(), 4, "sole writer owns the whole budget");
        let guards: Vec<_> = (0..4).map(|_| a.try_acquire().expect("full budget")).collect();
        assert!(a.try_acquire().is_none());
        drop(guards);
    }

    #[test]
    fn writer_cap_clamps_below_the_share() {
        let budget = WriteBudget::new(8, None);
        let w = budget.register(2); // own cap tighter than share (8)
        assert_eq!(w.fair_share(), 2);
        let g1 = w.try_acquire().unwrap();
        let g2 = w.try_acquire().unwrap();
        assert!(w.try_acquire().is_none());
        drop((g1, g2));
    }

    #[test]
    fn share_never_below_one() {
        let budget = WriteBudget::new(2, None);
        let writers: Vec<_> = (0..5).map(|_| budget.register(4)).collect();
        for w in &writers {
            assert_eq!(w.fair_share(), 1, "share floors at 1 even oversubscribed");
        }
        // Only `limit` clusters fit globally no matter the writer count.
        let g1 = writers[0].try_acquire().expect("slot 1");
        let g2 = writers[1].try_acquire().expect("slot 2");
        assert!(writers[2].try_acquire().is_none(), "global limit");
        drop((g1, g2));
    }

    #[test]
    fn acquire_blocks_until_capacity_frees() {
        let budget = WriteBudget::new(1, None);
        let a = budget.register(4);
        let b = Arc::new(budget.register(4));
        let held = a.try_acquire().expect("only slot");
        let (tx, rx) = std::sync::mpsc::channel();
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let g = b2.acquire(); // blocks: budget full
            tx.send(()).unwrap();
            drop(g);
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "acquire must block while the budget is full"
        );
        drop(held);
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("blocked acquire must wake when the slot frees");
        h.join().unwrap();
    }

    #[test]
    fn stats_track_admissions_and_waits() {
        let budget = WriteBudget::new(2, None);
        let w = budget.register(4);
        let g = w.acquire();
        let g2 = w.acquire();
        drop((g, g2));
        let st = budget.stats();
        assert_eq!(st.admissions, 2);
        assert_eq!(st.limit, 2);
        assert_eq!(st.in_flight, 0);
        assert_eq!(st.active_writers, 1);
        assert_eq!(w.waits(), 0, "uncontended acquires never count as waits");
    }

    #[test]
    fn per_writer_wait_counter_tracks_only_the_waiting_writer() {
        let budget = WriteBudget::new(1, None);
        let a = budget.register(4);
        let b = Arc::new(budget.register(4));
        let held = a.try_acquire().expect("only slot");
        let b2 = b.clone();
        let h = std::thread::spawn(move || {
            let g = b2.acquire(); // must wait: budget full
            drop(g);
        });
        // Give the waiter time to register its wait, then release.
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(held);
        h.join().unwrap();
        assert_eq!(a.waits(), 0, "the holder never waited");
        assert!(b.waits() >= 1, "the blocked writer's wait must be attributed to it");
    }

    /// Regression for the adaptive-resize path: a cluster guard
    /// dropped *mid-unwind* (a flush task panicking while the writer
    /// is between size steps) must release its slot and wake blocked
    /// admission waiters — a leaked slot would deadlock every other
    /// writer of the session.
    #[test]
    fn guard_dropped_during_panic_unwind_wakes_blocked_waiters() {
        let budget = Arc::new(WriteBudget::new(1, None));
        let a = budget.register(4);
        let b = Arc::new(budget.register(4));

        // Take the only slot FIRST, then start the waiter.
        let guard = a.try_acquire().expect("only slot");
        let (tx, rx) = std::sync::mpsc::channel();
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || {
            let g = b2.acquire();
            tx.send(()).unwrap();
            drop(g);
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "waiter must block while the slot is held"
        );

        // Holder panics with the guard captured: the unwind drops it.
        let holder = std::thread::spawn(move || {
            let _held = guard;
            panic!("injected mid-resize panic");
        });
        assert!(holder.join().is_err(), "holder must have panicked");

        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("waiter must wake when the unwinding holder drops its guard");
        waiter.join().unwrap();
        assert_eq!(budget.in_flight(), 0, "no slot may leak across the unwind");
    }

    /// The same budget type serves the read side: two prefetching
    /// readers split the read budget max-min fair, exactly like
    /// writers do.
    #[test]
    fn readers_share_a_read_budget_fairly() {
        let budget = IoBudget::new(4, None);
        let r1 = budget.register(8);
        let r2 = budget.register(8);
        assert_eq!(r1.fair_share(), 2);
        let g1 = r1.try_acquire().expect("window slot 1");
        let g2 = r1.try_acquire().expect("window slot 2");
        assert!(r1.try_acquire().is_none(), "reader capped at its share");
        let g3 = r2.try_acquire().expect("second reader's share is intact");
        drop((g1, g2, g3));
        assert_eq!(budget.in_flight(), 0);
    }
}
