//! Implicit multi-threading (IMT): ROOT's `ROOT::EnableImplicitMT()`.
//!
//! A process-global task pool plus scoped task groups. Every implicitly
//! parallel path in the library (parallel column read/write, parallel
//! basket decompression, merger helpers) funnels through here, so a
//! single switch — exactly like ROOT's — turns implicit parallelism on
//! and off for the whole process:
//!
//! ```no_run
//! rootio_par::imt::enable(4);
//! assert!(rootio_par::imt::is_enabled());
//! rootio_par::imt::disable();
//! ```
//!
//! The pool is a from-scratch scoped *work-stealing* scheduler (the
//! TBB analogue): every worker owns a deque (LIFO local execution,
//! FIFO stealing) and an injector queue receives jobs from non-worker
//! threads, so hot paths never contend on a single global lock.
//! [`Pool::scope`] lets callers spawn borrowing closures, and the
//! scope owner *helps execute* queued jobs while it waits, so nested
//! scopes cannot deadlock and a blocked caller still contributes CPU.
//! Idle threads park on a condvar (no polling) and are woken
//! event-count style only when work arrives.

//!
//! [`TaskGroup`] complements the scope with a submit-now, join-later
//! primitive: `'static` jobs with a shared completion count, so a
//! producer (the pipelined tree writer) can enqueue flush tasks, keep
//! filling, and join — or apply backpressure — whenever it likes.

//!
//! [`IoBudget`] adds the session dimension: one global in-flight
//! cluster cap shared by many members, with per-member fair admission,
//! so N pipelined writers — or N prefetching readers — on one pool
//! stay within one memory bound and none of them can starve the
//! others (see [`crate::session`]; `WriteBudget` / `WriterBudget`
//! remain as write-era aliases).

mod budget;
mod pool;

pub use budget::{BudgetStats, ClusterGuard, IoBudget, MemberBudget, WriteBudget, WriterBudget};
pub use pool::{Pool, Scope, TaskGroup};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

static GLOBAL: OnceLock<RwLock<Option<Arc<Pool>>>> = OnceLock::new();
static POOL_SIZE: AtomicUsize = AtomicUsize::new(0);

fn cell() -> &'static RwLock<Option<Arc<Pool>>> {
    GLOBAL.get_or_init(|| RwLock::new(None))
}

/// Enable implicit multi-threading with `n` workers (0 = all cores).
/// Idempotent; re-enabling with a different `n` rebuilds the pool.
pub fn enable(n: usize) {
    let n = if n == 0 { num_cpus() } else { n };
    let mut g = cell().write().unwrap();
    if let Some(p) = g.as_ref() {
        if p.threads() == n {
            return;
        }
    }
    *g = Some(Arc::new(Pool::new(n)));
    POOL_SIZE.store(n, Ordering::Relaxed);
}

/// Disable implicit multi-threading; parallel paths fall back to serial.
pub fn disable() {
    *cell().write().unwrap() = None;
    POOL_SIZE.store(0, Ordering::Relaxed);
}

/// Is IMT currently on?
pub fn is_enabled() -> bool {
    cell().read().unwrap().is_some()
}

/// The global pool, if enabled.
pub fn pool() -> Option<Arc<Pool>> {
    cell().read().unwrap().clone()
}

/// Number of IMT workers (0 when disabled).
pub fn threads() -> usize {
    POOL_SIZE.load(Ordering::Relaxed)
}

/// Best-effort hardware concurrency.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for `i in 0..n`, on the global pool when IMT is enabled,
/// serially otherwise. This is the library's `TThreadExecutor::Foreach`.
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    match pool() {
        Some(p) => p.parallel_for(n, &f),
        None => {
            for i in 0..n {
                f(i);
            }
        }
    }
}

/// Map `f` over `0..n` preserving order, parallel when IMT is on.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    match pool() {
        Some(p) => p.parallel_map(n, &f),
        None => (0..n).map(f).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn global_switch() {
        // Single test exercising the global state to avoid cross-test
        // interference (other tests use private pools).
        disable();
        assert!(!is_enabled());
        let hits = AtomicUsize::new(0);
        parallel_for(100, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);

        enable(3);
        assert!(is_enabled());
        assert_eq!(threads(), 3);
        let hits = AtomicUsize::new(0);
        parallel_for(1000, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);

        let v = parallel_map(50, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());

        enable(3); // idempotent
        assert_eq!(threads(), 3);
        disable();
        assert!(!is_enabled());
        assert_eq!(threads(), 0);
    }
}
