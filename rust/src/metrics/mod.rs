//! Per-thread activity timelines — the stand-in for the paper's VTune
//! screenshots (Figure 7).
//!
//! A [`Recorder`] collects `(thread, kind, start, end)` spans from any
//! instrumented code path. After a run it can report the useful-work
//! fraction per thread, dump CSV for plotting, and render the same kind
//! of ASCII timeline the paper shows: one stripe per thread, dark where
//! the thread does useful work.

use std::collections::HashMap;
use std::sync::Mutex;
use std::thread::ThreadId;
use std::time::{Duration, Instant};

/// What a thread was doing during a span. `Running` counts as *not*
/// useful (the "green" in VTune); everything else is useful ("brown").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    Startup,
    Generate,
    Serialize,
    Compress,
    Decompress,
    Deserialize,
    Process,
    Read,
    Write,
    Merge,
    /// Scheduled but not doing useful work (lock wait, queue wait).
    Running,
}

impl SpanKind {
    pub fn is_useful(self) -> bool {
        !matches!(self, SpanKind::Running)
    }

    pub fn glyph(self) -> char {
        match self {
            SpanKind::Startup => 'S',
            SpanKind::Generate => 'g',
            SpanKind::Serialize => 's',
            SpanKind::Compress => 'c',
            SpanKind::Decompress => 'd',
            SpanKind::Deserialize => 'u',
            SpanKind::Process => 'p',
            SpanKind::Read => 'r',
            SpanKind::Write => 'w',
            SpanKind::Merge => 'm',
            SpanKind::Running => '.',
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Startup => "startup",
            SpanKind::Generate => "generate",
            SpanKind::Serialize => "serialize",
            SpanKind::Compress => "compress",
            SpanKind::Decompress => "decompress",
            SpanKind::Deserialize => "deserialize",
            SpanKind::Process => "process",
            SpanKind::Read => "read",
            SpanKind::Write => "write",
            SpanKind::Merge => "merge",
            SpanKind::Running => "running",
        }
    }
}

/// One recorded activity interval, times relative to the recorder epoch.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub thread: usize,
    pub kind: SpanKind,
    pub start: Duration,
    pub end: Duration,
}

#[derive(Default)]
struct State {
    spans: Vec<Span>,
    threads: HashMap<ThreadId, usize>,
}

/// Thread-safe span collector.
pub struct Recorder {
    epoch: Instant,
    state: Mutex<State>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { epoch: Instant::now(), state: Mutex::new(State::default()) }
    }

    fn thread_index(&self, state: &mut State) -> usize {
        let id = std::thread::current().id();
        let next = state.threads.len();
        *state.threads.entry(id).or_insert(next)
    }

    /// Time `f` and record it under `kind`.
    pub fn record<R>(&self, kind: SpanKind, f: impl FnOnce() -> R) -> R {
        let start = self.epoch.elapsed();
        let out = f();
        let end = self.epoch.elapsed();
        let mut st = self.state.lock().unwrap();
        let thread = self.thread_index(&mut st);
        st.spans.push(Span { thread, kind, start, end });
        out
    }

    /// Record an externally timed span.
    pub fn push(&self, kind: SpanKind, start: Duration, end: Duration) {
        let mut st = self.state.lock().unwrap();
        let thread = self.thread_index(&mut st);
        st.spans.push(Span { thread, kind, start, end });
    }

    pub fn elapsed(&self) -> Duration {
        self.epoch.elapsed()
    }

    pub fn snapshot(&self) -> Vec<Span> {
        self.state.lock().unwrap().spans.clone()
    }

    pub fn n_threads(&self) -> usize {
        self.state.lock().unwrap().threads.len()
    }

    /// Useful-work time per thread, and the total wall time observed.
    pub fn useful_per_thread(&self) -> (Vec<Duration>, Duration) {
        let st = self.state.lock().unwrap();
        let n = st.threads.len();
        let mut useful = vec![Duration::ZERO; n];
        let mut wall = Duration::ZERO;
        for s in &st.spans {
            if s.kind.is_useful() {
                useful[s.thread] += s.end.saturating_sub(s.start);
            }
            wall = wall.max(s.end);
        }
        (useful, wall)
    }

    /// Fraction of (threads × wall) spent doing useful work — the
    /// quantity Figure 7's before/after comparison improves.
    pub fn useful_fraction(&self) -> f64 {
        let (useful, wall) = self.useful_per_thread();
        if useful.is_empty() || wall.is_zero() {
            return 0.0;
        }
        let total: f64 = useful.iter().map(|d| d.as_secs_f64()).sum();
        total / (useful.len() as f64 * wall.as_secs_f64())
    }

    /// CSV dump: `thread,kind,start_us,end_us`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("thread,kind,start_us,end_us\n");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{},{},{},{}\n",
                s.thread,
                s.kind.name(),
                s.start.as_micros(),
                s.end.as_micros()
            ));
        }
        out
    }

    /// ASCII timeline: one row per thread, `width` buckets across the
    /// observed wall time. A bucket shows the glyph of the dominant
    /// useful kind, '.' if only `Running`, ' ' if idle.
    pub fn timeline_ascii(&self, width: usize) -> String {
        let spans = self.snapshot();
        let n_threads = self.n_threads();
        let wall = spans.iter().map(|s| s.end).max().unwrap_or_default();
        if wall.is_zero() || n_threads == 0 || width == 0 {
            return String::new();
        }
        let bucket = wall.as_secs_f64() / width as f64;
        // per (thread, bucket): accumulated useful time per kind glyph
        let mut grid: Vec<Vec<HashMap<char, f64>>> = vec![vec![HashMap::new(); width]; n_threads];
        for s in &spans {
            let b0 = ((s.start.as_secs_f64() / bucket) as usize).min(width - 1);
            let b1 = ((s.end.as_secs_f64() / bucket) as usize).min(width - 1);
            for b in b0..=b1 {
                let cell_start = b as f64 * bucket;
                let cell_end = cell_start + bucket;
                let overlap = (s.end.as_secs_f64().min(cell_end)
                    - s.start.as_secs_f64().max(cell_start))
                .max(0.0);
                *grid[s.thread][b].entry(s.kind.glyph()).or_insert(0.0) += overlap;
            }
        }
        let mut out = String::new();
        for (t, row) in grid.iter().enumerate() {
            out.push_str(&format!("T{t:02} |"));
            for cell in row {
                let ch = cell
                    .iter()
                    .filter(|(g, _)| **g != '.')
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(g, _)| *g)
                    .or_else(|| cell.keys().next().copied())
                    .unwrap_or(' ');
                out.push(ch);
            }
            out.push_str("|\n");
        }
        out.push_str("legend: S startup, g generate, s serialize, c compress, ");
        out.push_str("d decompress, u deserialize, p process, r read, w write, m merge, . idle-running\n");
        out
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn record_and_fractions() {
        let r = Recorder::new();
        r.record(SpanKind::Compress, || std::thread::sleep(Duration::from_millis(10)));
        r.record(SpanKind::Running, || std::thread::sleep(Duration::from_millis(10)));
        let (useful, wall) = r.useful_per_thread();
        assert_eq!(useful.len(), 1);
        assert!(useful[0] >= Duration::from_millis(9));
        assert!(wall >= Duration::from_millis(19));
        let f = r.useful_fraction();
        assert!(f > 0.2 && f < 0.8, "fraction {f}");
    }

    #[test]
    fn multithreaded_spans() {
        let r = Arc::new(Recorder::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    r.record(SpanKind::Write, || std::thread::sleep(Duration::from_millis(5)));
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(r.n_threads(), 4);
        assert_eq!(r.snapshot().len(), 4);
    }

    #[test]
    fn csv_and_ascii_render() {
        let r = Recorder::new();
        r.push(SpanKind::Generate, Duration::ZERO, Duration::from_millis(5));
        r.push(SpanKind::Write, Duration::from_millis(5), Duration::from_millis(10));
        let csv = r.to_csv();
        assert!(csv.contains("generate"));
        assert!(csv.contains("write"));
        let art = r.timeline_ascii(20);
        assert!(art.contains("T00 |"));
        assert!(art.contains('g'));
        assert!(art.contains('w'));
    }

    #[test]
    fn empty_recorder() {
        let r = Recorder::new();
        assert_eq!(r.useful_fraction(), 0.0);
        assert_eq!(r.timeline_ascii(10), "");
    }
}
