//! Pipeline observability: per-thread span timelines (the stand-in
//! for the paper's VTune screenshots, Figure 7) plus the unified
//! metrics [`Registry`].
//!
//! A [`Recorder`] is a cheap-clone handle collecting `(thread, kind,
//! start, end)` spans from any instrumented code path — pool task
//! execution, budget admission waits, prefetch fetch/decode, resilient
//! retries/hedges, writer flush stages, chain file transitions. The
//! record path is *sharded*: each thread appends to its own buffer
//! (one uncontended mutex per thread, drained only at snapshot), so
//! recording never serialises the workers it measures, and a
//! [`Recorder::disabled`] handle costs a single branch. After a run it
//! reports the useful-work fraction per thread, dumps CSV, renders the
//! paper-style ASCII timeline, and exports Chrome trace-event JSON
//! that Perfetto / `chrome://tracing` load directly.
//!
//! Submodules: [`hist`] (log-bucketed latency histograms),
//! [`registry`] (the named counter/gauge tree), [`json`] (reader for
//! the crate's own artifacts).

pub mod hist;
pub mod json;
pub mod registry;

pub use hist::{HistSnapshot, Histogram};
pub use registry::{Registry, Snapshot};

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// What a thread was doing during a span. Waiting kinds (`Running`,
/// `AdmissionWait`, `Retry`, `Hedge`) and the `Task` container count
/// as *not* useful (the "green" in VTune); everything else is useful
/// ("brown").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    Startup,
    Generate,
    Serialize,
    Compress,
    Decompress,
    Deserialize,
    Process,
    Read,
    Write,
    Merge,
    /// Scheduled but not doing useful work (lock wait, queue wait).
    Running,
    /// One pool job executing, whatever it does. A *container* span:
    /// the real work inside it records its own kind, so `Task` itself
    /// is excluded from useful-work accounting (no double counting)
    /// but shows task boundaries in the Chrome trace.
    Task,
    /// A prefetch window's coalesced fetch (plan → verify → decode
    /// spawn).
    Fetch,
    /// The device-level vectored read inside a fetch.
    ScatterRead,
    /// Backoff sleep before a storage retry attempt.
    Retry,
    /// A hedged duplicate read racing a slow primary.
    Hedge,
    /// Blocked acquiring an `IoBudget` slot.
    AdmissionWait,
    /// Sealing one page/basket of a paged cluster (serialise +
    /// compress, recorded by those kinds) — the paged-layout container.
    PageSeal,
    /// Zone-map predicate pruning while building a fetch plan.
    ZonePrune,
    /// A chain advancing to its next file (open + schema check +
    /// prefetcher prime).
    ChainAdvance,
    /// Circuit-breaker state transition (zero-width mark: open,
    /// half-open probe window, or close).
    BreakerTrip,
}

impl SpanKind {
    pub fn is_useful(self) -> bool {
        !matches!(
            self,
            SpanKind::Running
                | SpanKind::Task
                | SpanKind::AdmissionWait
                | SpanKind::Retry
                | SpanKind::Hedge
                | SpanKind::BreakerTrip
        )
    }

    pub fn glyph(self) -> char {
        match self {
            SpanKind::Startup => 'S',
            SpanKind::Generate => 'g',
            SpanKind::Serialize => 's',
            SpanKind::Compress => 'c',
            SpanKind::Decompress => 'd',
            SpanKind::Deserialize => 'u',
            SpanKind::Process => 'p',
            SpanKind::Read => 'r',
            SpanKind::Write => 'w',
            SpanKind::Merge => 'm',
            SpanKind::Running => '.',
            SpanKind::Task => ':',
            SpanKind::Fetch => 'f',
            SpanKind::ScatterRead => 'v',
            SpanKind::Retry => '~',
            SpanKind::Hedge => 'h',
            SpanKind::AdmissionWait => 'a',
            SpanKind::PageSeal => 'P',
            SpanKind::ZonePrune => 'z',
            SpanKind::ChainAdvance => '>',
            SpanKind::BreakerTrip => '!',
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Startup => "startup",
            SpanKind::Generate => "generate",
            SpanKind::Serialize => "serialize",
            SpanKind::Compress => "compress",
            SpanKind::Decompress => "decompress",
            SpanKind::Deserialize => "deserialize",
            SpanKind::Process => "process",
            SpanKind::Read => "read",
            SpanKind::Write => "write",
            SpanKind::Merge => "merge",
            SpanKind::Running => "running",
            SpanKind::Task => "task",
            SpanKind::Fetch => "fetch",
            SpanKind::ScatterRead => "scatter_read",
            SpanKind::Retry => "retry",
            SpanKind::Hedge => "hedge",
            SpanKind::AdmissionWait => "admission_wait",
            SpanKind::PageSeal => "page_seal",
            SpanKind::ZonePrune => "zone_prune",
            SpanKind::ChainAdvance => "chain_advance",
            SpanKind::BreakerTrip => "breaker_trip",
        }
    }

    /// Which subsystem emits this kind — the `cat` field of the Chrome
    /// trace, so Perfetto can filter per layer.
    pub fn subsystem(self) -> &'static str {
        match self {
            SpanKind::Task => "pool",
            SpanKind::AdmissionWait => "budget",
            SpanKind::Fetch => "prefetch",
            SpanKind::ScatterRead
            | SpanKind::Read
            | SpanKind::Retry
            | SpanKind::Hedge
            | SpanKind::BreakerTrip => "storage",
            SpanKind::Serialize | SpanKind::Compress | SpanKind::PageSeal | SpanKind::Write => {
                "writer"
            }
            SpanKind::ChainAdvance | SpanKind::ZonePrune => "chain",
            SpanKind::Decompress | SpanKind::Deserialize => "codec",
            SpanKind::Merge => "merger",
            SpanKind::Startup | SpanKind::Generate | SpanKind::Process => "framework",
            SpanKind::Running => "idle",
        }
    }
}

/// One recorded activity interval, times relative to [`process_epoch`].
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub thread: usize,
    pub kind: SpanKind,
    pub start: Duration,
    pub end: Duration,
}

/// The process-wide monotonic t0 every span is timed against, so
/// spans pushed by different subsystems (and different recorders)
/// share one timebase.
pub fn process_epoch() -> &'static Instant {
    static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Time a closure against [`process_epoch`]; returns `(value, (start,
/// end))`. The interval can be handed to [`Recorder::push`].
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, (Duration, Duration)) {
    let t0 = process_epoch().elapsed();
    let out = f();
    let t1 = process_epoch().elapsed();
    (out, (t0, t1))
}

/// One thread's private span buffer. Only its owning thread appends;
/// the recorder locks it briefly at snapshot time to drain.
struct Shard {
    thread: usize,
    buf: Mutex<Vec<Span>>,
}

struct Inner {
    /// Distinguishes recorders in the thread-local shard cache (an
    /// `Arc` pointer can be reused after drop; this never is).
    id: u64,
    shards: Mutex<Vec<Arc<Shard>>>,
    /// Spans already pulled out of shards by earlier snapshots.
    drained: Mutex<Vec<Span>>,
    next_thread: AtomicUsize,
    /// A recording thread panicked while holding a shard lock. The
    /// spans are plain values so recovery is safe, but surfaced via
    /// [`Recorder::check`] as the PR 2/3 `Error::Sync` convention.
    poisoned: AtomicBool,
}

thread_local! {
    /// Cache of (recorder id, this thread's shard). One entry per
    /// recorder this thread has recorded into; entries whose recorder
    /// died are pruned on the next miss.
    static SHARDS: RefCell<Vec<(u64, Arc<Shard>, Weak<Inner>)>> = const { RefCell::new(Vec::new()) };
}

/// Recover a poisoned lock: span data is plain values, so the state
/// is usable — the poisoning is remembered and surfaced by `check()`.
fn lock_recover<'a, T>(m: &'a Mutex<T>, poisoned: &AtomicBool) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| {
        poisoned.store(true, Ordering::Release);
        p.into_inner()
    })
}

/// Thread-safe span collector handle. `Clone` is an `Arc` bump; all
/// clones feed the same buffers. A [`Recorder::disabled`] handle
/// (also the `Default`) makes every record call a single branch.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Recorder({})", if self.inner.is_some() { "enabled" } else { "disabled" })
    }
}

impl Recorder {
    /// An enabled recorder (historical name; same as [`Recorder::enabled`]).
    pub fn new() -> Self {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        Recorder {
            inner: Some(Arc::new(Inner {
                id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
                shards: Mutex::new(Vec::new()),
                drained: Mutex::new(Vec::new()),
                next_thread: AtomicUsize::new(0),
                poisoned: AtomicBool::new(false),
            })),
        }
    }

    pub fn enabled() -> Self {
        Recorder::new()
    }

    /// The no-op handle: every call is one branch, nothing allocates.
    pub fn disabled() -> Self {
        Recorder { inner: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Do two handles record into the same buffers? (Two disabled
    /// handles compare equal — neither records anything.) Lets an
    /// installer uninstall only its *own* recorder from a shared pool.
    pub fn same(&self, other: &Recorder) -> bool {
        match (&self.inner, &other.inner) {
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            (None, None) => true,
            _ => false,
        }
    }

    /// This thread's shard for this recorder, creating + registering
    /// it on first use.
    fn shard(inner: &Arc<Inner>) -> Arc<Shard> {
        SHARDS.with(|cache| {
            let mut cache = cache.borrow_mut();
            if let Some((_, shard, _)) = cache.iter().find(|(id, _, _)| *id == inner.id) {
                return shard.clone();
            }
            cache.retain(|(_, _, rec)| rec.strong_count() > 0);
            let shard = Arc::new(Shard {
                thread: inner.next_thread.fetch_add(1, Ordering::Relaxed),
                buf: Mutex::new(Vec::new()),
            });
            lock_recover(&inner.shards, &inner.poisoned).push(shard.clone());
            cache.push((inner.id, shard.clone(), Arc::downgrade(inner)));
            shard
        })
    }

    fn append(inner: &Arc<Inner>, kind: SpanKind, start: Duration, end: Duration) {
        let shard = Self::shard(inner);
        let mut buf = lock_recover(&shard.buf, &inner.poisoned);
        buf.push(Span { thread: shard.thread, kind, start, end });
    }

    /// Time `f` and record it under `kind`. Disabled: runs `f` with no
    /// clock reads at all.
    pub fn record<R>(&self, kind: SpanKind, f: impl FnOnce() -> R) -> R {
        let Some(inner) = &self.inner else { return f() };
        let start = process_epoch().elapsed();
        let out = f();
        let end = process_epoch().elapsed();
        Self::append(inner, kind, start, end);
        out
    }

    /// Record an externally timed span (times from [`process_epoch`],
    /// e.g. via [`timed`]).
    pub fn push(&self, kind: SpanKind, start: Duration, end: Duration) {
        if let Some(inner) = &self.inner {
            Self::append(inner, kind, start, end);
        }
    }

    /// Record an instantaneous event (breaker transition, prune
    /// decision) as a zero-length span.
    pub fn mark(&self, kind: SpanKind) {
        if let Some(inner) = &self.inner {
            let t = process_epoch().elapsed();
            Self::append(inner, kind, t, t);
        }
    }

    /// Time since the process epoch (kept for callers that stamp their
    /// own span ends, e.g. the merger output loop).
    pub fn elapsed(&self) -> Duration {
        process_epoch().elapsed()
    }

    /// Surface recording-side lock poisoning (a thread panicked while
    /// appending) as [`Error::Sync`] instead of a propagated panic.
    pub fn check(&self) -> Result<()> {
        match &self.inner {
            Some(inner) if inner.poisoned.load(Ordering::Acquire) => Err(Error::Sync(
                "metrics recorder shard lock poisoned by a panicked thread".into(),
            )),
            _ => Ok(()),
        }
    }

    /// Drain every thread shard and return all spans recorded so far,
    /// sorted by start time. Cumulative: repeated snapshots return the
    /// same (growing) history.
    pub fn snapshot(&self) -> Vec<Span> {
        let Some(inner) = &self.inner else { return Vec::new() };
        let shards: Vec<Arc<Shard>> =
            lock_recover(&inner.shards, &inner.poisoned).clone();
        let mut drained = lock_recover(&inner.drained, &inner.poisoned);
        for shard in shards {
            let mut buf = lock_recover(&shard.buf, &inner.poisoned);
            drained.append(&mut buf);
        }
        let mut out = drained.clone();
        drop(drained);
        out.sort_by_key(|s| (s.start, s.thread));
        out
    }

    /// Threads that have recorded at least one span.
    pub fn n_threads(&self) -> usize {
        match &self.inner {
            Some(inner) => inner.next_thread.load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Useful-work time per thread (union of useful spans — nested or
    /// overlapping spans never double-count), and the wall time
    /// between the first span start and the last span end.
    pub fn useful_per_thread(&self) -> (Vec<Duration>, Duration) {
        let spans = self.snapshot();
        let n = self
            .n_threads()
            .max(spans.iter().map(|s| s.thread + 1).max().unwrap_or(0));
        let mut per: Vec<Vec<(Duration, Duration)>> = vec![Vec::new(); n];
        let mut t0 = Duration::MAX;
        let mut t1 = Duration::ZERO;
        for s in &spans {
            t0 = t0.min(s.start);
            t1 = t1.max(s.end.max(s.start));
            if s.kind.is_useful() && s.end > s.start {
                per[s.thread].push((s.start, s.end));
            }
        }
        let wall = if spans.is_empty() { Duration::ZERO } else { t1.saturating_sub(t0) };
        let useful = per
            .into_iter()
            .map(|mut iv| {
                // Interval union (input already start-sorted by snapshot).
                iv.sort_by_key(|&(s, _)| s);
                let mut total = Duration::ZERO;
                let mut cur: Option<(Duration, Duration)> = None;
                for (s, e) in iv {
                    match &mut cur {
                        Some((_, ce)) if s <= *ce => *ce = (*ce).max(e),
                        _ => {
                            if let Some((cs, ce)) = cur.take() {
                                total += ce.saturating_sub(cs);
                            }
                            cur = Some((s, e));
                        }
                    }
                }
                if let Some((cs, ce)) = cur {
                    total += ce.saturating_sub(cs);
                }
                total
            })
            .collect();
        (useful, wall)
    }

    /// Fraction of (threads × wall) spent doing useful work — the
    /// quantity Figure 7's before/after comparison improves.
    pub fn useful_fraction(&self) -> f64 {
        let (useful, wall) = self.useful_per_thread();
        if useful.is_empty() || wall.is_zero() {
            return 0.0;
        }
        let total: f64 = useful.iter().map(|d| d.as_secs_f64()).sum();
        total / (useful.len() as f64 * wall.as_secs_f64())
    }

    /// CSV dump: `thread,kind,start_us,end_us`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("thread,kind,start_us,end_us\n");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{},{},{},{}\n",
                s.thread,
                s.kind.name(),
                s.start.as_micros(),
                s.end.as_micros()
            ));
        }
        out
    }

    /// Chrome trace-event JSON (the `traceEvents` array of complete
    /// `"ph":"X"` events). Loadable by Perfetto / `chrome://tracing`.
    /// Timestamps are microseconds from the first recorded span.
    pub fn to_chrome_json(&self) -> String {
        let spans = self.snapshot();
        let t0 = spans.iter().map(|s| s.start).min().unwrap_or_default();
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        for (i, s) in spans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let ts = s.start.saturating_sub(t0).as_secs_f64() * 1e6;
            let dur = s.end.saturating_sub(s.start).as_secs_f64() * 1e6;
            out.push_str(&format!(
                "\n{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{}}}",
                s.kind.name(),
                s.kind.subsystem(),
                ts,
                dur.max(0.001),
                s.thread
            ));
        }
        out.push_str("\n]}\n");
        out
    }

    /// ASCII timeline: one row per thread, `width` buckets across the
    /// observed wall time. A bucket shows the glyph of the dominant
    /// useful kind, the dominant waiting glyph if only waits, ' ' if
    /// idle.
    pub fn timeline_ascii(&self, width: usize) -> String {
        let spans = self.snapshot();
        let n_threads = self
            .n_threads()
            .max(spans.iter().map(|s| s.thread + 1).max().unwrap_or(0));
        if spans.is_empty() || n_threads == 0 || width == 0 {
            return String::new();
        }
        let t0 = spans.iter().map(|s| s.start).min().unwrap_or_default();
        let wall = spans
            .iter()
            .map(|s| s.end.max(s.start).saturating_sub(t0))
            .max()
            .unwrap_or_default();
        if wall.is_zero() {
            return String::new();
        }
        let bucket = wall.as_secs_f64() / width as f64;
        // per (thread, bucket): accumulated time per kind
        let mut grid: Vec<Vec<std::collections::HashMap<SpanKind, f64>>> =
            vec![vec![std::collections::HashMap::new(); width]; n_threads];
        for s in &spans {
            let start = s.start.saturating_sub(t0).as_secs_f64();
            let end = s.end.max(s.start).saturating_sub(t0).as_secs_f64();
            let b0 = ((start / bucket) as usize).min(width - 1);
            let b1 = ((end / bucket) as usize).min(width - 1);
            let row = &mut grid[s.thread.min(n_threads - 1)];
            for (b, cell) in row.iter_mut().enumerate().take(b1 + 1).skip(b0) {
                let cell_start = b as f64 * bucket;
                let cell_end = cell_start + bucket;
                let overlap = (end.min(cell_end) - start.max(cell_start)).max(0.0);
                *cell.entry(s.kind).or_insert(0.0) += overlap;
            }
        }
        let dominant = |cell: &std::collections::HashMap<SpanKind, f64>, useful: bool| {
            cell.iter()
                .filter(|(k, _)| k.is_useful() == useful)
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(k, _)| k.glyph())
        };
        let mut out = String::new();
        for (t, row) in grid.iter().enumerate() {
            out.push_str(&format!("T{t:02} |"));
            for cell in row {
                out.push(dominant(cell, true).or_else(|| dominant(cell, false)).unwrap_or(' '));
            }
            out.push_str("|\n");
        }
        out.push_str("legend: S startup, g generate, s serialize, c compress, ");
        out.push_str("d decompress, u deserialize, p process, r read, w write, m merge, ");
        out.push_str("f fetch, v scatter-read, P page-seal, z zone-prune, > chain-advance, ");
        out.push_str(": task, a admission-wait, ~ retry, h hedge, ! breaker-trip, ");
        out.push_str(". idle-running\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn record_and_fractions() {
        let r = Recorder::new();
        r.record(SpanKind::Compress, || std::thread::sleep(Duration::from_millis(10)));
        r.record(SpanKind::Running, || std::thread::sleep(Duration::from_millis(10)));
        let (useful, wall) = r.useful_per_thread();
        assert_eq!(useful.len(), 1);
        assert!(useful[0] >= Duration::from_millis(9));
        assert!(wall >= Duration::from_millis(19));
        let f = r.useful_fraction();
        assert!(f > 0.2 && f < 0.8, "fraction {f}");
    }

    #[test]
    fn multithreaded_spans() {
        let r = Arc::new(Recorder::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    r.record(SpanKind::Write, || std::thread::sleep(Duration::from_millis(5)));
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(r.n_threads(), 4);
        assert_eq!(r.snapshot().len(), 4);
    }

    #[test]
    fn csv_and_ascii_render() {
        let r = Recorder::new();
        let t0 = process_epoch().elapsed();
        r.push(SpanKind::Generate, t0, t0 + Duration::from_millis(5));
        r.push(SpanKind::Write, t0 + Duration::from_millis(5), t0 + Duration::from_millis(10));
        let csv = r.to_csv();
        assert!(csv.contains("generate"));
        assert!(csv.contains("write"));
        let art = r.timeline_ascii(20);
        assert!(art.contains("T00 |"));
        assert!(art.contains('g'));
        assert!(art.contains('w'));
    }

    #[test]
    fn empty_recorder() {
        let r = Recorder::new();
        assert_eq!(r.useful_fraction(), 0.0);
        assert_eq!(r.timeline_ascii(10), "");
        assert!(r.check().is_ok());
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        let v = r.record(SpanKind::Compress, || 42);
        assert_eq!(v, 42);
        r.push(SpanKind::Write, Duration::ZERO, Duration::from_millis(1));
        r.mark(SpanKind::ZonePrune);
        assert!(r.snapshot().is_empty());
        assert_eq!(r.n_threads(), 0);
        assert_eq!(r.useful_fraction(), 0.0);
        assert!(r.check().is_ok());
    }

    #[test]
    fn clones_share_the_same_buffers() {
        let r = Recorder::new();
        let r2 = r.clone();
        r.record(SpanKind::Read, || {});
        r2.record(SpanKind::Write, || {});
        assert_eq!(r.snapshot().len(), 2);
        assert_eq!(r2.snapshot().len(), 2);
    }

    #[test]
    fn nested_spans_do_not_double_count_useful_time() {
        // A Task container holding a Compress span: useful time is the
        // compress interval once, not task + compress.
        let r = Recorder::new();
        let t0 = process_epoch().elapsed();
        let ms = Duration::from_millis;
        r.push(SpanKind::Task, t0, t0 + ms(10));
        r.push(SpanKind::Compress, t0 + ms(2), t0 + ms(8));
        // Overlapping useful spans also merge.
        r.push(SpanKind::Decompress, t0 + ms(6), t0 + ms(9));
        let (useful, wall) = r.useful_per_thread();
        assert_eq!(useful.len(), 1);
        assert_eq!(useful[0], ms(7)); // union of [2,8) and [6,9)
        assert_eq!(wall, ms(10));
    }

    #[test]
    fn zero_duration_and_out_of_order_spans_do_not_panic() {
        let r = Recorder::new();
        let t0 = process_epoch().elapsed();
        let ms = Duration::from_millis;
        r.push(SpanKind::Compress, t0, t0); // zero duration
        r.push(SpanKind::Write, t0 + ms(5), t0 + ms(1)); // end < start
        r.mark(SpanKind::ZonePrune);
        let (useful, _) = r.useful_per_thread();
        assert_eq!(useful[0], Duration::ZERO);
        let _ = r.timeline_ascii(10);
        let _ = r.to_csv();
        let _ = r.to_chrome_json();
        assert!(r.useful_fraction() >= 0.0);
    }

    #[test]
    fn chrome_json_is_valid_and_categorised() {
        let r = Recorder::new();
        r.record(SpanKind::Fetch, || std::thread::sleep(Duration::from_millis(1)));
        r.record(SpanKind::Task, || {});
        let doc = r.to_chrome_json();
        let j = json::parse(&doc).unwrap();
        let events = j.get("traceEvents").and_then(json::Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        let cats: Vec<&str> =
            events.iter().filter_map(|e| e.get("cat").and_then(json::Json::as_str)).collect();
        assert!(cats.contains(&"prefetch"));
        assert!(cats.contains(&"pool"));
        for e in events {
            assert_eq!(e.get("ph").and_then(json::Json::as_str), Some("X"));
            assert!(e.get("dur").and_then(json::Json::as_f64).unwrap() > 0.0);
        }
    }

    #[test]
    fn poisoned_shard_surfaces_as_sync_error_not_panic() {
        // Poison a shard by panicking while the recorder's locks are
        // held on this thread, then confirm the API recovers.
        let r = Recorder::new();
        r.record(SpanKind::Read, || {});
        let inner = r.inner.as_ref().unwrap().clone();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = inner.shards.lock().unwrap();
            panic!("poison");
        }));
        assert!(res.is_err());
        // Snapshot still works (recovers the lock) and check() reports.
        assert_eq!(r.snapshot().len(), 1);
        match r.check() {
            Err(Error::Sync(_)) => {}
            other => panic!("expected Error::Sync, got {other:?}"),
        }
    }
}
