//! Log-bucketed latency histograms.
//!
//! A [`Histogram`] is a lock-free array of power-of-two nanosecond
//! buckets: `record` is three relaxed atomic adds, so it can sit on
//! the window-decode / basket-compress / device-read hot paths without
//! serialising them. [`HistSnapshot`] is the value type the registry
//! stores: it subtracts (`since`) for per-phase deltas and answers
//! quantile queries (p50/p95/p99) at bucket resolution — good to ~2x,
//! which is what a regression gate needs, without retaining one entry
//! per observation the way the old `window_latencies` vec did.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of power-of-two buckets: bucket `i` holds observations in
/// `[2^i, 2^(i+1))` nanoseconds (bucket 0 also absorbs 0 ns), which
/// spans 1 ns ..= ~584 years — every latency this crate can see.
pub const BUCKETS: usize = 64;

/// Concurrent log-bucketed histogram of durations.
#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

fn bucket_of(ns: u64) -> usize {
    // floor(log2(ns)) with 0 mapped to bucket 0.
    (63 - ns.max(1).leading_zeros()) as usize
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    /// Record one observation. Never blocks; three relaxed atomics.
    pub fn record(&self, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current bucket counts out.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`].
#[derive(Clone, Copy, Debug)]
pub struct HistSnapshot {
    buckets: [u64; BUCKETS],
    count: u64,
    sum_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], count: 0, sum_ns: 0 }
    }
}

impl HistSnapshot {
    /// Observations accumulated since the `earlier` snapshot — the
    /// same delta idiom every stats struct in this crate uses.
    pub fn since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (i, dst) in buckets.iter_mut().enumerate() {
            *dst = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        HistSnapshot {
            buckets,
            count: self.count.saturating_sub(earlier.count),
            sum_ns: self.sum_ns.saturating_sub(earlier.sum_ns),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns / self.count)
    }

    /// Value at quantile `p` in `[0, 1]`: the upper bound of the bucket
    /// holding the rank-`ceil(p * count)` observation (so the reported
    /// value is ≥ the true one, never flattering). Zero when empty.
    pub fn quantile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let hi = if i >= 63 { u64::MAX } else { (2u64 << i) - 1 };
                return Duration::from_nanos(hi);
            }
        }
        Duration::from_nanos(u64::MAX)
    }

    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count(), 0);
        assert_eq!(s.p50(), Duration::ZERO);
        assert_eq!(s.mean(), Duration::ZERO);
    }

    #[test]
    fn quantiles_bound_the_observations() {
        let h = Histogram::new();
        for us in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 1000] {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 10);
        // p50 upper bucket bound must cover 50 µs but stay within 2x.
        assert!(s.p50() >= Duration::from_micros(50), "p50 {:?}", s.p50());
        assert!(s.p50() < Duration::from_micros(200), "p50 {:?}", s.p50());
        // p99 lands in the 1 ms outlier's bucket.
        assert!(s.p99() >= Duration::from_micros(1000), "p99 {:?}", s.p99());
        assert!(s.p99() < Duration::from_micros(4000), "p99 {:?}", s.p99());
        assert!(s.mean() >= Duration::from_micros(100));
    }

    #[test]
    fn since_subtracts_buckets_and_count() {
        let h = Histogram::new();
        h.record(Duration::from_micros(5));
        let base = h.snapshot();
        h.record(Duration::from_micros(5));
        h.record(Duration::from_millis(5));
        let delta = h.snapshot().since(&base);
        assert_eq!(delta.count(), 2);
        assert!(delta.p99() >= Duration::from_millis(5));
        // The full snapshot still sees all three.
        assert_eq!(h.snapshot().count(), 3);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Histogram::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.record(Duration::from_nanos(i * 37 + 1));
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
