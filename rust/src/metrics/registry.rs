//! Unified metrics registry: one named counter/gauge tree.
//!
//! Every subsystem in this crate reports through its own stats struct
//! ([`crate::session::SessionStats`], [`crate::cache::PrefetchStats`],
//! [`crate::tree::writer::WriteStats`],
//! [`crate::storage::ResilienceStats`],
//! [`crate::storage::sim::DeviceStats`],
//! [`crate::compress::pool::PoolStats`], sizer/selector summaries).
//! The [`Registry`] folds them into one [`Snapshot`] — a sorted
//! `name → value` tree with `since()` deltas — so `rootio stats`, the
//! bench-trajectory gate and (eventually) a `rootio serve` metrics
//! endpoint all read a single surface instead of ten structs.
//!
//! A [`Registry`] also owns the three *live* latency histograms
//! ([`crate::metrics::hist::Histogram`]) the pipeline feeds directly:
//! window submit→decoded, basket compress, and device read. Recording
//! into them is a few relaxed atomics, so they are always on.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use super::hist::{HistSnapshot, Histogram};
use super::json::escape;

/// Shared handle to the live histograms + snapshot builder.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    window_latency: Histogram,
    basket_compress: Histogram,
    device_read: Histogram,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Window submit→decoded latency (fed by the prefetcher when a
    /// window's last basket finishes decoding).
    pub fn window_latency(&self) -> &Histogram {
        &self.inner.window_latency
    }

    /// Per-basket compression latency (fed by flush tasks).
    pub fn basket_compress(&self) -> &Histogram {
        &self.inner.basket_compress
    }

    /// Device read latency per coalesced scatter fetch (fed by the
    /// prefetcher's fetch tasks).
    pub fn device_read(&self) -> &Histogram {
        &self.inner.device_read
    }

    /// Snapshot with the three live histograms pre-filled; callers
    /// fold whatever stats structs their run produced on top.
    pub fn snapshot(&self) -> Snapshot {
        let mut s = Snapshot::default();
        s.put_hist("window_latency", self.inner.window_latency.snapshot());
        s.put_hist("basket_compress", self.inner.basket_compress.snapshot());
        s.put_hist("device_read", self.inner.device_read.snapshot());
        s
    }
}

/// One point-in-time metrics tree: monotonic counters, point-in-time
/// gauges, and histogram snapshots, each under a dotted name.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    pub fn set_counter(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn add_counter(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn put_hist(&mut self, name: &str, h: HistSnapshot) {
        self.hists.insert(name.to_string(), h);
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Delta view: counters and histograms subtract (missing-in-earlier
    /// counts as zero), gauges keep their current value.
    pub fn since(&self, earlier: &Snapshot) -> Snapshot {
        let mut out = self.clone();
        for (name, v) in &mut out.counters {
            *v = v.saturating_sub(earlier.counter(name).unwrap_or(0));
        }
        for (name, h) in &mut out.hists {
            if let Some(e) = earlier.hist(name) {
                *h = h.since(e);
            }
        }
        out
    }

    fn dur_counter(&mut self, name: &str, d: Duration) {
        self.set_counter(name, d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Fold a session's budget/membership stats in.
    pub fn put_session(&mut self, s: &crate::session::SessionStats) {
        self.set_counter("session.writers_opened", s.writers_opened);
        self.set_gauge("session.active_writers", s.active_writers as f64);
        self.set_gauge("session.in_flight_clusters", s.in_flight_clusters as f64);
        self.set_gauge("session.budget_limit", s.budget_limit as f64);
        self.set_counter("session.admissions", s.admissions);
        self.set_counter("session.admission_waits", s.admission_waits);
        self.set_counter("session.readers_opened", s.readers_opened);
        self.set_gauge("session.active_readers", s.active_readers as f64);
        self.set_gauge("session.in_flight_read_windows", s.in_flight_read_windows as f64);
        self.set_gauge("session.read_budget_limit", s.read_budget_limit as f64);
        self.set_counter("session.read_admission_waits", s.read_admission_waits);
        self.set_gauge("session.in_flight_hedges", s.in_flight_hedges as f64);
        self.set_gauge("session.hedge_limit", s.hedge_limit as f64);
    }

    /// Fold one stream's (or one chain's summed) prefetch stats in
    /// under `prefix` (usually `"prefetch"`).
    pub fn put_prefetch(&mut self, prefix: &str, s: &crate::cache::PrefetchStats) {
        self.set_counter(&format!("{prefix}.clusters"), s.clusters);
        self.set_counter(&format!("{prefix}.baskets"), s.baskets);
        self.set_counter(&format!("{prefix}.device_reads"), s.device_reads);
        self.set_counter(&format!("{prefix}.stored_bytes"), s.stored_bytes);
        self.set_counter(&format!("{prefix}.bytes_selected"), s.bytes_selected);
        self.set_counter(&format!("{prefix}.bytes_skipped"), s.bytes_skipped);
        self.set_counter(&format!("{prefix}.pages_pruned"), s.pages_pruned);
        self.set_counter(&format!("{prefix}.bytes_pruned"), s.bytes_pruned);
        self.dur_counter(&format!("{prefix}.fetch_stall_us"), s.fetch_stall);
        self.dur_counter(&format!("{prefix}.fetch_time_us"), s.fetch_time);
        self.dur_counter(&format!("{prefix}.decode_time_us"), s.decode_time);
        self.set_counter(&format!("{prefix}.admission_denials"), s.admission_denials);
        self.set_counter(&format!("{prefix}.retries"), s.retries);
        self.set_counter(&format!("{prefix}.hedges"), s.hedges);
        self.set_counter(&format!("{prefix}.hedge_wins"), s.hedge_wins);
        self.set_counter(&format!("{prefix}.deadline_misses"), s.deadline_misses);
        self.set_counter(&format!("{prefix}.degraded_windows"), s.degraded_windows);
        self.put_sizer(&format!("{prefix}.window"), &s.window);
    }

    /// Fold a writer's close-time stats in under `prefix`.
    pub fn put_write(&mut self, prefix: &str, s: &crate::tree::writer::WriteStats) {
        self.dur_counter(&format!("{prefix}.serialize_us"), s.serialize);
        self.dur_counter(&format!("{prefix}.compress_us"), s.compress);
        self.dur_counter(&format!("{prefix}.stall_us"), s.stall);
        self.set_counter(&format!("{prefix}.baskets"), s.baskets);
        self.put_sizer(&format!("{prefix}.sizing"), &s.sizing);
        self.set_gauge(&format!("{prefix}.selection.columns"), s.selection.columns as f64);
        self.set_gauge(&format!("{prefix}.selection.committed"), s.selection.committed as f64);
        self.set_counter(&format!("{prefix}.selection.probes"), s.selection.probes);
        self.set_gauge(&format!("{prefix}.selection.reprobes"), s.selection.reprobes as f64);
    }

    /// Fold a resilient backend's counters in under `prefix`.
    pub fn put_resilience(&mut self, prefix: &str, s: &crate::storage::ResilienceStats) {
        self.set_counter(&format!("{prefix}.requests"), s.requests);
        self.set_counter(&format!("{prefix}.attempts"), s.attempts);
        self.set_counter(&format!("{prefix}.retries"), s.retries);
        self.set_counter(&format!("{prefix}.hedges"), s.hedges);
        self.set_counter(&format!("{prefix}.hedge_wins"), s.hedge_wins);
        self.set_counter(&format!("{prefix}.deadline_misses"), s.deadline_misses);
        self.set_counter(&format!("{prefix}.breaker_opens"), s.breaker_opens);
        self.set_counter(&format!("{prefix}.shed"), s.shed);
        self.set_counter(&format!("{prefix}.write_retries"), s.write_retries);
        self.set_counter(&format!("{prefix}.exhausted"), s.exhausted);
    }

    /// Fold a simulated/remote device's counters in under `prefix`.
    pub fn put_device(&mut self, prefix: &str, s: &crate::storage::sim::DeviceStats) {
        self.set_counter(&format!("{prefix}.reads"), s.reads);
        self.set_counter(&format!("{prefix}.writes"), s.writes);
        self.set_counter(&format!("{prefix}.bytes_read"), s.bytes_read);
        self.set_counter(&format!("{prefix}.bytes_written"), s.bytes_written);
        self.set_counter(&format!("{prefix}.seeks"), s.seeks);
        self.dur_counter(&format!("{prefix}.queue_wait_us"), s.queue_wait);
        self.dur_counter(&format!("{prefix}.seek_time_us"), s.seek_time);
        self.dur_counter(&format!("{prefix}.transfer_time_us"), s.transfer_time);
        self.set_counter(&format!("{prefix}.faults"), s.faults);
        self.set_counter(&format!("{prefix}.timeouts"), s.timeouts);
        self.set_counter(&format!("{prefix}.short_reads"), s.short_reads);
        self.set_counter(&format!("{prefix}.stuck"), s.stuck);
    }

    /// Fold the scratch-buffer pool's effectiveness counters in.
    pub fn put_pool(&mut self, s: &crate::compress::pool::PoolStats) {
        self.set_counter("scratch_pool.hits", s.hits);
        self.set_counter("scratch_pool.misses", s.misses);
        self.set_counter("scratch_pool.drops", s.drops);
        self.set_counter("scratch_pool.evictions", s.evictions);
        self.set_gauge("scratch_pool.resident_bytes", s.resident_bytes as f64);
    }

    /// Fold a sizer band summary in under `prefix`.
    pub fn put_sizer(&mut self, prefix: &str, s: &crate::tree::sizer::SizerSummary) {
        self.set_gauge(&format!("{prefix}.min_entries"), s.min_entries as f64);
        self.set_gauge(&format!("{prefix}.max_entries"), s.max_entries as f64);
        self.set_gauge(&format!("{prefix}.last_entries"), s.last_entries as f64);
        self.set_counter(&format!("{prefix}.grows"), s.grows as u64);
        self.set_counter(&format!("{prefix}.shrinks"), s.shrinks as u64);
        self.set_counter(&format!("{prefix}.clusters"), s.clusters);
    }

    /// Serialise the whole tree as JSON (stable key order — the
    /// BTreeMaps keep names sorted).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"counters\": {");
        let mut first = true;
        for (k, v) in &self.counters {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\n    \"{}\": {}", escape(k), v));
        }
        out.push_str("\n  },\n  \"gauges\": {");
        first = true;
        for (k, v) in &self.gauges {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\n    \"{}\": {}", escape(k), fmt_f64(*v)));
        }
        out.push_str("\n  },\n  \"histograms\": {");
        first = true;
        for (k, h) in &self.hists {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    \"{}\": {{\"count\": {}, \"mean_us\": {}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}}}",
                escape(k),
                h.count(),
                fmt_f64(h.mean().as_secs_f64() * 1e6),
                fmt_f64(h.p50().as_secs_f64() * 1e6),
                fmt_f64(h.p95().as_secs_f64() * 1e6),
                fmt_f64(h.p99().as_secs_f64() * 1e6),
            ));
        }
        out.push_str("\n  }\n}\n");
        out
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::json;

    #[test]
    fn counters_gauges_and_since_deltas() {
        let mut a = Snapshot::default();
        a.set_counter("x.n", 10);
        a.set_gauge("x.level", 3.0);
        let mut b = Snapshot::default();
        b.set_counter("x.n", 25);
        b.set_counter("x.new", 5);
        b.set_gauge("x.level", 7.0);
        let d = b.since(&a);
        assert_eq!(d.counter("x.n"), Some(15));
        assert_eq!(d.counter("x.new"), Some(5));
        assert_eq!(d.gauge("x.level"), Some(7.0));
    }

    #[test]
    fn registry_histograms_appear_in_snapshot() {
        let r = Registry::new();
        r.window_latency().record(Duration::from_micros(100));
        r.device_read().record(Duration::from_micros(50));
        let s = r.snapshot();
        assert_eq!(s.hist("window_latency").unwrap().count(), 1);
        assert_eq!(s.hist("device_read").unwrap().count(), 1);
        assert_eq!(s.hist("basket_compress").unwrap().count(), 0);
    }

    #[test]
    fn json_dump_parses_back() {
        let r = Registry::new();
        r.window_latency().record(Duration::from_micros(300));
        let mut s = r.snapshot();
        s.set_counter("session.admissions", 42);
        s.set_gauge("session.budget_limit", 16.0);
        let doc = s.to_json();
        let j = json::parse(&doc).unwrap();
        assert_eq!(
            j.get("counters").and_then(|c| c.get("session.admissions")).and_then(|v| v.as_f64()),
            Some(42.0)
        );
        let h = j.get("histograms").and_then(|h| h.get("window_latency")).unwrap();
        assert_eq!(h.get("count").and_then(|v| v.as_f64()), Some(1.0));
        assert!(h.get("p99_us").and_then(|v| v.as_f64()).unwrap() >= 300.0);
    }
}
