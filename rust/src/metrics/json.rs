//! Minimal JSON reader for this crate's own artifacts.
//!
//! The bench-trajectory gate (`rootio summary`) and the trace
//! acceptance tests need to read back the `BENCH_fig*.json`,
//! `TRACE_*.json` and `STATS_*.json` files the crate itself emits.
//! There is no external JSON dependency, so this is a small
//! recursive-descent parser covering the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null). Malformed
//! input surfaces as [`Error::Format`] — never a panic.

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in document order (duplicates kept; `get`
    /// returns the first).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// First value under `key` when this is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse one JSON document (trailing whitespace allowed, nothing else).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

/// Escape a string for embedding in emitted JSON.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Format(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates degrade to the replacement char;
                            // our own artifacts never emit them.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy the full UTF-8 scalar starting here.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = s.chars().next().ok_or_else(|| self.err("unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bench_shaped_document() {
        let doc = r#"{"bench":"fig1","rows":[{"label":"serial","threads":1,"wall_ms":12.5,"MBps":100.0},{"label":"imt","threads":8,"wall_ms":2.5,"MBps":500.0}]}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("fig1"));
        let rows = j.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("wall_ms").and_then(Json::as_f64), Some(2.5));
    }

    #[test]
    fn parses_escapes_nested_and_literals() {
        let j = parse(r#"{"a":[true,false,null,-1.5e2],"s":"x\n\"A"}"#).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("x\n\"A"));
        let a = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(a[3], Json::Num(-150.0));
        assert_eq!(a[2], Json::Null);
    }

    #[test]
    fn rejects_malformed_without_panic() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"abc", "{\"a\" 1}"] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn escape_round_trips() {
        let s = "line\n\"quoted\"\tand \\ back";
        let doc = format!("{{\"k\":\"{}\"}}", escape(s));
        let j = parse(&doc).unwrap();
        assert_eq!(j.get("k").and_then(Json::as_str), Some(s));
    }
}
