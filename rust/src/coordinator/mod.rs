//! The paper's coordination layer: the scheduling policies that
//! parallelise each phase of the I/O pipeline.
//!
//! * [`read`] — §2.1 / Figure 1: per-column (branch) parallel
//!   decompression + deserialisation.
//! * [`baskets`] — §2.2 / Figure 2: per-basket parallel decompression,
//!   optionally interleaved with processing of the decompressed data
//!   (the PJRT analysis graph).
//! * [`write`] — §3.1 / Figure 3: per-column parallel serialisation +
//!   compression on the write path.
//!
//! All policies degrade gracefully to serial execution when IMT is
//! disabled — the "IMT off" baselines of every figure.

pub mod baskets;
pub mod read;
pub mod write;
