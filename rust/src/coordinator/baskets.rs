//! Parallel basket decompression with interleaved processing
//! (paper §2.2, Figure 2).
//!
//! Baskets are grouped in aligned clusters (all branches cut at the
//! same entries). Each cluster becomes one task: fetch + decompress +
//! deserialise its branch baskets. With `split_clusters` (default) a
//! cluster additionally fans out one subtask per branch basket on the
//! work-stealing pool, so a tree whose cluster count is smaller than
//! the thread count still saturates every core — parallelism scales
//! as `min(total_baskets, T)` rather than `min(clusters, T)`. When an
//! analysis [`Engine`] is attached, the completed cluster is
//! immediately submitted to the PJRT analysis graph; the graph runs on
//! the runtime service thread, so *processing of decompressed data
//! overlaps with decompression of the next clusters* — exactly the
//! interleaving the paper ships in ROOT 6.14.

use std::sync::Mutex;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::imt;
use crate::runtime::Engine;
use crate::serial::column::ColumnData;
use crate::tree::reader::TreeReader;

/// Pipeline options.
#[derive(Clone, Debug)]
pub struct PipelineOptions {
    /// Force serial decompression (the IMT-off baseline).
    pub force_serial: bool,
    /// Split each cluster into per-branch basket subtasks (nested on
    /// the work-stealing pool). Off = one monolithic task per cluster,
    /// the pre-split behaviour kept for comparison benchmarks.
    pub split_clusters: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions { force_serial: false, split_clusters: true }
    }
}

/// Accounting from one pipeline run.
#[derive(Debug, Default)]
pub struct PipelineReport {
    pub clusters: usize,
    pub baskets: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub raw_bytes: u64,
    pub wall: std::time::Duration,
    /// Summed analysis histogram (when an engine was attached).
    pub hist: Option<Vec<f32>>,
    /// Number of events analysed.
    pub analyzed: u64,
}

impl PipelineReport {
    pub fn decompression_mbps(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }
}

/// Cluster boundaries (shared basket cuts) of a tree.
///
/// Returns `(first_entry, n_entries, basket_index)` per cluster and
/// validates the alignment invariant the writer guarantees.
pub fn clusters(reader: &TreeReader) -> Result<Vec<(u64, u32, usize)>> {
    let meta = reader.meta();
    let Some(first) = meta.branches.first() else { return Ok(Vec::new()) };
    let cuts: Vec<(u64, u32, usize)> = first
        .baskets
        .iter()
        .enumerate()
        .map(|(k, b)| (b.first_entry, b.n_entries, k))
        .collect();
    for br in &meta.branches[1..] {
        if br.baskets.len() != cuts.len()
            || br
                .baskets
                .iter()
                .zip(&cuts)
                .any(|(b, c)| b.first_entry != c.0 || b.n_entries != c.1)
        {
            return Err(Error::Coordinator(format!(
                "branch '{}' basket cuts are not cluster-aligned",
                br.name
            )));
        }
    }
    Ok(cuts)
}

/// Run the decompression (+ optional analysis) pipeline over the whole
/// tree. The decoded data is *not* retained — like an analysis pass,
/// each cluster is consumed and dropped, so memory stays bounded by the
/// number of in-flight tasks.
pub fn run(
    reader: &TreeReader,
    engine: Option<&Engine>,
    opts: &PipelineOptions,
) -> Result<PipelineReport> {
    let cuts = clusters(reader)?;
    let meta = reader.meta();
    let nbins = engine.map(|e| e.meta().nbins).unwrap_or(0);
    let acc: Mutex<(Vec<f32>, u64)> = Mutex::new((vec![0f32; nbins], 0));
    let errors: Mutex<Vec<Error>> = Mutex::new(Vec::new());
    let t0 = Instant::now();

    let parallel = !opts.force_serial && imt::is_enabled();
    // Oversized-cluster splitting: with fewer clusters than workers a
    // per-cluster task graph strands cores, so each cluster's branch
    // baskets become their own pool subtasks (nested scopes are
    // deadlock-free — the owner helps execute).
    let split = parallel && opts.split_clusters && meta.branches.len() > 1;

    let process_cluster = |k: usize| {
        let (first_entry, n_entries, basket) = cuts[k];
        let _ = first_entry;
        let run_one = || -> Result<()> {
            // fetch + decompress + deserialise every branch's basket
            let cols: Vec<ColumnData> = if split {
                imt::parallel_map(meta.branches.len(), |b| reader.read_basket(b, basket))
                    .into_iter()
                    .collect::<Result<_>>()?
            } else {
                let mut cols = Vec::with_capacity(meta.branches.len());
                for b in 0..meta.branches.len() {
                    cols.push(reader.read_basket(b, basket)?);
                }
                cols
            };
            if let Some(engine) = engine {
                let n = n_entries as usize;
                let ncols = engine.meta().ncols;
                if cols.len() < ncols {
                    return Err(Error::Coordinator(format!(
                        "analysis needs {ncols} columns, tree has {}",
                        cols.len()
                    )));
                }
                // row-major (n, ncols) hand-off buffer for PJRT
                let mut flat = vec![0f32; n * ncols];
                for (c, col) in cols.iter().take(ncols).enumerate() {
                    let v = col.as_f32().ok_or_else(|| {
                        Error::Coordinator("analysis columns must be f32".into())
                    })?;
                    for i in 0..n {
                        flat[i * ncols + c] = v[i];
                    }
                }
                let res = engine.analyze(flat, n)?;
                let mut g = acc.lock().unwrap();
                for (h, v) in g.0.iter_mut().zip(&res.hist) {
                    *h += v;
                }
                g.1 += n as u64;
            }
            Ok(())
        };
        if let Err(e) = run_one() {
            errors.lock().unwrap().push(e);
        }
    };

    if parallel {
        imt::parallel_for(cuts.len(), process_cluster);
    } else {
        for k in 0..cuts.len() {
            process_cluster(k);
        }
    }

    if let Some(e) = errors.into_inner().unwrap().into_iter().next() {
        return Err(e);
    }
    let wall = t0.elapsed();
    let (hist, analyzed) = acc.into_inner().unwrap();
    let stored: u64 = meta.branches.iter().map(|b| b.stored_bytes()).sum();
    let raw: u64 = meta.branches.iter().map(|b| b.raw_bytes()).sum();
    Ok(PipelineReport {
        clusters: cuts.len(),
        baskets: cuts.len() * meta.branches.len(),
        entries: reader.entries(),
        stored_bytes: stored,
        raw_bytes: raw,
        wall,
        hist: engine.map(|_| hist),
        analyzed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::column::ColumnData;
    use crate::serial::schema::Schema;
    use crate::storage::mem::MemBackend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};
    use std::sync::Arc;

    fn build(n_branches: usize, entries: usize, basket: usize) -> Arc<FileReader> {
        let schema = Schema::flat_f32("c", n_branches);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), n_branches);
        let cfg = WriterConfig {
            basket_entries: basket,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        let mut remaining = entries;
        while remaining > 0 {
            let n = remaining.min(basket);
            let block: Vec<ColumnData> = (0..n_branches)
                .map(|b| ColumnData::F32((0..n).map(|i| (b * i) as f32).collect()))
                .collect();
            w.fill_columns(&block).unwrap();
            remaining -= n;
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    #[test]
    fn clusters_enumerated() {
        let file = build(4, 1000, 256);
        let reader = TreeReader::open_first(file).unwrap();
        let cuts = clusters(&reader).unwrap();
        assert_eq!(cuts.len(), 4); // 256,256,256,232
        assert_eq!(cuts[0], (0, 256, 0));
        assert_eq!(cuts[3], (768, 232, 3));
    }

    #[test]
    fn serial_pipeline_accounts_everything() {
        let file = build(6, 2000, 512);
        let reader = TreeReader::open_first(file).unwrap();
        let rep =
            run(&reader, None, &PipelineOptions { force_serial: true, ..Default::default() })
                .unwrap();
        assert_eq!(rep.clusters, 4);
        assert_eq!(rep.baskets, 24);
        assert_eq!(rep.entries, 2000);
        assert_eq!(rep.raw_bytes, 6 * 2000 * 4);
        assert!(rep.hist.is_none());
    }

    #[test]
    fn parallel_matches_serial_accounting() {
        let file = build(6, 2000, 250);
        let reader = TreeReader::open_first(file).unwrap();
        let serial =
            run(&reader, None, &PipelineOptions { force_serial: true, ..Default::default() })
                .unwrap();
        crate::imt::enable(4);
        let parallel = run(&reader, None, &PipelineOptions::default()).unwrap();
        crate::imt::disable();
        assert_eq!(serial.raw_bytes, parallel.raw_bytes);
        assert_eq!(serial.clusters, parallel.clusters);
    }

    #[test]
    fn split_and_unsplit_clusters_agree() {
        // Fewer clusters (2) than workers (4): splitting is what keeps
        // the extra workers busy; both modes must account identically.
        let file = build(8, 1000, 500);
        let reader = TreeReader::open_first(file).unwrap();
        crate::imt::enable(4);
        let split = run(&reader, None, &PipelineOptions::default()).unwrap();
        let unsplit = run(
            &reader,
            None,
            &PipelineOptions { force_serial: false, split_clusters: false },
        )
        .unwrap();
        crate::imt::disable();
        assert_eq!(split.clusters, 2);
        assert_eq!(split.baskets, unsplit.baskets);
        assert_eq!(split.raw_bytes, unsplit.raw_bytes);
        assert_eq!(split.entries, unsplit.entries);
    }
}
