//! Parallel column writing (paper §3.1) — convenience pipeline that
//! builds a single-tree file from column blocks. Serialisation and
//! compression run through the tree writer's flush pipeline: with
//! `FlushMode::Pipelined` the producer keeps landing blocks while
//! earlier clusters compress on the IMT pool, and the report's
//! `stall` / `compress_time` pair quantifies the overlap (stall
//! strictly below compress time means the producer was *not* the
//! bottleneck — the paper's §3.1 goal).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::format::writer::FileWriter;
use crate::format::Directory;
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::storage::BackendRef;
use crate::tree::sink::FileSink;
use crate::tree::writer::{TreeWriter, WriterConfig};

/// Accounting from a write pipeline run.
#[derive(Debug, Clone, Copy)]
pub struct WriteReport {
    pub entries: u64,
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub wall: Duration,
    /// Producer stall: wall time `fill` spent blocked on flush work
    /// (backpressure plus the close join).
    pub stall: Duration,
    /// Total compression CPU across flush tasks.
    pub compress_time: Duration,
    /// Total serialisation CPU across flush tasks.
    pub serialize_time: Duration,
}

impl WriteReport {
    /// Uncompressed-data ingest bandwidth.
    pub fn throughput_mbps(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }

    pub fn compression_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            return 1.0;
        }
        self.raw_bytes as f64 / self.stored_bytes as f64
    }

    /// Fraction of compression CPU the producer did *not* wait for
    /// (0.0 = fully synchronous, → 1.0 = fully overlapped).
    pub fn overlap_fraction(&self) -> f64 {
        if self.compress_time.is_zero() {
            return 0.0;
        }
        let stall = self.stall.min(self.compress_time);
        1.0 - stall.as_secs_f64() / self.compress_time.as_secs_f64()
    }
}

/// Write `blocks` (each one `ColumnData` per branch) as tree `name` on
/// `backend`, then finalise the file. Returns throughput accounting.
pub fn write_blocks<I>(
    backend: BackendRef,
    schema: Schema,
    name: &str,
    config: WriterConfig,
    blocks: I,
) -> Result<WriteReport>
where
    I: IntoIterator<Item = Vec<ColumnData>>,
{
    let t0 = Instant::now();
    let fw = Arc::new(FileWriter::create(backend)?);
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = TreeWriter::new(schema.clone(), sink, config);
    for block in blocks {
        w.fill_columns(&block)?;
    }
    let (sink, entries, stats) = w.close()?;
    let meta = sink.into_meta(name.to_string(), schema, entries)?;
    let raw: u64 = meta.branches.iter().map(|b| b.raw_bytes()).sum();
    let stored: u64 = meta.branches.iter().map(|b| b.stored_bytes()).sum();
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(WriteReport {
        entries,
        raw_bytes: raw,
        stored_bytes: stored,
        wall: t0.elapsed(),
        stall: stats.stall,
        compress_time: stats.compress,
        serialize_time: stats.serialize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use crate::tree::reader::TreeReader;
    use crate::tree::writer::{FlushGranularity, FlushMode};

    #[test]
    fn write_blocks_roundtrip_and_accounting() {
        let schema = Schema::flat_f32("x", 3);
        let be = Arc::new(MemBackend::new());
        let blocks: Vec<Vec<ColumnData>> = (0..4)
            .map(|blk| {
                (0..3)
                    .map(|b| {
                        ColumnData::F32((0..1000).map(|i| (blk * 100 + i + b) as f32).collect())
                    })
                    .collect()
            })
            .collect();
        let cfg = WriterConfig {
            basket_entries: 1000,
            compression: Settings::new(Codec::Rzip, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let rep = write_blocks(be.clone(), schema, "t", cfg, blocks).unwrap();
        assert_eq!(rep.entries, 4000);
        assert_eq!(rep.raw_bytes, 3 * 4000 * 4);
        assert!(rep.stored_bytes > 0);
        assert!(rep.compression_ratio() >= 1.0);
        assert!(rep.compress_time > Duration::ZERO);
        assert!(rep.serialize_time > Duration::ZERO);

        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(reader.entries(), 4000);
        let cols = reader.read_all().unwrap();
        assert_eq!(cols[0].len(), 4000);
    }

    #[test]
    fn pipelined_write_is_byte_identical_to_serial_write() {
        let schema = Schema::flat_f32("x", 8);
        let blocks: Vec<Vec<ColumnData>> = vec![(0..8)
            .map(|b| ColumnData::F32((0..512).map(|i| ((i * b) % 31) as f32).collect()))
            .collect()];
        let mk = |flush: FlushMode| {
            let be = Arc::new(MemBackend::new());
            let cfg = WriterConfig {
                basket_entries: 128,
                compression: Settings::new(Codec::Rzip, 2),
                flush,
                granularity: FlushGranularity::Block,
                max_inflight_clusters: 2,
            };
            let rep =
                write_blocks(be.clone(), schema.clone(), "t", cfg, blocks.clone()).unwrap();
            let len = be.len().unwrap() as usize;
            let mut bytes = vec![0u8; len];
            be.read_at(0, &mut bytes).unwrap();
            (rep, bytes)
        };
        let (rs, bytes_serial) = mk(FlushMode::Serial);
        crate::imt::enable(4);
        let (rp, bytes_pipelined) = mk(FlushMode::Pipelined);
        let (rb, bytes_parallel) = mk(FlushMode::Parallel);
        crate::imt::disable();
        assert_eq!(bytes_serial, bytes_pipelined, "pipelined file diverged");
        assert_eq!(bytes_serial, bytes_parallel, "parallel file diverged");
        assert_eq!(rs.stored_bytes, rp.stored_bytes);
        assert_eq!(rs.stored_bytes, rb.stored_bytes);
        // serial mode: the producer pays the whole flush, so stall
        // covers serialise + compress by construction
        assert!(rs.stall >= rs.compress_time);
    }
}
