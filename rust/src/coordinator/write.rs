//! Parallel column writing (paper §3.1) — convenience pipeline that
//! builds a single-tree file from column blocks, with per-branch
//! serialisation + compression parallelised through IMT by the tree
//! writer's flush.

use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::format::writer::FileWriter;
use crate::format::Directory;
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::storage::BackendRef;
use crate::tree::sink::FileSink;
use crate::tree::writer::{TreeWriter, WriterConfig};

/// Accounting from a write pipeline run.
#[derive(Debug, Clone, Copy)]
pub struct WriteReport {
    pub entries: u64,
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub wall: std::time::Duration,
}

impl WriteReport {
    /// Uncompressed-data ingest bandwidth.
    pub fn throughput_mbps(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }

    pub fn compression_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            return 1.0;
        }
        self.raw_bytes as f64 / self.stored_bytes as f64
    }
}

/// Write `blocks` (each one `ColumnData` per branch) as tree `name` on
/// `backend`, then finalise the file. Returns throughput accounting.
pub fn write_blocks<I>(
    backend: BackendRef,
    schema: Schema,
    name: &str,
    config: WriterConfig,
    blocks: I,
) -> Result<WriteReport>
where
    I: IntoIterator<Item = Vec<ColumnData>>,
{
    let t0 = Instant::now();
    let fw = Arc::new(FileWriter::create(backend)?);
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = TreeWriter::new(schema.clone(), sink, config);
    for block in blocks {
        w.fill_columns(&block)?;
    }
    let (sink, entries) = w.close()?;
    let meta = sink.into_meta(name.to_string(), schema, entries);
    let raw: u64 = meta.branches.iter().map(|b| b.raw_bytes()).sum();
    let stored: u64 = meta.branches.iter().map(|b| b.stored_bytes()).sum();
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(WriteReport { entries, raw_bytes: raw, stored_bytes: stored, wall: t0.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::storage::mem::MemBackend;
    use crate::tree::reader::TreeReader;

    #[test]
    fn write_blocks_roundtrip_and_accounting() {
        let schema = Schema::flat_f32("x", 3);
        let be = Arc::new(MemBackend::new());
        let blocks: Vec<Vec<ColumnData>> = (0..4)
            .map(|blk| {
                (0..3)
                    .map(|b| {
                        ColumnData::F32((0..1000).map(|i| (blk * 100 + i + b) as f32).collect())
                    })
                    .collect()
            })
            .collect();
        let cfg = WriterConfig {
            basket_entries: 1000,
            compression: Settings::new(Codec::Rzip, 3),
            parallel_flush: false,
        };
        let rep = write_blocks(be.clone(), schema, "t", cfg, blocks).unwrap();
        assert_eq!(rep.entries, 4000);
        assert_eq!(rep.raw_bytes, 3 * 4000 * 4);
        assert!(rep.stored_bytes > 0);
        assert!(rep.compression_ratio() >= 1.0);

        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(reader.entries(), 4000);
        let cols = reader.read_all().unwrap();
        assert_eq!(cols[0].len(), 4000);
    }

    #[test]
    fn imt_write_matches_serial_write_content() {
        let schema = Schema::flat_f32("x", 8);
        let blocks: Vec<Vec<ColumnData>> = vec![(0..8)
            .map(|b| ColumnData::F32((0..512).map(|i| ((i * b) % 31) as f32).collect()))
            .collect()];
        let mk = |parallel: bool| {
            let be = Arc::new(MemBackend::new());
            let cfg = WriterConfig {
                basket_entries: 128,
                compression: Settings::new(Codec::Rzip, 2),
                parallel_flush: parallel,
            };
            let rep =
                write_blocks(be.clone(), schema.clone(), "t", cfg, blocks.clone()).unwrap();
            let reader =
                TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
            (rep, reader.read_all().unwrap())
        };
        let (rs, cols_serial) = mk(false);
        crate::imt::enable(4);
        let (rp, cols_parallel) = mk(true);
        crate::imt::disable();
        assert_eq!(cols_serial, cols_parallel);
        assert_eq!(rs.stored_bytes, rp.stored_bytes);
    }
}
