//! Parallel column writing (paper §3.1) — convenience pipelines that
//! build files from column blocks, opened under an I/O [`Session`].
//!
//! One writer: [`write_blocks`] builds a single-tree file; with
//! `FlushMode::Pipelined` the producer keeps landing blocks while
//! earlier clusters compress on the session's pool, and the report's
//! `stall` / `compress_time` pair quantifies the overlap (stall
//! strictly below compress time means the producer was *not* the
//! bottleneck — the paper's §3.1 goal).
//!
//! Many writers: [`write_files`] runs N producer threads, one
//! [`WriteJob`] each, **all attached to one shared session** — one
//! pool, one global in-flight cluster budget with per-writer fair
//! admission. That is the multi-file production shape (Riley & Jones'
//! concurrent CMS output modules): aggregate throughput scales with
//! the writer count while buffered memory stays inside the one
//! session bound, and every output file is byte-identical to the same
//! writer run alone. Session-shared writing of *several trees into
//! one file* goes through [`crate::tree::sink::FileSink::finish_tree`]
//! + [`crate::format::writer::FileWriter::finish_registered`] instead.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::format::writer::FileWriter;
use crate::format::Directory;
use crate::serial::column::ColumnData;
use crate::serial::schema::Schema;
use crate::session::Session;
use crate::storage::BackendRef;
use crate::tree::sink::FileSink;
use crate::compress::select::SelectSummary;
use crate::tree::sizer::SizerSummary;
use crate::tree::writer::{TreeWriter, WriterConfig};

/// Accounting from a write pipeline run.
#[derive(Debug, Clone, Copy)]
pub struct WriteReport {
    pub entries: u64,
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub wall: Duration,
    /// Producer stall: wall time `fill` spent blocked on flush work
    /// (backpressure plus the close join).
    pub stall: Duration,
    /// Total compression CPU across flush tasks.
    pub compress_time: Duration,
    /// Total serialisation CPU across flush tasks.
    pub serialize_time: Duration,
    /// Cluster-size report: the band of cluster sizes the writer cut
    /// (constant under `ClusterSizing::Fixed`; the adaptive sizer's
    /// chosen band and step counts under `ClusterSizing::Adaptive`).
    pub sizing: SizerSummary,
    /// Per-column codec-selection report (all-zero under
    /// `CodecSelection::Global`).
    pub selection: SelectSummary,
}

impl WriteReport {
    /// Uncompressed-data ingest bandwidth. Degenerate runs — nothing
    /// written, or a wall too short to measure — report 0.0 rather
    /// than dividing by zero.
    pub fn throughput_mbps(&self) -> f64 {
        if self.raw_bytes == 0 || self.wall.is_zero() {
            return 0.0;
        }
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }

    pub fn compression_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            return 1.0;
        }
        self.raw_bytes as f64 / self.stored_bytes as f64
    }

    /// Fraction of compression CPU the producer did *not* wait for
    /// (0.0 = fully synchronous, → 1.0 = fully overlapped). Empty
    /// runs — no compression work at all — report 0.0 rather than
    /// dividing by the zero compress time.
    pub fn overlap_fraction(&self) -> f64 {
        if self.compress_time.is_zero() {
            return 0.0;
        }
        let stall = self.stall.min(self.compress_time);
        1.0 - stall.as_secs_f64() / self.compress_time.as_secs_f64()
    }
}

/// Write `blocks` (each one `ColumnData` per branch) as tree `name` on
/// `backend`, then finalise the file. Returns throughput accounting.
/// The writer runs under a private single-writer session; see
/// [`write_blocks_in_session`] to share a job-wide one.
pub fn write_blocks<I>(
    backend: BackendRef,
    schema: Schema,
    name: &str,
    config: WriterConfig,
    blocks: I,
) -> Result<WriteReport>
where
    I: IntoIterator<Item = Vec<ColumnData>>,
{
    let session = Session::solo(config.max_inflight_clusters);
    write_blocks_in_session(&session, backend, schema, name, config, blocks)
}

/// As [`write_blocks`], with the writer attached to `session`: flush
/// tasks run on the session pool and cluster admission draws from the
/// session's shared budget alongside the job's other writers.
pub fn write_blocks_in_session<I>(
    session: &Session,
    backend: BackendRef,
    schema: Schema,
    name: &str,
    config: WriterConfig,
    blocks: I,
) -> Result<WriteReport>
where
    I: IntoIterator<Item = Vec<ColumnData>>,
{
    let t0 = Instant::now();
    let fw = Arc::new(FileWriter::create(backend)?);
    let sink = FileSink::new(fw.clone(), schema.len());
    let mut w = TreeWriter::attached(schema.clone(), sink, config, session);
    for block in blocks {
        w.fill_columns(&block)?;
    }
    let (sink, entries, stats) = w.close()?;
    let meta = sink.into_meta(name.to_string(), schema, entries)?;
    let raw: u64 = meta.branches.iter().map(|b| b.raw_bytes()).sum();
    let stored: u64 = meta.branches.iter().map(|b| b.stored_bytes()).sum();
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(WriteReport {
        entries,
        raw_bytes: raw,
        stored_bytes: stored,
        wall: t0.elapsed(),
        stall: stats.stall,
        compress_time: stats.compress,
        serialize_time: stats.serialize,
        sizing: stats.sizing,
        selection: stats.selection,
    })
}

/// One output file of a multi-writer job: its destination, tree shape
/// and the blocks its producer will land.
pub struct WriteJob {
    pub backend: BackendRef,
    pub schema: Schema,
    pub name: String,
    pub config: WriterConfig,
    pub blocks: Vec<Vec<ColumnData>>,
}

/// Write many files concurrently under one shared `session`: one
/// producer thread per job, every writer drawing from the session's
/// pool and fair-share in-flight budget. Reports come back in job
/// order; the first failure wins. Each output is byte-identical to
/// the same job written alone (ordered appends per file), so
/// concurrency is purely a throughput property.
pub fn write_files(session: &Session, jobs: Vec<WriteJob>) -> Result<Vec<WriteReport>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|job| {
                let session = session.clone();
                s.spawn(move || {
                    write_blocks_in_session(
                        &session,
                        job.backend,
                        job.schema,
                        &job.name,
                        job.config,
                        job.blocks,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(std::panic::resume_unwind))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use crate::tree::reader::TreeReader;
    use crate::tree::writer::{FlushGranularity, FlushMode};

    #[test]
    fn write_blocks_roundtrip_and_accounting() {
        let schema = Schema::flat_f32("x", 3);
        let be = Arc::new(MemBackend::new());
        let blocks: Vec<Vec<ColumnData>> = (0..4)
            .map(|blk| {
                (0..3)
                    .map(|b| {
                        ColumnData::F32((0..1000).map(|i| (blk * 100 + i + b) as f32).collect())
                    })
                    .collect()
            })
            .collect();
        let cfg = WriterConfig {
            basket_entries: 1000,
            compression: Settings::new(Codec::Rzip, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let rep = write_blocks(be.clone(), schema, "t", cfg, blocks).unwrap();
        assert_eq!(rep.entries, 4000);
        assert_eq!(rep.raw_bytes, 3 * 4000 * 4);
        assert!(rep.stored_bytes > 0);
        assert!(rep.compression_ratio() >= 1.0);
        assert!(rep.compress_time > Duration::ZERO);
        assert!(rep.serialize_time > Duration::ZERO);

        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(reader.entries(), 4000);
        let cols = reader.read_all().unwrap();
        assert_eq!(cols[0].len(), 4000);
    }

    #[test]
    fn degenerate_reports_are_guarded() {
        // Hand-built empty report: all the rate/ratio accessors must
        // return finite values instead of dividing by zero.
        let empty = WriteReport {
            entries: 0,
            raw_bytes: 0,
            stored_bytes: 0,
            wall: Duration::ZERO,
            stall: Duration::ZERO,
            compress_time: Duration::ZERO,
            serialize_time: Duration::ZERO,
            sizing: SizerSummary::default(),
            selection: SelectSummary::default(),
        };
        assert_eq!(empty.throughput_mbps(), 0.0);
        assert_eq!(empty.overlap_fraction(), 0.0);
        assert_eq!(empty.compression_ratio(), 1.0);

        // Zero wall but non-zero bytes (clock quantisation): still 0.0,
        // never inf/NaN.
        let quantised = WriteReport { raw_bytes: 4096, ..empty };
        assert_eq!(quantised.throughput_mbps(), 0.0);
        assert!(quantised.throughput_mbps().is_finite());

        // A real empty run through the full pipeline agrees.
        let be = Arc::new(MemBackend::new());
        let rep = write_blocks(
            be,
            Schema::flat_f32("x", 2),
            "t",
            WriterConfig::default(),
            Vec::<Vec<ColumnData>>::new(),
        )
        .unwrap();
        assert_eq!(rep.entries, 0);
        assert_eq!(rep.throughput_mbps(), 0.0);
        assert_eq!(rep.overlap_fraction(), 0.0);
    }

    #[test]
    fn write_files_shares_one_session_and_matches_solo_bytes() {
        use crate::imt::Pool;
        use crate::session::SessionConfig;
        let schema = Schema::flat_f32("c", 3);
        let mk_blocks = |seed: usize| -> Vec<Vec<ColumnData>> {
            (0..3)
                .map(|blk| {
                    (0..3)
                        .map(|b| {
                            ColumnData::F32(
                                (0..400)
                                    .map(|i| ((seed * 7919 + blk * 131 + b * 17 + i) % 97) as f32)
                                    .collect(),
                            )
                        })
                        .collect()
                })
                .collect()
        };
        let cfg = WriterConfig {
            basket_entries: 256,
            compression: Settings::new(Codec::Rzip, 2),
            flush: FlushMode::Pipelined,
            granularity: FlushGranularity::Block,
            max_inflight_clusters: 2,
            ..Default::default()
        };
        // Ground truth: each job alone, serial flush.
        let solo_bytes: Vec<Vec<u8>> = (0..3)
            .map(|j| {
                let be = Arc::new(MemBackend::new());
                let solo_cfg = WriterConfig { flush: FlushMode::Serial, ..cfg.clone() };
                write_blocks(be.clone(), schema.clone(), "t", solo_cfg, mk_blocks(j)).unwrap();
                let mut bytes = vec![0u8; be.len().unwrap() as usize];
                be.read_at(0, &mut bytes).unwrap();
                bytes
            })
            .collect();
        // Concurrent: all three under one session on a private pool.
        let pool = Arc::new(Pool::new(3));
        let session = crate::session::Session::with_pool(
            pool,
            SessionConfig::for_writers(3, 2),
        );
        let backends: Vec<Arc<MemBackend>> =
            (0..3).map(|_| Arc::new(MemBackend::new())).collect();
        let jobs: Vec<WriteJob> = backends
            .iter()
            .enumerate()
            .map(|(j, be)| WriteJob {
                backend: be.clone(),
                schema: schema.clone(),
                name: "t".into(),
                config: cfg.clone(),
                blocks: mk_blocks(j),
            })
            .collect();
        let reports = write_files(&session, jobs).unwrap();
        assert_eq!(reports.len(), 3);
        for (j, be) in backends.iter().enumerate() {
            let mut bytes = vec![0u8; be.len().unwrap() as usize];
            be.read_at(0, &mut bytes).unwrap();
            assert_eq!(
                bytes, solo_bytes[j],
                "job {j}: session-shared output diverged from its solo bytes"
            );
            assert_eq!(reports[j].entries, 3 * 400);
        }
        assert_eq!(session.stats().writers_opened, 3);
        assert_eq!(session.stats().in_flight_clusters, 0);
    }

    #[test]
    fn adaptive_sizing_knob_plumbs_through_the_report() {
        use crate::imt::Pool;
        use crate::session::{Session, SessionConfig};
        use crate::tree::sizer::{AdaptiveConfig, ClusterSizing};
        let schema = Schema::flat_f32("x", 2);
        let blocks: Vec<Vec<ColumnData>> = (0..4)
            .map(|blk| {
                (0..2)
                    .map(|b| {
                        ColumnData::F32(
                            (0..2048).map(|i| ((blk * 31 + b * 7 + i) % 53) as f32).collect(),
                        )
                    })
                    .collect()
            })
            .collect();
        let adaptive = AdaptiveConfig {
            min_entries: 64,
            max_entries: 1024,
            hysteresis: 1,
            warmup: 0,
            ..Default::default()
        };
        let cfg = WriterConfig {
            basket_entries: 256,
            compression: Settings::new(Codec::Lz4r, 2),
            flush: FlushMode::Pipelined,
            granularity: FlushGranularity::Block,
            max_inflight_clusters: 2,
            sizing: ClusterSizing::Adaptive(adaptive),
            ..Default::default()
        };
        let pool = Arc::new(Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::for_writers(1, 2));
        let be = Arc::new(MemBackend::new());
        let rep =
            write_blocks_in_session(&session, be.clone(), schema, "t", cfg, blocks).unwrap();
        assert_eq!(rep.entries, 4 * 2048);
        assert!(rep.sizing.clusters > 0, "adaptive writer must record windows");
        assert!(rep.sizing.min_entries >= 64 && rep.sizing.max_entries <= 1024);
        assert!(rep.sizing.last_entries >= 64 && rep.sizing.last_entries <= 1024);
        // Whatever sizes were chosen, the data must decode intact.
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(reader.entries(), 4 * 2048);
        let cols = reader.read_all().unwrap();
        assert_eq!(cols[0].len(), 4 * 2048);
    }

    #[test]
    fn pipelined_write_is_byte_identical_to_serial_write() {
        let schema = Schema::flat_f32("x", 8);
        let blocks: Vec<Vec<ColumnData>> = vec![(0..8)
            .map(|b| ColumnData::F32((0..512).map(|i| ((i * b) % 31) as f32).collect()))
            .collect()];
        let mk = |flush: FlushMode| {
            let be = Arc::new(MemBackend::new());
            let cfg = WriterConfig {
                basket_entries: 128,
                compression: Settings::new(Codec::Rzip, 2),
                flush,
                granularity: FlushGranularity::Block,
                max_inflight_clusters: 2,
                ..Default::default()
            };
            let rep =
                write_blocks(be.clone(), schema.clone(), "t", cfg, blocks.clone()).unwrap();
            let len = be.len().unwrap() as usize;
            let mut bytes = vec![0u8; len];
            be.read_at(0, &mut bytes).unwrap();
            (rep, bytes)
        };
        let (rs, bytes_serial) = mk(FlushMode::Serial);
        crate::imt::enable(4);
        let (rp, bytes_pipelined) = mk(FlushMode::Pipelined);
        let (rb, bytes_parallel) = mk(FlushMode::Parallel);
        crate::imt::disable();
        assert_eq!(bytes_serial, bytes_pipelined, "pipelined file diverged");
        assert_eq!(bytes_serial, bytes_parallel, "parallel file diverged");
        assert_eq!(rs.stored_bytes, rp.stored_bytes);
        assert_eq!(rs.stored_bytes, rb.stored_bytes);
        // serial mode: the producer pays the whole flush, so stall
        // covers serialise + compress by construction
        assert!(rs.stall >= rs.compress_time);
    }
}
