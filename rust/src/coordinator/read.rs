//! Parallel column reading (paper §2.1, Figure 1).
//!
//! Two task decompositions are supported:
//!
//! * **Branch granularity** (ROOT 6.08's first IMT read path): each
//!   selected branch is one task — storage fetch, decompression,
//!   deserialisation. With B branches and T threads the speedup caps
//!   at `min(B, T)`, the paper's quad-core ×3.5 result.
//! * **Basket granularity** (default): every (branch, basket) pair is
//!   its own fetch→decompress→deserialise task, reassembled in entry
//!   order afterwards. Reads now scale as `min(total_baskets, T)`, so
//!   a narrow 4-branch tree keeps 16 threads busy — the decomposition
//!   Bockelman/Zhang/Pivarski identify as where read-path parallelism
//!   actually lives.
//!
//! Scratch buffers on both paths come from [`crate::compress::pool`];
//! tasks run on the work-stealing IMT pool, whose LIFO local queues
//! keep a branch's consecutive baskets on one worker when the system
//! is busy (cache locality) while idle workers steal whole branches.

use std::time::Instant;

use crate::cache::{ClusterStream, PrefetchOptions, PrefetchStats};
use crate::error::{Error, Result};
use crate::imt;
use crate::serial::column::ColumnData;
use crate::session::Session;
use crate::tree::reader::TreeReader;

/// Task decomposition for a parallel column read.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Granularity {
    /// One task per (branch, basket): scales as `min(total_baskets, T)`.
    #[default]
    Basket,
    /// One task per branch: scales as `min(branches, T)` (the ROOT
    /// 6.08 policy, kept as the Figure-1 baseline).
    Branch,
}

/// Column-read options.
#[derive(Clone, Debug, Default)]
pub struct ReadOptions {
    /// Branch indices to read (None = all), e.g. an analysis touching a
    /// subset of columns — ROOT's core columnar-format advantage.
    pub branches: Option<Vec<usize>>,
    /// Force serial even when IMT is on (baseline measurements).
    pub force_serial: bool,
    /// Parallel task decomposition (ignored when serial or when
    /// `prefetch` is set).
    pub granularity: Granularity,
    /// Read through the parallel read-ahead cache ([`crate::cache`]):
    /// coalesced cluster-window fetches, per-basket decode tasks, and
    /// a (fixed or adaptive) prefetch window that hides storage
    /// latency. `None` keeps the direct per-basket paths above;
    /// ignored under `force_serial`. When both `branches` and the
    /// prefetch options carry a selection, `branches` wins; with
    /// `branches: None` the prefetch selection applies (and the
    /// report's accounting follows it).
    pub prefetch: Option<PrefetchOptions>,
}

/// Outcome + accounting of a column read.
#[derive(Debug)]
pub struct ReadReport {
    pub columns: Vec<ColumnData>,
    pub branches_read: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub raw_bytes: u64,
    /// Stored bytes the effective selection covers (equals
    /// `stored_bytes`; kept distinct so projection accounting reads
    /// the same on every path, prefetched or not).
    pub bytes_selected: u64,
    /// Stored bytes of the tree's unselected branches — what a
    /// whole-tree read would have fetched on top of `bytes_selected`
    /// (projection pushdown's saving).
    pub bytes_skipped: u64,
    pub wall: std::time::Duration,
    /// Prefetcher accounting when the read went through the read-ahead
    /// cache (`ReadOptions::prefetch`), `None` otherwise.
    pub prefetch: Option<PrefetchStats>,
}

impl ReadReport {
    /// Effective decompressed-data bandwidth. Degenerate runs —
    /// nothing read, or a wall too short to measure — report 0.0
    /// rather than dividing by zero (the same guard
    /// `WriteReport::throughput_mbps` carries).
    pub fn throughput_mbps(&self) -> f64 {
        if self.raw_bytes == 0 || self.wall.is_zero() {
            return 0.0;
        }
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }
}

/// Basket-granularity read core: flatten the selection into (branch,
/// basket) tasks, decode them all through `run` (some parallel-map
/// flavour), then stitch the results back into per-branch columns in
/// entry order. Shared by the global-IMT path and the explicit-pool
/// baseline so the reassembly invariant lives in exactly one place.
fn read_baskets_with(
    reader: &TreeReader,
    selection: &[usize],
    run: impl FnOnce(
        usize,
        &(dyn Fn(usize) -> Result<ColumnData> + Sync),
    ) -> Vec<Result<ColumnData>>,
) -> Result<Vec<ColumnData>> {
    let meta = reader.meta();
    let mut tasks: Vec<(usize, usize)> = Vec::new();
    for &b in selection {
        for k in 0..meta.branches[b].baskets.len() {
            tasks.push((b, k));
        }
    }
    let task = |i: usize| {
        let (b, k) = tasks[i];
        reader.read_basket(b, k)
    };
    let decoded = run(tasks.len(), &task);
    // Ordered reassembly: tasks were emitted branch-major with baskets
    // ascending, so consuming the results sequentially rebuilds each
    // branch in entry order. A missing result means the pool lost a
    // task — surfaced as a sync error, never a panic mid-reassembly.
    let mut results = decoded.into_iter();
    let mut columns = Vec::with_capacity(selection.len());
    for &b in selection {
        let mut col = ColumnData::new(meta.branches[b].ty);
        for k in 0..meta.branches[b].baskets.len() {
            let part = results.next().ok_or_else(|| {
                Error::Sync(format!(
                    "parallel read reassembly lost the result for basket ({b},{k})"
                ))
            })??;
            col.append(&part)?;
        }
        columns.push(col);
    }
    Ok(columns)
}

/// Basket-granularity parallel read on the global IMT pool (serial
/// when IMT is off).
fn read_baskets_parallel(reader: &TreeReader, selection: &[usize]) -> Result<Vec<ColumnData>> {
    read_baskets_with(reader, selection, |n, f| imt::parallel_map(n, f))
}

/// Basket-granularity parallel read on an explicit pool — the
/// hermetic no-prefetch baseline benchmarks measure against, with the
/// same decomposition and ordered reassembly as [`read_columns`]'s
/// basket path.
pub fn read_baskets_on_pool(
    reader: &TreeReader,
    selection: &[usize],
    pool: &crate::imt::Pool,
) -> Result<Vec<ColumnData>> {
    read_baskets_with(reader, selection, |n, f| pool.parallel_map(n, &f))
}

/// Read the selected columns of `reader`, in parallel when IMT is on.
pub fn read_columns(reader: &TreeReader, opts: &ReadOptions) -> Result<ReadReport> {
    read_columns_with(reader, opts, None)
}

/// As [`read_columns`], but running the prefetch path inside `session`
/// — shared read budget, shared completion domain, and (when the
/// session is traced) pool/budget/prefetch/storage spans for the whole
/// read. The non-prefetch paths are unchanged; pass a
/// `ReadOptions::prefetch` to get the session-scoped behaviour.
pub fn read_columns_in_session(
    reader: &TreeReader,
    opts: &ReadOptions,
    session: &Session,
) -> Result<ReadReport> {
    read_columns_with(reader, opts, Some(session))
}

fn read_columns_with(
    reader: &TreeReader,
    opts: &ReadOptions,
    session: Option<&Session>,
) -> Result<ReadReport> {
    // Effective selection: the outer `branches` wins, else a selection
    // carried inside the prefetch options, else every branch — so the
    // report's accounting always matches what was actually read.
    let selection: Vec<usize> = match (
        &opts.branches,
        opts.prefetch.as_ref().and_then(|p| p.branches.as_ref()),
    ) {
        (Some(v), _) => v.clone(),
        (None, Some(v)) => v.clone(),
        (None, None) => (0..reader.n_branches()).collect(),
    };
    // The serial and per-branch parallel paths below never consult
    // ClusterPlan, so they must enforce its selection invariants
    // themselves: a duplicated branch would be fetched twice and its
    // bytes double-counted into `bytes_selected`, silently breaking
    // the selected+skipped partition. (The prefetch path re-checks in
    // `ClusterPlan::build`; checking here keeps every path agreeing.)
    for (i, &b) in selection.iter().enumerate() {
        if b >= reader.n_branches() {
            return Err(Error::Coordinator(format!(
                "read: branch index {b} out of range ({} branches)",
                reader.n_branches()
            )));
        }
        if selection[..i].contains(&b) {
            return Err(Error::Coordinator(format!(
                "read: branch index {b} selected more than once"
            )));
        }
    }
    let t0 = Instant::now();
    let mut prefetch_stats: Option<PrefetchStats> = None;
    let serial = || -> Result<Vec<ColumnData>> {
        selection.iter().map(|&b| reader.read_branch(b)).collect()
    };
    let columns: Vec<ColumnData> = if opts.force_serial {
        serial()?
    } else if let Some(pf) = &opts.prefetch {
        // Stream through the read-ahead cache: coalesced window
        // fetches + pooled decode tasks (inline while IMT is off, so
        // the coalescing benefit survives either way). A caller-held
        // session scopes the stream's budget and tracing; otherwise
        // the stream opens its own private session.
        let pf_opts = PrefetchOptions {
            branches: Some(selection.clone()),
            ..pf.clone()
        };
        let mut stream = match session {
            Some(s) => ClusterStream::open_in_session(reader, &pf_opts, s)?,
            None => reader.stream(&pf_opts)?,
        };
        let cols = stream.read_all_columns()?;
        prefetch_stats = Some(stream.stats());
        cols
    } else if !imt::is_enabled() {
        serial()?
    } else {
        match opts.granularity {
            Granularity::Basket => read_baskets_parallel(reader, &selection)?,
            Granularity::Branch => {
                imt::parallel_map(selection.len(), |i| reader.read_branch(selection[i]))
                    .into_iter()
                    .collect::<Result<_>>()?
            }
        }
    };
    let wall = t0.elapsed();
    let meta = reader.meta();
    let (mut stored, mut raw) = (0u64, 0u64);
    for &b in &selection {
        stored += meta.branches[b].stored_bytes();
        raw += meta.branches[b].raw_bytes();
    }
    let tree_stored: u64 = meta.branches.iter().map(|br| br.stored_bytes()).sum();
    Ok(ReadReport {
        branches_read: selection.len(),
        entries: reader.entries(),
        stored_bytes: stored,
        raw_bytes: raw,
        bytes_selected: stored,
        bytes_skipped: tree_stored.saturating_sub(stored),
        wall,
        columns,
        prefetch: prefetch_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::schema::Schema;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{FlushMode, TreeWriter, WriterConfig};
    use std::sync::Arc;

    fn build_with_basket(
        n_branches: usize,
        entries: usize,
        basket_entries: usize,
    ) -> Arc<FileReader> {
        let schema = Schema::flat_f32("c", n_branches);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), n_branches);
        let cfg = WriterConfig {
            basket_entries,
            compression: Settings::new(Codec::Rzip, 2),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..entries {
            let row: Vec<Value> =
                (0..n_branches).map(|b| Value::F32(((i * b) % 97) as f32 * 0.5)).collect();
            w.fill(row).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    fn build(n_branches: usize, entries: usize) -> Arc<FileReader> {
        build_with_basket(n_branches, entries, 256)
    }

    #[test]
    fn serial_and_parallel_agree() {
        let file = build(12, 1000);
        let reader = TreeReader::open_first(file).unwrap();
        let serial = read_columns(
            &reader,
            &ReadOptions { force_serial: true, ..Default::default() },
        )
        .unwrap();
        crate::imt::enable(4);
        let parallel = read_columns(&reader, &ReadOptions::default()).unwrap();
        let per_branch = read_columns(
            &reader,
            &ReadOptions { granularity: Granularity::Branch, ..Default::default() },
        )
        .unwrap();
        crate::imt::disable();
        assert_eq!(serial.columns, parallel.columns);
        assert_eq!(serial.columns, per_branch.columns);
        assert_eq!(serial.raw_bytes, parallel.raw_bytes);
        assert_eq!(serial.branches_read, 12);
    }

    /// Basket-granularity reads must byte-match the serial baseline on
    /// uneven shapes: a trailing partial basket, single-basket
    /// branches, one branch total, and the empty tree.
    #[test]
    fn basket_granularity_agrees_on_uneven_shapes() {
        // (branches, entries, basket_entries)
        let shapes = [
            (4, 1000, 256), // last basket partial (1000 = 3*256 + 232)
            (3, 100, 100),  // exactly one basket per branch
            (5, 7, 1000),   // single under-full basket
            (1, 513, 64),   // one branch, many baskets, partial tail
            (2, 0, 128),    // empty tree: no baskets at all
            (6, 256, 1),    // degenerate: one entry per basket
        ];
        for (nb, entries, basket) in shapes {
            let file = build_with_basket(nb, entries, basket);
            let reader = TreeReader::open_first(file).unwrap();
            let serial = read_columns(
                &reader,
                &ReadOptions { force_serial: true, ..Default::default() },
            )
            .unwrap();
            crate::imt::enable(4);
            let parallel = read_columns(&reader, &ReadOptions::default()).unwrap();
            crate::imt::disable();
            assert_eq!(
                serial.columns, parallel.columns,
                "shape ({nb}, {entries}, {basket})"
            );
            assert_eq!(serial.entries, entries as u64);
        }
    }

    /// Regression (ISSUE 5 satellite): a degenerate read — empty tree
    /// or an unmeasurably short wall — must report 0.0 MB/s, never a
    /// division by (near-)zero blowing up to inf/NaN.
    #[test]
    fn throughput_guards_zero_wall_and_zero_bytes() {
        let mk = |raw_bytes: u64, wall: std::time::Duration| ReadReport {
            columns: Vec::new(),
            branches_read: 0,
            entries: 0,
            stored_bytes: 0,
            raw_bytes,
            bytes_selected: 0,
            bytes_skipped: 0,
            wall,
            prefetch: None,
        };
        assert_eq!(mk(0, std::time::Duration::from_millis(5)).throughput_mbps(), 0.0);
        assert_eq!(mk(1_000_000, std::time::Duration::ZERO).throughput_mbps(), 0.0);
        assert_eq!(mk(0, std::time::Duration::ZERO).throughput_mbps(), 0.0);
        let ok = mk(2_000_000, std::time::Duration::from_secs(1)).throughput_mbps();
        assert!((ok - 2.0).abs() < 1e-9, "healthy reads still report, got {ok}");
    }

    /// The prefetch path must decode identically to the serial
    /// baseline and report its cache accounting.
    #[test]
    fn prefetched_read_matches_serial() {
        use crate::cache::WindowPolicy;
        let file = build_with_basket(6, 1500, 128);
        let reader = TreeReader::open_first(file).unwrap();
        let serial = read_columns(
            &reader,
            &ReadOptions { force_serial: true, ..Default::default() },
        )
        .unwrap();
        for window in [WindowPolicy::None, WindowPolicy::Fixed(4), WindowPolicy::default()]
        {
            let rep = read_columns(
                &reader,
                &ReadOptions {
                    prefetch: Some(PrefetchOptions { window, ..Default::default() }),
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(serial.columns, rep.columns, "window {window:?}");
            let pf = rep.prefetch.expect("prefetch stats reported");
            assert_eq!(pf.clusters, 12, "1500 entries / 128 per cluster");
            assert_eq!(pf.baskets, 72);
            assert!(
                pf.device_reads <= pf.baskets / 4,
                "coalescing must collapse per-basket reads: {} reads for {} baskets",
                pf.device_reads,
                pf.baskets
            );
        }
        // Selection order flows through the prefetcher too.
        let sel = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![5, 0, 2]),
                prefetch: Some(PrefetchOptions::default()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(sel.columns[0], serial.columns[5]);
        assert_eq!(sel.columns[1], serial.columns[0]);
        assert_eq!(sel.columns[2], serial.columns[2]);
        assert_eq!(sel.branches_read, 3);
        // A selection carried inside the prefetch options applies when
        // the outer one is absent — and the accounting follows it.
        let inner = read_columns(
            &reader,
            &ReadOptions {
                prefetch: Some(PrefetchOptions {
                    branches: Some(vec![4]),
                    ..Default::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(inner.branches_read, 1);
        assert_eq!(inner.columns.len(), 1);
        assert_eq!(inner.columns[0], serial.columns[4]);
        assert!(inner.stored_bytes < serial.stored_bytes / 3);
    }

    /// Regression (ISSUE 8 satellite): when BOTH `ReadOptions::branches`
    /// and the prefetch options carry a selection, the outer one wins —
    /// columns, branch count, and byte accounting all follow it. The
    /// None-falls-through half lives in `prefetched_read_matches_serial`.
    #[test]
    fn outer_selection_overrides_prefetch_selection() {
        let file = build_with_basket(8, 900, 128);
        let reader = TreeReader::open_first(file).unwrap();
        let serial =
            read_columns(&reader, &ReadOptions { force_serial: true, ..Default::default() })
                .unwrap();
        let rep = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![6, 1]),
                prefetch: Some(PrefetchOptions {
                    branches: Some(vec![0, 2, 3, 4]), // must lose
                    ..Default::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.branches_read, 2, "outer selection must win");
        assert_eq!(rep.columns.len(), 2);
        assert_eq!(rep.columns[0], serial.columns[6]);
        assert_eq!(rep.columns[1], serial.columns[1]);
        let meta = reader.meta();
        let want: u64 =
            [6usize, 1].iter().map(|&b| meta.branches[b].stored_bytes()).sum();
        let total: u64 = meta.branches.iter().map(|b| b.stored_bytes()).sum();
        assert_eq!(rep.stored_bytes, want, "accounting follows the outer selection");
        assert_eq!(rep.bytes_selected, want);
        assert_eq!(rep.bytes_skipped, total - want);
        // The prefetcher itself saw the winning selection too.
        let pf = rep.prefetch.expect("prefetch stats reported");
        assert_eq!(pf.bytes_selected, want);
        assert_eq!(pf.bytes_skipped, total - want);
    }

    /// Projection accounting on the plain (non-prefetch) paths: selected
    /// + skipped always partition the tree's stored bytes.
    #[test]
    fn byte_accounting_partitions_tree_bytes() {
        let file = build(6, 400);
        let reader = TreeReader::open_first(file).unwrap();
        let meta_total: u64 =
            reader.meta().branches.iter().map(|b| b.stored_bytes()).sum();
        let full =
            read_columns(&reader, &ReadOptions { force_serial: true, ..Default::default() })
                .unwrap();
        assert_eq!(full.bytes_selected, meta_total);
        assert_eq!(full.bytes_skipped, 0);
        let part = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![1, 4]),
                force_serial: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(part.bytes_selected, part.stored_bytes);
        assert_eq!(part.bytes_selected + part.bytes_skipped, meta_total);
        assert!(part.bytes_skipped > 0);
    }

    /// Regression (ISSUE 9 satellite): duplicate branch indices in a
    /// selection were never rejected — only out-of-range was checked —
    /// so `bytes_selected` double-counted the duplicated branch and
    /// `bytes_selected + bytes_skipped` overshot the tree's stored
    /// bytes. Every path (serial, parallel, prefetched, and a
    /// duplicate smuggled in via the prefetch options) must error.
    #[test]
    fn duplicate_branch_selection_is_rejected_on_every_path() {
        let file = build(4, 300);
        let reader = TreeReader::open_first(file).unwrap();
        let dup = Some(vec![1usize, 3, 1]);
        let serial = read_columns(
            &reader,
            &ReadOptions { branches: dup.clone(), force_serial: true, ..Default::default() },
        );
        assert!(serial.unwrap_err().to_string().contains("selected more than once"));
        crate::imt::enable(2);
        let parallel =
            read_columns(&reader, &ReadOptions { branches: dup.clone(), ..Default::default() });
        crate::imt::disable();
        assert!(parallel.is_err());
        let prefetched = read_columns(
            &reader,
            &ReadOptions {
                branches: dup,
                prefetch: Some(PrefetchOptions::default()),
                ..Default::default()
            },
        );
        assert!(prefetched.is_err());
        let inner = read_columns(
            &reader,
            &ReadOptions {
                prefetch: Some(PrefetchOptions {
                    branches: Some(vec![0, 0]),
                    ..Default::default()
                }),
                ..Default::default()
            },
        );
        assert!(inner.is_err(), "prefetch-carried selections are validated too");
        // The partition invariant the rejection protects: a valid
        // subset's selected + skipped bytes exactly cover the tree.
        let ok = read_columns(
            &reader,
            &ReadOptions { branches: Some(vec![3, 1]), force_serial: true, ..Default::default() },
        )
        .unwrap();
        let total: u64 =
            reader.meta().branches.iter().map(|b| b.stored_bytes()).sum();
        assert_eq!(ok.bytes_selected + ok.bytes_skipped, total);
    }

    #[test]
    fn column_selection_reads_subset() {
        let file = build(10, 500);
        let reader = TreeReader::open_first(file).unwrap();
        let rep = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![2, 7]),
                force_serial: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.columns.len(), 2);
        assert_eq!(rep.branches_read, 2);
        // reading 2 of 10 branches touches ~1/5 of the bytes
        let full = read_columns(
            &reader,
            &ReadOptions { force_serial: true, ..Default::default() },
        )
        .unwrap();
        assert!(rep.stored_bytes < full.stored_bytes / 3);
    }

    /// Paged v3 files flow through every read path — serial,
    /// basket-granularity parallel, and the prefetching cache with a
    /// projection — and decode identically on each, with the
    /// projection's byte accounting partitioning the tree.
    #[test]
    fn paged_v3_reads_match_across_paths() {
        use crate::tree::writer::Layout;
        let n_branches = 6usize;
        let schema = Schema::flat_f32("c", n_branches);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), n_branches);
        let cfg = WriterConfig {
            basket_entries: 256,
            compression: Settings::new(Codec::Lz4r, 2),
            flush: FlushMode::Serial,
            layout: Layout::Paged { page_entries: 64 },
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..1500usize {
            let row: Vec<Value> =
                (0..n_branches).map(|b| Value::F32(((i * (b + 2)) % 89) as f32 * 0.25)).collect();
            w.fill(row).unwrap();
        }
        let (sink, n, _) = w.close().unwrap();
        let meta = sink.into_meta("t".into(), schema, n).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert!(!reader.meta().clusters.is_empty(), "paged tree records cluster spans");

        let serial = read_columns(
            &reader,
            &ReadOptions { force_serial: true, ..Default::default() },
        )
        .unwrap();
        crate::imt::enable(4);
        let parallel = read_columns(&reader, &ReadOptions::default()).unwrap();
        crate::imt::disable();
        assert_eq!(serial.columns, parallel.columns);

        let prefetched = read_columns(
            &reader,
            &ReadOptions { prefetch: Some(PrefetchOptions::default()), ..Default::default() },
        )
        .unwrap();
        assert_eq!(serial.columns, prefetched.columns);
        assert_eq!(prefetched.bytes_skipped, 0);

        let projected = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![4, 1]),
                prefetch: Some(PrefetchOptions::default()),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(projected.columns[0], serial.columns[4]);
        assert_eq!(projected.columns[1], serial.columns[1]);
        assert_eq!(
            projected.bytes_selected + projected.bytes_skipped,
            serial.bytes_selected,
            "projection accounting partitions the paged tree's bytes"
        );
        assert!(projected.bytes_skipped > 0);
        let pf = projected.prefetch.expect("prefetch stats reported");
        assert_eq!(pf.bytes_selected, projected.bytes_selected);
    }

    /// The explicit-pool baseline shares the coordinator's
    /// decomposition + reassembly: identical output, no global IMT.
    #[test]
    fn explicit_pool_basket_read_matches_serial() {
        let file = build(5, 800);
        let reader = TreeReader::open_first(file).unwrap();
        let serial = read_columns(
            &reader,
            &ReadOptions { force_serial: true, ..Default::default() },
        )
        .unwrap();
        let pool = crate::imt::Pool::new(3);
        let selection: Vec<usize> = (0..5).collect();
        let cols = read_baskets_on_pool(&reader, &selection, &pool).unwrap();
        assert_eq!(cols, serial.columns);
        // subset + reordered selection goes through the same core
        let cols = read_baskets_on_pool(&reader, &[4, 1], &pool).unwrap();
        assert_eq!(cols[0], serial.columns[4]);
        assert_eq!(cols[1], serial.columns[1]);
    }

    #[test]
    fn basket_selection_subset_parallel() {
        let file = build(10, 500);
        let reader = TreeReader::open_first(file).unwrap();
        crate::imt::enable(3);
        let rep = read_columns(
            &reader,
            &ReadOptions { branches: Some(vec![7, 2]), ..Default::default() },
        )
        .unwrap();
        crate::imt::disable();
        let serial = read_columns(
            &reader,
            &ReadOptions {
                branches: Some(vec![7, 2]),
                force_serial: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.columns, serial.columns);
    }
}
