//! Parallel column reading (paper §2.1, Figure 1).
//!
//! Each selected branch is read — storage fetch, decompression,
//! deserialisation — as one task on the IMT pool. With B branches and
//! T threads the expected speedup is `min(B, T)` until decompression
//! saturates the cores, which is the paper's quad-core ×3.5 result.

use std::time::Instant;

use crate::error::Result;
use crate::imt;
use crate::serial::column::ColumnData;
use crate::tree::reader::TreeReader;

/// Column-read options.
#[derive(Clone, Debug, Default)]
pub struct ReadOptions {
    /// Branch indices to read (None = all), e.g. an analysis touching a
    /// subset of columns — ROOT's core columnar-format advantage.
    pub branches: Option<Vec<usize>>,
    /// Force serial even when IMT is on (baseline measurements).
    pub force_serial: bool,
}

/// Outcome + accounting of a column read.
#[derive(Debug)]
pub struct ReadReport {
    pub columns: Vec<ColumnData>,
    pub branches_read: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub raw_bytes: u64,
    pub wall: std::time::Duration,
}

impl ReadReport {
    /// Effective decompressed-data bandwidth.
    pub fn throughput_mbps(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.wall.as_secs_f64()
    }
}

/// Read the selected columns of `reader`, in parallel when IMT is on.
pub fn read_columns(reader: &TreeReader, opts: &ReadOptions) -> Result<ReadReport> {
    let selection: Vec<usize> = match &opts.branches {
        Some(v) => v.clone(),
        None => (0..reader.n_branches()).collect(),
    };
    let t0 = Instant::now();
    let columns: Vec<ColumnData> = if opts.force_serial || !imt::is_enabled() {
        selection.iter().map(|&b| reader.read_branch(b)).collect::<Result<_>>()?
    } else {
        imt::parallel_map(selection.len(), |i| reader.read_branch(selection[i]))
            .into_iter()
            .collect::<Result<_>>()?
    };
    let wall = t0.elapsed();
    let meta = reader.meta();
    let (mut stored, mut raw) = (0u64, 0u64);
    for &b in &selection {
        stored += meta.branches[b].stored_bytes();
        raw += meta.branches[b].raw_bytes();
    }
    Ok(ReadReport {
        branches_read: selection.len(),
        entries: reader.entries(),
        stored_bytes: stored,
        raw_bytes: raw,
        wall,
        columns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::format::reader::FileReader;
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::serial::schema::Schema;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{TreeWriter, WriterConfig};
    use std::sync::Arc;

    fn build(n_branches: usize, entries: usize) -> Arc<FileReader> {
        let schema = Schema::flat_f32("c", n_branches);
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone()).unwrap());
        let sink = FileSink::new(fw.clone(), n_branches);
        let cfg = WriterConfig {
            basket_entries: 256,
            compression: Settings::new(Codec::Rzip, 2),
            parallel_flush: false,
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in 0..entries {
            let row: Vec<Value> =
                (0..n_branches).map(|b| Value::F32(((i * b) % 97) as f32 * 0.5)).collect();
            w.fill(row).unwrap();
        }
        let (sink, n) = w.close().unwrap();
        fw.finish(&Directory { trees: vec![sink.into_meta("t".into(), schema, n)] }).unwrap();
        Arc::new(FileReader::open(be).unwrap())
    }

    #[test]
    fn serial_and_parallel_agree() {
        let file = build(12, 1000);
        let reader = TreeReader::open_first(file).unwrap();
        let serial = read_columns(
            &reader,
            &ReadOptions { branches: None, force_serial: true },
        )
        .unwrap();
        crate::imt::enable(4);
        let parallel = read_columns(&reader, &ReadOptions::default()).unwrap();
        crate::imt::disable();
        assert_eq!(serial.columns, parallel.columns);
        assert_eq!(serial.raw_bytes, parallel.raw_bytes);
        assert_eq!(serial.branches_read, 12);
    }

    #[test]
    fn column_selection_reads_subset() {
        let file = build(10, 500);
        let reader = TreeReader::open_first(file).unwrap();
        let rep = read_columns(
            &reader,
            &ReadOptions { branches: Some(vec![2, 7]), force_serial: true },
        )
        .unwrap();
        assert_eq!(rep.columns.len(), 2);
        assert_eq!(rep.branches_read, 2);
        // reading 2 of 10 branches touches ~1/5 of the bytes
        let full =
            read_columns(&reader, &ReadOptions { branches: None, force_serial: true }).unwrap();
        assert!(rep.stored_bytes < full.stored_bytes / 3);
    }
}
