//! Shared plumbing for the experiment harnesses: table rendering, CSV
//! output, and synthetic dataset construction.

use std::sync::Arc;

use crate::cache::PrefetchOptions;
use crate::compress::{Codec, Settings};
use crate::coordinator::write::{write_blocks, WriteReport};
use crate::error::Result;
use crate::format::reader::FileReader;
use crate::framework::dataset::{self, DatasetKind, SplitMix};
use crate::metrics::{Recorder, Snapshot};
use crate::runtime::Engine;
use crate::serial::column::ColumnData;
use crate::session::{Session, SessionConfig};
use crate::storage::mem::MemBackend;
use crate::storage::BackendRef;
use crate::tree::reader::TreeReader;
use crate::tree::writer::{FlushMode, WriterConfig};

/// Simple fixed-width table printer (markdown-flavoured).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:>w$} |"));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    /// CSV twin of the table.
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write the CSV beside the repo (results/<name>.csv), best-effort.
pub fn save_csv(name: &str, table: &Table) {
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), table.to_csv());
    }
}

/// One machine-readable benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Free-form case label (dataset, mode, ...). Must not contain `"`.
    pub label: String,
    pub threads: usize,
    pub wall_ms: f64,
    pub mbps: f64,
}

/// Emit `BENCH_<name>.json` in the working directory so CI can track
/// the perf trajectory across PRs (hand-rolled JSON: no serde in this
/// offline environment). Best-effort, like [`save_csv`].
pub fn save_bench_json(name: &str, rows: &[BenchRow]) {
    let mut s = String::with_capacity(64 + rows.len() * 96);
    s.push_str("{\"bench\":\"");
    s.push_str(name);
    s.push_str("\",\"rows\":[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"label\":\"{}\",\"threads\":{},\"wall_ms\":{:.3},\"MBps\":{:.3}}}",
            r.label, r.threads, r.wall_ms, r.mbps
        ));
    }
    s.push_str("]}\n");
    let _ = std::fs::write(format!("BENCH_{name}.json"), s);
}

/// Emit `TRACE_<name>.json` — a Chrome trace-event (Perfetto-loadable)
/// dump of everything `recorder` collected. Best-effort, like
/// [`save_csv`]; a disabled recorder writes nothing.
pub fn save_trace_json(name: &str, recorder: &Recorder) {
    if recorder.is_enabled() {
        let _ = std::fs::write(format!("TRACE_{name}.json"), recorder.to_chrome_json());
    }
}

/// Emit `STATS_<name>.json` — one metrics-registry snapshot.
/// Best-effort, like [`save_csv`].
pub fn save_stats_json(name: &str, snap: &Snapshot) {
    let _ = std::fs::write(format!("STATS_{name}.json"), snap.to_json());
}

/// Observability epilogue every experiment runs after its measured
/// cells: stream `file` (the experiment's own data when it is still in
/// scope, else a small synthesized stand-in) through a **traced**
/// 4-worker session and emit `TRACE_<name>.json` + `STATS_<name>.json`
/// beside `BENCH_<name>.json`. The epilogue is a separate run so the
/// measured numbers are never perturbed by tracing; it is best-effort,
/// so observability can never fail a benchmark.
pub fn save_observability(name: &str, file: Option<BackendRef>) {
    let run = || -> Result<()> {
        let be = match file {
            Some(b) => b,
            None => {
                synthesize_flat_f32(4, 8_192, 512, Settings::new(Codec::Lz4r, 2))?
            }
        };
        let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
        let pool = Arc::new(crate::imt::Pool::new(4));
        let session =
            Session::with_pool(pool, SessionConfig::default().traced());
        let mut stream =
            reader.stream_in_session(&PrefetchOptions::fixed(4), &session)?;
        stream.read_all_columns()?;
        let mut snap = session.metrics().snapshot();
        snap.put_prefetch("prefetch", &stream.stats());
        snap.put_session(&session.stats());
        snap.put_pool(&crate::compress::pool::stats());
        save_stats_json(name, &snap);
        save_trace_json(name, session.recorder());
        session.recorder().check()
    };
    let _ = run();
}

/// Build an in-memory flat-f32 file with exactly `n_branches` branches
/// — the narrow-tree shape where basket granularity beats branch
/// granularity (B < T).
pub fn synthesize_flat_f32(
    n_branches: usize,
    entries: usize,
    basket_entries: usize,
    compression: Settings,
) -> Result<BackendRef> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let schema = crate::serial::schema::Schema::flat_f32("n", n_branches);
    let mut rng = SplitMix::new(42);
    let block: Vec<ColumnData> = (0..n_branches)
        .map(|b| {
            ColumnData::F32(
                (0..entries).map(|i| rng.uniform() * (b + 1) as f32 + (i % 13) as f32).collect(),
            )
        })
        .collect();
    let cfg = WriterConfig {
        basket_entries,
        compression,
        flush: FlushMode::Serial,
        ..Default::default()
    };
    write_blocks(be.clone(), schema, "events", cfg, vec![block])?;
    Ok(be)
}

/// Try to load the PJRT engine; fall back to None (pure-rust event
/// synthesis) when artifacts are not built.
pub fn try_engine() -> Option<Engine> {
    match Engine::load_default() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("note: PJRT engine unavailable ({e}); using rust fallback generator");
            None
        }
    }
}

/// Build an in-memory dataset file of `kind` with `entries` rows.
pub fn synthesize_dataset(
    kind: DatasetKind,
    entries: usize,
    basket_entries: usize,
    compression: Settings,
    engine: Option<&Engine>,
) -> Result<(BackendRef, WriteReport)> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let block_size = engine.map(|e| e.meta().blocks[0]).unwrap_or(4096);
    let mut blocks: Vec<Vec<ColumnData>> = Vec::new();
    let mut produced = 0usize;
    let mut idx = 0u32;
    while produced < entries {
        let cols = match engine {
            Some(e) => dataset::engine_block(e, kind, idx + 1, 0, block_size)?,
            None => {
                let mut rng = SplitMix::new(idx as u64 + 1);
                dataset::fallback_block(&mut rng, kind, block_size)
            }
        };
        produced += block_size;
        idx += 1;
        blocks.push(cols);
    }
    let cfg = WriterConfig {
        basket_entries,
        compression,
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let report = write_blocks(be.clone(), kind.schema(), "events", cfg, blocks)?;
    Ok((be, report))
}

/// Build an in-memory *physics* file: exactly the engine's 8 analysis
/// columns, cluster size = an engine block size (so the Fig 2 pipeline
/// can feed PJRT directly).
pub fn synthesize_physics_file(
    entries: usize,
    compression: Settings,
    engine: Option<&Engine>,
) -> Result<(BackendRef, WriteReport)> {
    let be: BackendRef = Arc::new(MemBackend::new());
    let block_size = engine.map(|e| e.meta().blocks[0]).unwrap_or(4096);
    let schema = crate::serial::schema::Schema::flat_f32("p", 8);
    let mut blocks = Vec::new();
    let mut produced = 0usize;
    let mut idx = 0u32;
    while produced < entries {
        let cols: Vec<ColumnData> = match engine {
            Some(e) => {
                let ev = e.generate(idx + 1, 0, block_size)?;
                ev.columns().into_iter().map(ColumnData::F32).collect()
            }
            None => {
                let mut rng = SplitMix::new(idx as u64 + 1);
                let ev = rng.event_block(block_size, 8);
                ev.columns().into_iter().map(ColumnData::F32).collect()
            }
        };
        produced += block_size;
        idx += 1;
        blocks.push(cols);
    }
    let cfg = WriterConfig {
        basket_entries: block_size,
        compression,
        flush: FlushMode::Serial,
        ..Default::default()
    };
    let report = write_blocks(be.clone(), schema, "events", cfg, blocks)?;
    Ok((be, report))
}

pub fn fmt_mbps(v: f64) -> String {
    format!("{v:.1}")
}

pub fn fmt_speedup(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::format::reader::FileReader;
    use crate::tree::reader::TreeReader;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "speedup"]);
        t.row(vec!["1".into(), "3.50x".into()]);
        let s = t.render();
        assert!(s.contains("3.50x |"), "rendered:\n{s}");
        assert!(t.to_csv().starts_with("a,speedup\n1,3.50x\n"));
    }

    #[test]
    fn synthesize_dataset_fallback() {
        let (be, rep) = synthesize_dataset(
            DatasetKind::Aod,
            8192,
            4096,
            Settings::new(Codec::Lz4r, 3),
            None,
        )
        .unwrap();
        assert_eq!(rep.entries, 8192);
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(r.n_branches(), 12);
        assert_eq!(r.entries(), 8192);
    }

    #[test]
    fn synthesize_physics_fallback() {
        let (be, rep) = synthesize_physics_file(8192, Settings::uncompressed(), None).unwrap();
        assert_eq!(rep.entries, 8192);
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        assert_eq!(r.n_branches(), 8);
        // clusters aligned at 4096
        let cuts = crate::coordinator::baskets::clusters(&r).unwrap();
        assert_eq!(cuts.len(), 2);
    }
}
