//! Experiment harnesses: one function per paper table/figure.
//!
//! Each harness regenerates the corresponding figure's data — the same
//! workload structure, sweep axes and baselines — and returns a
//! rendered table (also saved as `results/<name>.csv`).
//!
//! **Methodology on this host.** The paper sweeps thread counts on
//! multi-core machines; this reproduction host has a single CPU core
//! (see DESIGN.md §4 and `simsched`). Every harness therefore:
//!
//! 1. runs the *real* pipeline serially (real codecs, real serialiser,
//!    real PJRT graphs, real data) and measures per-task costs;
//! 2. replays the coordinator's exact task graph through the
//!    [`crate::simsched`] discrete-event scheduler to obtain the
//!    multi-worker scaling the paper plots;
//! 3. reports the measured serial wall time alongside the simulated
//!    sweep, so on a real multi-core host the two columns can be
//!    cross-checked (the real thread pool implements the same FIFO
//!    list-scheduling policy the simulator models).
//!
//! The bench binaries (`rust/benches/`) and `rootio bench` CLI are thin
//! wrappers over these functions.

pub mod util;

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{PrefetchOptions, PrefetchStats, WindowController, WindowPolicy};
use crate::compress::select::{CodecSelection, SelectConfig};
use crate::compress::{self, Codec, Settings};
use crate::coordinator::baskets;
use crate::coordinator::write::write_blocks;
use crate::error::{Error, Result};
use crate::format::reader::FileReader;
use crate::framework::dataset::{self, DatasetKind};
use crate::hadd::{hadd, HaddOptions};
use crate::imt;
use crate::metrics::SpanKind;
use crate::serial::column::ColumnData;
use crate::serial::schema::{ColumnType, Field, Schema};
use crate::storage::mem::MemBackend;
use crate::session::{Session, SessionConfig};
use crate::simsched::{simulate, Graph};
use crate::storage::remote::{RemoteConfig, RemoteDevice};
use crate::storage::resilient::{HedgePolicy, ResilientBackend, ResilientConfig, RetryPolicy};
use crate::storage::sim::{DeviceModel, SimDevice};
use crate::storage::BackendRef;
use crate::tree::reader::TreeReader;
use crate::tree::sizer::{AdaptiveConfig, ClusterSizer, ClusterSizing};
use crate::tree::writer::{FlushGranularity, FlushMode, WriterConfig};

use util::{
    save_bench_json, save_csv, save_observability, synthesize_dataset, synthesize_flat_f32,
    synthesize_physics_file, try_engine, BenchRow, Table,
};

fn thread_sweep(quick: bool) -> Vec<usize> {
    if quick {
        vec![1, 2, 4]
    } else {
        vec![1, 2, 4, 8]
    }
}

fn measure<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

fn ms(d: Duration) -> String {
    format!("{:.1}", d.as_secs_f64() * 1e3)
}

/// Figure 1 — parallel reading of multiple data columns.
///
/// CMS GenSim-like (70 columns), ATLAS xAOD-like (200 columns) and a
/// narrow 4-branch tree. Per-*basket* fetch+decompress+deserialise
/// costs are measured for real, then two task fan-outs are scheduled
/// on 1..8 workers: one task per branch (the ROOT 6.08 IMT policy,
/// speedup capped at `min(B, T)`) and one task per basket (this PR's
/// pipeline, scaling as `min(total_baskets, T)`). The narrow tree is
/// where the gap shows: 4 branches on 8 threads leave half the cores
/// idle at branch granularity.
pub fn fig1(quick: bool) -> Result<String> {
    let engine = try_engine();
    let entries = if quick { 32_768 } else { 131_072 };
    let mut table = Table::new(&[
        "dataset", "columns", "granularity", "threads", "wall_ms", "read_MBps", "speedup",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();

    // (name, backend, entry count)
    let mut cases: Vec<(String, crate::storage::BackendRef, usize)> = Vec::new();
    for kind in [DatasetKind::GenSim, DatasetKind::Xaod] {
        let entries = if kind == DatasetKind::Xaod { entries / 2 } else { entries };
        let (be, _) = synthesize_dataset(
            kind,
            entries,
            4096,
            Settings::new(Codec::Rzip, 4),
            engine.as_ref(),
        )?;
        cases.push((kind.name().to_string(), be, entries));
    }
    // The narrow tree: B=4 < T, the acceptance case for basket
    // decomposition (4096-entry baskets -> entries/4096 per branch).
    let narrow_entries = entries / 2;
    cases.push((
        "narrow4".to_string(),
        synthesize_flat_f32(4, narrow_entries, 4096, Settings::new(Codec::Rzip, 4))?,
        narrow_entries,
    ));

    for (name, be, entries) in cases {
        let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
        let raw_bytes: u64 = reader.meta().branches.iter().map(|b| b.raw_bytes()).sum();

        // calibrate: real per-basket read cost, aggregated per branch
        let mut branch_graph = Graph::new();
        let mut basket_graph = Graph::new();
        let mut serial_wall = Duration::ZERO;
        for b in 0..reader.n_branches() {
            let mut branch_cost = Duration::ZERO;
            let mut read = 0usize;
            for k in 0..reader.meta().branches[b].baskets.len() {
                let (col, cost) = measure(|| reader.read_basket(b, k).unwrap());
                read += col.len();
                basket_graph.pool(SpanKind::Decompress, cost, vec![]);
                branch_cost += cost;
            }
            assert_eq!(read, entries);
            branch_graph.pool(SpanKind::Decompress, branch_cost, vec![]);
            serial_wall += branch_cost;
        }

        let t1 = simulate(&branch_graph, 1).makespan;
        for (gran, graph) in [("branch", &branch_graph), ("basket", &basket_graph)] {
            for &t in &thread_sweep(quick) {
                let r = simulate(graph, t);
                let label = if t == 1 && gran == "branch" {
                    format!("{t} (measured serial: {} ms)", ms(serial_wall))
                } else {
                    t.to_string()
                };
                let mbps = raw_bytes as f64 / 1e6 / r.makespan.as_secs_f64();
                table.row(vec![
                    name.clone(),
                    reader.n_branches().to_string(),
                    gran.into(),
                    label,
                    ms(r.makespan),
                    format!("{mbps:.1}"),
                    format!("{:.2}x", t1.as_secs_f64() / r.makespan.as_secs_f64()),
                ]);
                bench_rows.push(BenchRow {
                    label: format!("{name}/{gran}"),
                    threads: t,
                    wall_ms: r.makespan.as_secs_f64() * 1e3,
                    mbps,
                });
            }
        }
    }
    save_csv("fig1_parallel_read", &table);
    save_bench_json("fig1", &bench_rows);
    save_observability("fig1", None);
    Ok(format!(
        "## Figure 1 — parallel column reading (branch vs basket granularity)\n\
         (simulated workers, calibrated from measured per-basket costs; \
         see DESIGN.md §4)\n\n{}",
        table.render()
    ))
}

/// Figure 2 — parallel basket decompression, with and without
/// interleaved processing of decompressed data (PJRT analysis).
///
/// Per-(cluster, branch) basket decode costs and per-cluster analysis
/// costs are measured for real. Matching the split-cluster pipeline in
/// [`crate::coordinator::baskets`], every branch basket is its own
/// pool task; a cluster's analysis task depends on all of its branch
/// baskets and runs on the single PJRT service unit (which is how the
/// runtime works), so processing overlaps decompression exactly as in
/// ROOT 6.14.
pub fn fig2(quick: bool) -> Result<String> {
    let engine = try_engine();
    let entries = if quick { 65_536 } else { 262_144 };
    let (be, _) =
        synthesize_physics_file(entries, Settings::new(Codec::Rzip, 4), engine.as_ref())?;
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
    let cuts = baskets::clusters(&reader)?;
    let raw_bytes: u64 = reader.meta().branches.iter().map(|b| b.raw_bytes()).sum();

    // calibrate: per-(cluster, branch) decode cost + per-cluster
    // analyze cost
    let mut decode_costs: Vec<Vec<Duration>> = Vec::with_capacity(cuts.len());
    let mut analyze_costs = Vec::with_capacity(cuts.len());
    for &(_, n_entries, k) in &cuts {
        let mut branch_costs = Vec::with_capacity(reader.n_branches());
        let mut cols = Vec::with_capacity(reader.n_branches());
        for b in 0..reader.n_branches() {
            let (col, cost) = measure(|| reader.read_basket(b, k).unwrap());
            branch_costs.push(cost);
            cols.push(col);
        }
        decode_costs.push(branch_costs);
        if let Some(e) = engine.as_ref() {
            let n = n_entries as usize;
            let ncols = e.meta().ncols;
            let mut flat = vec![0f32; n * ncols];
            for (c, col) in cols.iter().take(ncols).enumerate() {
                let v = col.as_f32().unwrap();
                for i in 0..n {
                    flat[i * ncols + c] = v[i];
                }
            }
            let (_, a_cost) = measure(|| e.analyze(flat, n).unwrap());
            analyze_costs.push(a_cost);
        }
    }

    let mut table = Table::new(&[
        "mode", "threads", "wall_ms", "decomp_MBps", "speedup",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    for (mode, with_processing) in
        [("decompress", false), ("decompress+process", !analyze_costs.is_empty())]
    {
        let mut graph = Graph::new();
        for (i, branch_costs) in decode_costs.iter().enumerate() {
            let mut basket_tasks = Vec::with_capacity(branch_costs.len());
            for &d in branch_costs {
                basket_tasks.push(graph.pool(SpanKind::Decompress, d, vec![]));
            }
            if with_processing {
                graph.named("pjrt", SpanKind::Process, analyze_costs[i], basket_tasks);
            }
        }
        // Baseline = pre-6.14 ROOT: decompress, then process, all on one
        // thread with no overlap — i.e. the plain serial sum.
        let t1 = decode_costs.iter().flatten().sum::<Duration>()
            + if with_processing { analyze_costs.iter().sum() } else { Duration::ZERO };
        for &t in &thread_sweep(quick) {
            let r = simulate(&graph, t);
            let mbps = raw_bytes as f64 / 1e6 / r.makespan.as_secs_f64();
            table.row(vec![
                mode.into(),
                t.to_string(),
                ms(r.makespan),
                format!("{mbps:.1}"),
                format!("{:.2}x", t1.as_secs_f64() / r.makespan.as_secs_f64()),
            ]);
            bench_rows.push(BenchRow {
                label: mode.to_string(),
                threads: t,
                wall_ms: r.makespan.as_secs_f64() * 1e3,
                mbps,
            });
        }
    }
    save_csv("fig2_basket_decompression", &table);
    save_bench_json("fig2", &bench_rows);
    save_observability("fig2", None);
    Ok(format!(
        "## Figure 2 — parallel basket decompression (+ interleaved processing)\n\
         (simulated workers, calibrated per-basket costs; analysis runs on the \
         PJRT service unit)\n\n{}",
        table.render()
    ))
}

/// Figure 3 — framework write throughput vs streams: RECO and AOD,
/// IMT off (single-threaded output module) vs IMT on (TBufferMerger +
/// per-branch parallel compression) vs the no-output ceiling.
pub fn fig3(quick: bool) -> Result<String> {
    let engine = try_engine();
    let block = engine.as_ref().map(|e| e.meta().blocks[0]).unwrap_or(4096);
    let blocks_per_stream = if quick { 2 } else { 4 };
    let streams_sweep: Vec<usize> =
        if quick { vec![1, 2, 4, 8] } else { vec![1, 2, 4, 8, 16, 24, 32] };

    let mut table = Table::new(&[
        "dataset", "mode", "streams", "events_per_s", "ingest_MBps",
    ]);
    for kind in [DatasetKind::Reco, DatasetKind::Aod] {
        // calibrate on one block: generate cost, per-event processing
        // cost (CMSSW streams reconstruct before writing — we use the
        // real PJRT analysis graph as the stand-in), per-branch
        // ser+comp cost, and output-append cost
        let (cols, gen_cost) = measure(|| {
            match engine.as_ref() {
                Some(e) => dataset::engine_block(e, kind, 1, 0, block).unwrap(),
                None => {
                    let mut rng = dataset::SplitMix::new(1);
                    dataset::fallback_block(&mut rng, kind, block)
                }
            }
        });
        let process_cost = match engine.as_ref() {
            Some(e) => {
                let ev = e.generate(1, 0, block)?;
                // reconstruction is heavier than one analysis pass; CMS
                // reco is O(10-100)x — use 4x as a conservative stand-in
                let (_, c) = measure(|| e.analyze_block(&ev).unwrap());
                c * 4
            }
            None => gen_cost * 4,
        };
        let settings = Settings::new(Codec::Rzip, 2);
        let mut branch_costs = Vec::with_capacity(cols.len());
        let mut stored_per_block = 0u64;
        for col in &cols {
            let (payload, cost) = measure(|| {
                let raw = col.encode();
                compress::compress(settings, &raw)
            });
            stored_per_block += payload.len() as u64;
            branch_costs.push(cost);
        }
        let ser_comp_total: Duration = branch_costs.iter().sum();
        // output append: memory-bandwidth copy of the stored bytes
        let append_cost = Duration::from_secs_f64(stored_per_block as f64 / 8e9);
        let raw_per_block = (kind.n_branches() * block * 4) as u64;

        for (mode_name, mode) in [("no-output", 0), ("imt-off", 1), ("imt-on", 2)] {
            for &streams in &streams_sweep {
                let mut graph = Graph::new();
                for s in 0..streams {
                    let stream_unit = format!("stream-{s}");
                    let mut prev: Option<usize> = None;
                    for _ in 0..blocks_per_stream {
                        let deps = prev.map(|p| vec![p]).unwrap_or_default();
                        let g =
                            graph.named(&stream_unit, SpanKind::Generate, gen_cost, deps);
                        // per-block event processing on the stream thread
                        let g = graph.named(
                            &stream_unit,
                            SpanKind::Process,
                            process_cost,
                            vec![g],
                        );
                        prev = Some(g);
                        match mode {
                            0 => {}
                            1 => {
                                // single output thread serialises+compresses+writes
                                let o = graph.named(
                                    "output",
                                    SpanKind::Compress,
                                    ser_comp_total + append_cost,
                                    vec![g],
                                );
                                // stream hands off and continues; no dep back
                                let _ = o;
                            }
                            _ => {
                                // IMT on: per-branch compression on the pool
                                // (paper: 0.5 extra threads per stream), then
                                // the merger output thread appends bytes
                                let mut branch_tasks = Vec::with_capacity(branch_costs.len());
                                for &c in &branch_costs {
                                    branch_tasks.push(graph.pool(
                                        SpanKind::Compress,
                                        c,
                                        vec![g],
                                    ));
                                }
                                graph.named(
                                    "output",
                                    SpanKind::Merge,
                                    append_cost,
                                    branch_tasks,
                                );
                            }
                        }
                    }
                }
                let pool_workers = ((streams + 1) / 2).max(1);
                let r = simulate(&graph, pool_workers);
                let events = (streams * blocks_per_stream * block) as f64;
                let secs = r.makespan.as_secs_f64();
                table.row(vec![
                    kind.name().into(),
                    mode_name.into(),
                    streams.to_string(),
                    format!("{:.0}", events / secs),
                    format!("{:.1}", events / block as f64 * raw_per_block as f64 / 1e6 / secs),
                ]);
            }
        }
    }
    save_csv("fig3_parallel_write", &table);
    Ok(format!(
        "## Figure 3 — parallel column writing (framework streams)\n\
         (simulated streams, calibrated generate/compress/append costs)\n\n{}",
        table.render()
    ))
}

/// Write scaling — the §3.1 mirror of Figure 1: synchronous vs
/// pipelined flush, branch vs block task granularity.
///
/// Per-basket (and, for the fat-basket case, per-`MAX_BLOCK`-chunk)
/// serialise+compress costs are measured for real; the worker sweep is
/// scheduled through [`crate::simsched`] exactly like fig1. Two extra
/// "measured" rows run the real writer (sync = [`FlushMode::Parallel`],
/// pipelined = [`FlushMode::Pipelined`]) at host parallelism and
/// report producer stall vs total compress time from the write report
/// — stall strictly below compress time is the §3.1 claim that the
/// producer no longer waits out the compression.
/// Emits `BENCH_fig3.json` for the CI perf trajectory.
pub fn write_scaling(quick: bool) -> Result<String> {
    let entries = if quick { 16_384 } else { 65_536 };
    let basket = 2048usize;
    let n_branches = 4usize;
    let settings = Settings::new(Codec::Rzip, 4);
    let n_clusters = entries / basket;

    let gen_cluster = move |c: usize| -> Vec<ColumnData> {
        let mut rng = dataset::SplitMix::new(c as u64 + 1);
        (0..n_branches)
            .map(|b| {
                ColumnData::F32(
                    (0..basket)
                        .map(|i| rng.uniform() * (b + 1) as f32 + (i % 17) as f32)
                        .collect(),
                )
            })
            .collect()
    };

    // Calibrate: real per-basket serialise+compress costs plus the
    // production (generation) cost the producer pays between flushes.
    let (_, gen_cost) = measure(|| gen_cluster(0));
    let mut costs: Vec<Vec<Duration>> = Vec::with_capacity(n_clusters);
    let mut raw_bytes = 0u64;
    for c in 0..n_clusters {
        let cols = gen_cluster(c);
        let mut per_branch = Vec::with_capacity(n_branches);
        for col in &cols {
            raw_bytes += col.byte_len() as u64;
            let (_, cost) = measure(|| {
                let raw = col.encode();
                compress::compress(settings, &raw)
            });
            per_branch.push(cost);
        }
        costs.push(per_branch);
    }

    let mut table = Table::new(&[
        "case", "mode", "granularity", "threads", "wall_ms", "speedup", "stall_ms",
        "compress_ms",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();

    // Simulated sweep on the narrow tree. sync = fill blocks for the
    // whole flush, so cluster c+1's production waits on all of cluster
    // c's baskets; pipelined = baskets wait only on their own
    // production (the producer is a dedicated unit, as in the real
    // writer where the filling thread is separate from the pool).
    let mut graphs: Vec<(&str, Graph)> = Vec::new();
    for (mode, sync) in [("sync", true), ("pipelined", false)] {
        let mut g = Graph::new();
        let mut prev_cluster: Vec<usize> = Vec::new();
        let mut prev_gen: Option<usize> = None;
        for per_branch in &costs {
            let mut deps: Vec<usize> = prev_gen.into_iter().collect();
            if sync {
                deps.extend(prev_cluster.iter().copied());
            }
            let p = g.named("producer", SpanKind::Generate, gen_cost, deps);
            prev_gen = Some(p);
            let mut cur = Vec::with_capacity(per_branch.len());
            for &c in per_branch {
                cur.push(g.pool(SpanKind::Compress, c, vec![p]));
            }
            prev_cluster = cur;
        }
        graphs.push((mode, g));
    }
    for (mode, graph) in &graphs {
        let t1 = simulate(graph, 1).makespan;
        for &t in &thread_sweep(quick) {
            let r = simulate(graph, t);
            let mbps = raw_bytes as f64 / 1e6 / r.makespan.as_secs_f64();
            table.row(vec![
                "narrow4".into(),
                (*mode).into(),
                "block".into(),
                t.to_string(),
                ms(r.makespan),
                format!("{:.2}x", t1.as_secs_f64() / r.makespan.as_secs_f64()),
                "-".into(),
                "-".into(),
            ]);
            bench_rows.push(BenchRow {
                label: format!("narrow4/{mode}"),
                threads: t,
                wall_ms: r.makespan.as_secs_f64() * 1e3,
                mbps,
            });
        }
    }

    // Fat-basket case: a single branch whose raw payload spans several
    // compress blocks. Branch granularity = one task per basket; block
    // granularity = one task per MAX_BLOCK chunk (each chunk's real
    // compression cost measured separately).
    let fat_raw_len = if quick {
        compress::MAX_BLOCK + compress::MAX_BLOCK / 2
    } else {
        2 * compress::MAX_BLOCK
    };
    let fat_settings = Settings::new(Codec::Lz4r, 1);
    let fat_raw: Vec<u8> = {
        let mut rng = dataset::SplitMix::new(99);
        (0..fat_raw_len)
            .map(|i| {
                if i % 4 == 0 {
                    (rng.next_u32() >> 24) as u8
                } else {
                    (i % 197) as u8
                }
            })
            .collect()
    };
    let chunk_costs: Vec<Duration> = compress::block_ranges(fat_raw.len())
        .into_iter()
        .map(|r| measure(|| compress::compress(fat_settings, &fat_raw[r])).1)
        .collect();
    let branch_cost: Duration = chunk_costs.iter().sum();
    let fat_baskets = 4usize;
    for (gran, per_task) in [("branch", vec![branch_cost]), ("block", chunk_costs)] {
        let mut g = Graph::new();
        for _ in 0..fat_baskets {
            for &c in &per_task {
                g.pool(SpanKind::Compress, c, vec![]);
            }
        }
        let t1 = simulate(&g, 1).makespan;
        for &t in &thread_sweep(quick) {
            let r = simulate(&g, t);
            let mbps =
                (fat_baskets * fat_raw.len()) as f64 / 1e6 / r.makespan.as_secs_f64();
            table.row(vec![
                "fat1".into(),
                "pipelined".into(),
                gran.into(),
                t.to_string(),
                ms(r.makespan),
                format!("{:.2}x", t1.as_secs_f64() / r.makespan.as_secs_f64()),
                "-".into(),
                "-".into(),
            ]);
            bench_rows.push(BenchRow {
                label: format!("fat1/{gran}"),
                threads: t,
                wall_ms: r.makespan.as_secs_f64() * 1e3,
                mbps,
            });
        }
    }

    // Real executions at host parallelism: producer stall vs compress.
    let host = imt::num_cpus().clamp(2, 4);
    for (mode, flush) in [("sync", FlushMode::Parallel), ("pipelined", FlushMode::Pipelined)] {
        imt::enable(host);
        let be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
        let cfg = WriterConfig {
            basket_entries: basket,
            compression: settings,
            flush,
            granularity: FlushGranularity::Block,
            ..Default::default()
        };
        let rep = write_blocks(
            be,
            Schema::flat_f32("n", n_branches),
            "events",
            cfg,
            (0..n_clusters).map(gen_cluster),
        );
        // disable before surfacing any error so a failed run cannot
        // leave the global pool on for later experiments
        imt::disable();
        let rep = rep?;
        table.row(vec![
            "narrow4".into(),
            format!("{mode} (measured)"),
            "block".into(),
            host.to_string(),
            ms(rep.wall),
            format!("{:.0}% overlap", rep.overlap_fraction() * 100.0),
            ms(rep.stall),
            ms(rep.compress_time),
        ]);
        bench_rows.push(BenchRow {
            label: format!("narrow4/{mode}/measured"),
            threads: host,
            wall_ms: rep.wall.as_secs_f64() * 1e3,
            mbps: rep.throughput_mbps(),
        });
    }

    save_csv("fig3_write_scaling", &table);
    save_bench_json("fig3", &bench_rows);
    save_observability("fig3", None);
    Ok(format!(
        "## Write scaling — pipelined block-granularity flush (§3.1 mirror of Fig 1)\n\
         (simulated workers from measured per-basket / per-block costs; 'measured' \
         rows are real runs on the host pool reporting producer stall vs total \
         compress time)\n\n{}",
        table.render()
    ))
}

/// Multi-writer session scaling (BENCH_fig4.json) — the multi-tree /
/// multi-file coordinator target: N concurrent writers sharing one
/// [`crate::session::Session`] (one pool, one fair-share in-flight
/// budget) versus the same N writers run one-after-another.
///
/// Each writer models a production output module: its producer unit
/// pays generation plus a reconstruction stand-in (8× generation —
/// CMS reco is an order of magnitude above generation; cf. the fig3
/// harness) per cluster, then per-basket serialise+compress tasks land
/// on the shared pool. Costs are measured for real and the worker
/// sweep is scheduled through [`crate::simsched`] exactly like figs
/// 1–3; "measured" rows run the real
/// [`crate::coordinator::write::write_files`] coordinator on the host
/// pool and additionally assert the outputs byte-match their solo
/// runs. The fairness column is the spread between the first and
/// last writer to finish in the shared schedule (1.0 = perfectly
/// fair).
pub fn multi_writer(quick: bool) -> Result<String> {
    let basket = 2048usize;
    let n_branches = 2usize;
    let clusters = if quick { 6 } else { 12 };
    let settings = Settings::new(Codec::Lz4r, 3);

    let gen_cluster = move |w: usize, c: usize| -> Vec<ColumnData> {
        let mut rng = dataset::SplitMix::new(((w as u64) << 32) | (c as u64 + 1));
        (0..n_branches)
            .map(|b| {
                ColumnData::F32(
                    (0..basket)
                        .map(|i| rng.uniform() * (b + 1) as f32 + (i % 23) as f32)
                        .collect(),
                )
            })
            .collect()
    };

    // Calibrate: producer cost per cluster (generate + 8x reco
    // stand-in) and real per-(cluster, branch) serialise+compress.
    let (_, gen_cost) = measure(|| gen_cluster(0, 0));
    let producer_cost = gen_cost * 9;
    let mut costs: Vec<Vec<Duration>> = Vec::with_capacity(clusters);
    let mut raw_per_writer = 0u64;
    for c in 0..clusters {
        let cols = gen_cluster(0, c);
        let mut per_branch = Vec::with_capacity(n_branches);
        for col in &cols {
            raw_per_writer += col.byte_len() as u64;
            let (_, cost) = measure(|| {
                let raw = col.encode();
                compress::compress(settings, &raw)
            });
            per_branch.push(cost);
        }
        costs.push(per_branch);
    }

    // One writer's task graph: a chained producer unit gating its
    // clusters' pool compression tasks (pipelined: clusters are
    // otherwise independent). Returns the writer's task ids.
    let writer_graph = |g: &mut Graph, w: usize| -> Vec<usize> {
        let unit = format!("writer-{w}");
        let mut prev: Option<usize> = None;
        let mut ids = Vec::new();
        for per_branch in &costs {
            let deps: Vec<usize> = prev.into_iter().collect();
            let p = g.named(&unit, SpanKind::Generate, producer_cost, deps);
            prev = Some(p);
            ids.push(p);
            for &c in per_branch {
                ids.push(g.pool(SpanKind::Compress, c, vec![p]));
            }
        }
        ids
    };

    let writer_sweep: Vec<usize> = if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };
    let worker_sweep: Vec<usize> = if quick { vec![4, 8] } else { vec![2, 4, 8] };
    let mut table = Table::new(&[
        "writers", "workers", "mode", "wall_ms", "agg_MBps", "speedup", "fairness",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    for &n_writers in &writer_sweep {
        for &workers in &worker_sweep {
            // One-after-another baseline: each writer alone on the
            // full pool, walls summed.
            let mut solo_wall = Duration::ZERO;
            for w in 0..n_writers {
                let mut g = Graph::new();
                let _ = writer_graph(&mut g, w);
                solo_wall += simulate(&g, workers).makespan;
            }
            // Session-shared: all writers' tasks in one schedule.
            let mut g = Graph::new();
            let per_writer_ids: Vec<Vec<usize>> =
                (0..n_writers).map(|w| writer_graph(&mut g, w)).collect();
            let shared = simulate(&g, workers);
            let mut ends = vec![Duration::ZERO; n_writers];
            for p in &shared.placements {
                for (w, ids) in per_writer_ids.iter().enumerate() {
                    if ids.contains(&p.task) {
                        ends[w] = ends[w].max(p.end);
                    }
                }
            }
            let first = ends.iter().min().copied().unwrap_or_default();
            let last = ends.iter().max().copied().unwrap_or_default();
            let fairness = if first.is_zero() {
                1.0
            } else {
                last.as_secs_f64() / first.as_secs_f64()
            };
            let total_raw = raw_per_writer * n_writers as u64;
            for (mode, wall) in [("solo-seq", solo_wall), ("session", shared.makespan)] {
                let mbps = total_raw as f64 / 1e6 / wall.as_secs_f64();
                table.row(vec![
                    n_writers.to_string(),
                    workers.to_string(),
                    mode.into(),
                    ms(wall),
                    format!("{mbps:.1}"),
                    format!("{:.2}x", solo_wall.as_secs_f64() / wall.as_secs_f64()),
                    if mode == "session" { format!("{fairness:.2}") } else { "-".into() },
                ]);
                bench_rows.push(BenchRow {
                    label: format!("w{n_writers}/{mode}"),
                    threads: workers,
                    wall_ms: wall.as_secs_f64() * 1e3,
                    mbps,
                });
            }
        }
    }

    // Real runs on the host pool: 4 writers, solo-sequential vs one
    // shared session; outputs must byte-match their solo runs.
    let host = imt::num_cpus().clamp(2, 4);
    let n_real = 4usize;
    let real_cfg = WriterConfig {
        basket_entries: basket,
        compression: settings,
        flush: FlushMode::Pipelined,
        granularity: FlushGranularity::Block,
        max_inflight_clusters: 2,
        ..Default::default()
    };
    let mk_jobs = |backends: &[BackendRef]| -> Vec<crate::coordinator::write::WriteJob> {
        backends
            .iter()
            .enumerate()
            .map(|(w, be)| crate::coordinator::write::WriteJob {
                backend: be.clone(),
                schema: Schema::flat_f32("v", n_branches),
                name: "events".into(),
                config: real_cfg.clone(),
                blocks: (0..clusters).map(|c| gen_cluster(w, c)).collect(),
            })
            .collect()
    };
    let dump = |be: &BackendRef| -> Vec<u8> {
        use crate::storage::Backend;
        let mut bytes = vec![0u8; be.len().unwrap_or(0) as usize];
        let _ = be.read_at(0, &mut bytes);
        bytes
    };
    let pool = Arc::new(crate::imt::Pool::new(host));
    // solo-sequential baseline
    let solo_backends: Vec<BackendRef> =
        (0..n_real).map(|_| Arc::new(crate::storage::mem::MemBackend::new()) as BackendRef).collect();
    let (solo_reports, solo_wall) = measure(|| -> Result<Vec<_>> {
        mk_jobs(&solo_backends)
            .into_iter()
            .map(|job| {
                let session = crate::session::Session::with_pool(
                    pool.clone(),
                    crate::session::SessionConfig::for_writers(1, 2),
                );
                crate::coordinator::write::write_blocks_in_session(
                    &session, job.backend, job.schema, &job.name, job.config, job.blocks,
                )
            })
            .collect()
    });
    let solo_reports = solo_reports?;
    // session-shared
    let shared_backends: Vec<BackendRef> =
        (0..n_real).map(|_| Arc::new(crate::storage::mem::MemBackend::new()) as BackendRef).collect();
    let session = crate::session::Session::with_pool(
        pool.clone(),
        crate::session::SessionConfig::for_writers(n_real, 2),
    );
    let (shared_reports, shared_wall) =
        measure(|| crate::coordinator::write::write_files(&session, mk_jobs(&shared_backends)));
    let shared_reports = shared_reports?;
    for w in 0..n_real {
        if dump(&solo_backends[w]) != dump(&shared_backends[w]) {
            return Err(crate::error::Error::Coordinator(format!(
                "multi_writer: shared-session output {w} diverged from its solo bytes"
            )));
        }
    }
    let total_raw: u64 = solo_reports.iter().map(|r| r.raw_bytes).sum();
    let max_stall_ms = shared_reports
        .iter()
        .map(|r| r.stall.as_secs_f64() * 1e3)
        .fold(0.0f64, f64::max);
    for (mode, wall) in [("solo-seq (measured)", solo_wall), ("session (measured)", shared_wall)]
    {
        let mbps = total_raw as f64 / 1e6 / wall.as_secs_f64();
        table.row(vec![
            n_real.to_string(),
            host.to_string(),
            mode.into(),
            ms(wall),
            format!("{mbps:.1}"),
            format!("{:.2}x", solo_wall.as_secs_f64() / wall.as_secs_f64()),
            if mode.starts_with("session") {
                format!("max stall {max_stall_ms:.1} ms")
            } else {
                "-".into()
            },
        ]);
        bench_rows.push(BenchRow {
            label: format!("w{n_real}/{}/measured", if mode.starts_with("session") { "session" } else { "solo" }),
            threads: host,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps,
        });
    }

    save_csv("fig4_multi_writer", &table);
    save_bench_json("fig4", &bench_rows);
    save_observability("fig4", None);
    Ok(format!(
        "## Multi-writer session scaling (writers × workers, solo-sequential vs shared session)\n\
         (simulated workers from measured per-cluster producer and per-basket \
         serialise+compress costs; 'measured' rows run the real write_files \
         coordinator on the host pool with byte-identity asserted against solo runs)\n\n{}",
        table.render()
    ))
}

/// Per-cluster-size cost model measured from real runs: entry counts
/// (the ladder) mapped to a per-cluster producer cost and a
/// per-basket serialise+compress cost. Lookups for off-ladder sizes
/// (tail clusters) scale the nearest ladder point linearly.
struct SizeCosts {
    gen: std::collections::BTreeMap<usize, Duration>,
    comp: std::collections::BTreeMap<usize, Duration>,
}

impl SizeCosts {
    fn lookup(map: &std::collections::BTreeMap<usize, Duration>, c: usize) -> Duration {
        if let Some(d) = map.get(&c) {
            return *d;
        }
        // nearest ladder key at or below `c` (else the smallest),
        // scaled by the entry ratio — good enough for tail clusters.
        let (&k, &d) = map
            .range(..=c)
            .next_back()
            .unwrap_or_else(|| map.iter().next().expect("non-empty cost ladder"));
        d.mul_f64(c as f64 / k as f64)
    }

    fn gen(&self, c: usize) -> Duration {
        Self::lookup(&self.gen, c)
    }

    fn comp(&self, c: usize) -> Duration {
        Self::lookup(&self.comp, c)
    }
}

/// Measure the adaptive-sizing cost ladder for a narrow *fast*
/// producer: per-cluster production cost and per-basket
/// serialise+compress cost at every candidate cluster size (min of 3
/// real samples each). Production is a slice-copy out of one
/// pre-generated master buffer — the PJRT-event-block shape, where
/// landing a cluster is a memcpy and compression dominates — so the
/// workload is compression-bound by construction and the per-call
/// codec setup shows up undiluted at small sizes.
fn measure_size_costs(
    ladder: &[usize],
    n_branches: usize,
    settings: Settings,
) -> SizeCosts {
    let top = ladder.iter().copied().max().unwrap_or(1);
    let mut rng = dataset::SplitMix::new(0xF16_5);
    let master: Vec<Vec<f32>> = (0..n_branches)
        .map(|b| (0..top).map(|i| rng.uniform() * (b + 1) as f32 + (i % 29) as f32).collect())
        .collect();
    let mut gen = std::collections::BTreeMap::new();
    let mut comp = std::collections::BTreeMap::new();
    for &c in ladder {
        let mut best_gen = Duration::MAX;
        let mut best_comp = Duration::MAX;
        for _ in 0..3 {
            let (cols, g) = measure(|| {
                master
                    .iter()
                    .map(|m| ColumnData::F32(m[..c].to_vec()))
                    .collect::<Vec<_>>()
            });
            best_gen = best_gen.min(g);
            let (_, cc) = measure(|| {
                let raw = cols[0].encode();
                compress::compress(settings, &raw)
            });
            best_comp = best_comp.min(cc);
        }
        gen.insert(c, best_gen);
        comp.insert(c, best_comp);
    }
    SizeCosts { gen, comp }
}

/// Pipelined-writer task graph for a given cluster-size sequence: a
/// chained producer unit (generation) gating each cluster's per-basket
/// compress tasks on the pool — the same shape the write_scaling and
/// multi_writer harnesses schedule.
fn sizing_graph(sizes: &[usize], costs: &SizeCosts, n_branches: usize) -> Graph {
    let mut g = Graph::new();
    let mut prev: Option<usize> = None;
    for &c in sizes {
        let deps: Vec<usize> = prev.into_iter().collect();
        let p = g.named("producer", SpanKind::Generate, costs.gen(c), deps);
        prev = Some(p);
        for _ in 0..n_branches {
            g.pool(SpanKind::Compress, costs.comp(c), vec![p]);
        }
    }
    g
}

/// Drive a [`ClusterSizer`] through a *virtual-time* pipeline built
/// from the measured cost ladder: a deterministic discrete-event loop
/// (producer clock, `cap` in-flight cluster slots, `workers`
/// earliest-free compress units) feeds the controller exactly the
/// cumulative stall / compress / wait counters the real writer would
/// observe, and returns the resulting cluster-size trace. Same costs
/// in → same trace out, so the acceptance test is schedule-noise-free.
fn virtual_adaptive_trace(
    entries: usize,
    start: usize,
    cfg: AdaptiveConfig,
    workers: usize,
    cap: usize,
    costs: &SizeCosts,
    n_branches: usize,
) -> Vec<usize> {
    let workers = workers.max(1);
    let cap = cap.max(1);
    let mut sizer = ClusterSizer::new(start, ClusterSizing::Adaptive(cfg));
    let mut t = Duration::ZERO;
    let mut worker_free = vec![Duration::ZERO; workers];
    let mut inflight: Vec<Duration> = Vec::new();
    let mut cum_stall = Duration::ZERO;
    let mut cum_comp = Duration::ZERO;
    let mut waits = 0u64;
    let mut sizes = Vec::new();
    let mut done = 0usize;
    while done < entries {
        let c = sizer.target().min(entries - done);
        sizes.push(c);
        done += c;
        // produce the cluster
        t += costs.gen(c);
        // admission: wait for a slot when `cap` clusters are in flight
        inflight.retain(|&d| d > t);
        if inflight.len() >= cap {
            inflight.sort();
            let free_at = inflight[inflight.len() - cap];
            cum_stall += free_at.saturating_sub(t);
            waits += 1;
            t = free_at;
            inflight.retain(|&d| d > t);
        }
        // compress: one task per branch on the earliest-free workers
        let task = costs.comp(c);
        let mut cluster_done = t;
        for _ in 0..n_branches {
            let mut idx = 0;
            for (i, d) in worker_free.iter().enumerate() {
                if *d < worker_free[idx] {
                    idx = i;
                }
            }
            let fin = worker_free[idx].max(t) + task;
            worker_free[idx] = fin;
            cluster_done = cluster_done.max(fin);
            cum_comp += task;
        }
        inflight.push(cluster_done);
        sizer.observe(cum_stall, cum_comp, waits);
    }
    sizes
}

/// Adaptive cluster sizing (BENCH_fig5.json) — closing the write-path
/// feedback loop: a narrow fast producer (2 branches, cheap
/// generation, heavy rzip compression) swept across *fixed* cluster
/// sizes versus the adaptive sizer started at the stock default
/// (4096), clamped into the sweep band.
///
/// Methodology (the fig1/fig3/fig4 recipe): per-size producer and
/// per-basket serialise+compress costs are measured for real — the
/// rzip codec's fixed per-call setup makes tiny clusters genuinely
/// expensive per byte — and every row's worker sweep is scheduled
/// deterministically through [`crate::simsched`]. The adaptive row's
/// cluster-size *trace* comes from [`virtual_adaptive_trace`]: the
/// real [`ClusterSizer`] driven by a deterministic virtual-time
/// pipeline over the same measured costs. "measured" rows run the
/// real writer (fixed smallest, fixed largest, adaptive) on the host
/// pool, report the chosen size band, stall and admission waits from
/// [`crate::coordinator::write::WriteReport`], and assert the decoded
/// data is entry-identical across all three.
pub fn adaptive_sizing(quick: bool) -> Result<String> {
    let n_branches = 2usize;
    let entries: usize = if quick { 32_768 } else { 65_536 };
    let settings = Settings::new(Codec::Rzip, 4);
    let min_c = 128usize;
    let max_c = if quick { 4096 } else { 16_384 };
    let ladder: Vec<usize> =
        std::iter::successors(Some(min_c), |c| Some(c * 2)).take_while(|c| *c <= max_c).collect();
    let costs = measure_size_costs(&ladder, n_branches, settings);

    let threads: Vec<usize> = if quick { vec![2, 8] } else { vec![1, 2, 4, 8] };
    let mut table = Table::new(&[
        "mode", "cluster_entries", "threads", "wall_ms", "ingest_MBps", "speedup_vs_worst",
        "notes",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    let raw_bytes = (entries * n_branches * 4) as u64;

    // Fixed sweep: E/C clusters of C entries (+ tail).
    let fixed_sizes = |c: usize| -> Vec<usize> {
        let mut v = vec![c; entries / c];
        if entries % c > 0 {
            v.push(entries % c);
        }
        v
    };
    let mut walls_at_8: Vec<(String, f64)> = Vec::new();
    let mut fixed_rows: Vec<(usize, usize, Duration)> = Vec::new();
    for &c in &ladder {
        let g = sizing_graph(&fixed_sizes(c), &costs, n_branches);
        for &t in &threads {
            let r = simulate(&g, t);
            fixed_rows.push((c, t, r.makespan));
            if t == 8 {
                walls_at_8.push((format!("fixed/{c}"), r.makespan.as_secs_f64()));
            }
        }
    }
    // Adaptive: the sizer driven through the virtual-time pipeline,
    // starting at the stock `WriterConfig` default (4096) — the "keep
    // the default, the sizer finds your workload's size" shape.
    let adaptive_cfg = AdaptiveConfig {
        min_entries: min_c,
        max_entries: max_c,
        hysteresis: 1,
        warmup: 2,
        ..Default::default()
    };
    let start = 4096usize.clamp(min_c, max_c);
    let trace = virtual_adaptive_trace(entries, start, adaptive_cfg, 8, 4, &costs, n_branches);
    let adaptive_graph = sizing_graph(&trace, &costs, n_branches);
    let mut adaptive_rows: Vec<(usize, Duration)> = Vec::new();
    for &t in &threads {
        let r = simulate(&adaptive_graph, t);
        adaptive_rows.push((t, r.makespan));
        if t == 8 {
            walls_at_8.push(("adaptive".into(), r.makespan.as_secs_f64()));
        }
    }
    let worst_at_8 = walls_at_8
        .iter()
        .filter(|(m, _)| m.starts_with("fixed/"))
        .map(|(_, w)| *w)
        .fold(0.0f64, f64::max);

    for (c, t, wall) in fixed_rows {
        let mbps = raw_bytes as f64 / 1e6 / wall.as_secs_f64();
        table.row(vec![
            "fixed".into(),
            c.to_string(),
            t.to_string(),
            ms(wall),
            format!("{mbps:.1}"),
            if t == 8 {
                format!("{:.2}x", worst_at_8 / wall.as_secs_f64())
            } else {
                "-".into()
            },
            "-".into(),
        ]);
        bench_rows.push(BenchRow {
            label: format!("fixed/{c}"),
            threads: t,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps,
        });
    }
    let trace_note = {
        let first = trace.first().copied().unwrap_or(0);
        let last = trace.last().copied().unwrap_or(0);
        let peak = trace.iter().copied().max().unwrap_or(0);
        format!("trace {first}->{peak} (last {last}, {} clusters)", trace.len())
    };
    for (t, wall) in adaptive_rows {
        let mbps = raw_bytes as f64 / 1e6 / wall.as_secs_f64();
        table.row(vec![
            "adaptive".into(),
            format!("{min_c}..{max_c}"),
            t.to_string(),
            ms(wall),
            format!("{mbps:.1}"),
            if t == 8 {
                format!("{:.2}x", worst_at_8 / wall.as_secs_f64())
            } else {
                "-".into()
            },
            trace_note.clone(),
        ]);
        bench_rows.push(BenchRow {
            label: "adaptive".into(),
            threads: t,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps,
        });
    }

    // Real runs on the host pool: fixed smallest, fixed largest and
    // adaptive must decode to entry-identical data.
    let host = imt::num_cpus().clamp(2, 4);
    let block = 4096.min(entries);
    let gen_blocks = move |salt: u64| -> Vec<Vec<ColumnData>> {
        (0..entries / block)
            .map(|blk| {
                let mut rng = dataset::SplitMix::new(((salt + 7) << 24) | blk as u64);
                (0..n_branches)
                    .map(|b| {
                        ColumnData::F32(
                            (0..block)
                                .map(|i| rng.uniform() * (b + 1) as f32 + (i % 29) as f32)
                                .collect(),
                        )
                    })
                    .collect()
            })
            .collect()
    };
    let decode = |be: &BackendRef| -> Result<Vec<Vec<u8>>> {
        let reader = TreeReader::open_first(Arc::new(FileReader::open(be.clone())?))?;
        Ok(reader.read_all()?.iter().map(|c| c.encode()).collect())
    };
    let modes: Vec<(String, usize, ClusterSizing)> = vec![
        (format!("fixed/{min_c}"), min_c, ClusterSizing::Fixed),
        (format!("fixed/{max_c}"), max_c, ClusterSizing::Fixed),
        ("adaptive".into(), start, ClusterSizing::Adaptive(adaptive_cfg)),
    ];
    let mut decoded: Vec<Vec<Vec<u8>>> = Vec::new();
    let pool = Arc::new(crate::imt::Pool::new(host));
    for (mode, basket, sizing) in &modes {
        let be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
        let cfg = WriterConfig {
            basket_entries: *basket,
            compression: settings,
            flush: FlushMode::Pipelined,
            granularity: FlushGranularity::Block,
            max_inflight_clusters: 4,
            sizing: *sizing,
            ..Default::default()
        };
        // Private pool session: no global IMT state is touched.
        let session = crate::session::Session::with_pool(
            pool.clone(),
            crate::session::SessionConfig::for_writers(1, 4),
        );
        let rep = crate::coordinator::write::write_blocks_in_session(
            &session,
            be.clone(),
            Schema::flat_f32("n", n_branches),
            "events",
            cfg,
            gen_blocks(1),
        )?;
        decoded.push(decode(&be)?);
        let s = rep.sizing;
        table.row(vec![
            format!("{mode} (measured)"),
            format!("{}..{}", s.min_entries, s.max_entries),
            host.to_string(),
            ms(rep.wall),
            format!("{:.1}", rep.throughput_mbps()),
            format!("stall {}", ms(rep.stall)),
            format!("{} clusters, +{} -{}", s.clusters, s.grows, s.shrinks),
        ]);
        bench_rows.push(BenchRow {
            label: format!("{mode}/measured"),
            threads: host,
            wall_ms: rep.wall.as_secs_f64() * 1e3,
            mbps: rep.throughput_mbps(),
        });
    }
    for (i, (mode, _, _)) in modes.iter().enumerate().skip(1) {
        if decoded[i] != decoded[0] {
            return Err(crate::error::Error::Coordinator(format!(
                "adaptive_sizing: '{mode}' decoded data diverged from '{}'",
                modes[0].0
            )));
        }
    }

    save_csv("fig5_adaptive_sizing", &table);
    save_bench_json("fig5", &bench_rows);
    save_observability("fig5", None);
    Ok(format!(
        "## Adaptive cluster sizing — fixed sweep vs feedback-sized clusters (narrow fast producer)\n\
         (simulated workers from measured per-size costs; the adaptive trace is the real \
         ClusterSizer driven through a deterministic virtual-time pipeline; 'measured' rows \
         run the real writer on the host pool with entry-identity asserted across modes)\n\n{}",
        table.render()
    ))
}

/// Figure 6 — TBufferMerger write performance across devices.
///
/// Workers generate pseudo-random single-column data through the PRNG
/// kernel and compress baskets on their own threads; the output thread
/// appends to the device, whose cost comes from the calibrated
/// [`DeviceModel`] (sequential append: bandwidth-dominated).
pub fn fig6(quick: bool) -> Result<String> {
    let engine = try_engine();
    let total_mb = if quick { 64 } else { 256 };
    let workers_sweep: Vec<usize> = if quick { vec![1, 2, 4] } else { vec![1, 2, 4, 8] };

    // calibrate: generation + compression cost per ~1MB basket of PRNG data
    let block = engine.as_ref().map(|e| e.max_block()).unwrap_or(16384);
    let (ev_data, gen_block_cost) = measure(|| match engine.as_ref() {
        Some(e) => e.generate(1, 0, block).unwrap().data,
        None => {
            let mut rng = dataset::SplitMix::new(1);
            (0..block * 8).map(|_| rng.uniform()).collect()
        }
    });
    let basket_values = ev_data.len(); // one engine block = one basket here
    let basket_bytes = basket_values * 4;
    let raw = ColumnData::F32(ev_data).encode();
    let cases: Vec<(&str, Settings)> = vec![
        ("none", Settings::uncompressed()),
        ("rzip", Settings::new(Codec::Rzip, 4)),
    ];
    let mut table = Table::new(&[
        "panel", "device", "codec", "workers", "write_MBps", "speedup",
    ]);
    // Right panel: the paper scales compressed writing "to a larger
    // number of threads until the limit of the disk is reached" — the
    // HDD saturates first, the NVMe keeps going (the 4x gap).
    let right_sweep: Vec<usize> =
        if quick { vec![4, 16, 32] } else { vec![4, 8, 16, 32, 64, 128] };
    for (panel, device) in [
        ("left", DeviceModel::ssd()),
        ("left", DeviceModel::tmpfs()),
        ("right", DeviceModel::hdd()),
        ("right", DeviceModel::nvme()),
    ] {
        for (codec_name, settings) in &cases {
            // paper panels: left = ssd/tmpfs both codecs; right = hdd/nvme compressed
            if panel == "right" && *codec_name == "none" {
                continue;
            }
            let workers_sweep =
                if panel == "right" { right_sweep.clone() } else { workers_sweep.clone() };
            let (packed, comp_cost) = measure(|| compress::compress(*settings, &raw));
            let stored = packed.len();
            let device_cost = Duration::from_secs_f64(
                stored as f64 / (device.write_mbps * 1e6),
            );
            let n_baskets = (total_mb * 1_000_000usize).div_ceil(basket_bytes);
            let mut base: Option<f64> = None;
            for &w in &workers_sweep {
                let mut graph = Graph::new();
                for k in 0..n_baskets {
                    let unit = format!("w{:02}", k % w);
                    let g = graph.named(&unit, SpanKind::Generate, gen_block_cost, vec![]);
                    let c = graph.named(&unit, SpanKind::Compress, comp_cost, vec![g]);
                    graph.named("device", SpanKind::Write, device_cost, vec![c]);
                }
                let r = simulate(&graph, 1);
                let mbps =
                    n_baskets as f64 * basket_bytes as f64 / 1e6 / r.makespan.as_secs_f64();
                let speedup = mbps / *base.get_or_insert(mbps);
                table.row(vec![
                    panel.into(),
                    device.name.into(),
                    (*codec_name).into(),
                    w.to_string(),
                    format!("{mbps:.1}"),
                    format!("{speedup:.2}x"),
                ]);
            }
        }
    }
    save_csv("fig6_buffer_merger", &table);
    Ok(format!(
        "## Figure 6 — TBufferMerger write performance\n\
         (simulated workers; compression/generation costs measured, device costs \
         from the calibrated device models)\n\n{}",
        table.render()
    ))
}

/// Figure 7 — concurrency optimisations: the Figure 6 compressed/SSD
/// benchmark with ("before") and without ("after") a global streamer
/// lock, with per-thread timelines and useful-work fractions.
pub fn fig7(quick: bool) -> Result<String> {
    let engine = try_engine();
    let workers = if quick { 8 } else { 16 };
    let total_mb = if quick { 32 } else { 96 };
    let block = engine.as_ref().map(|e| e.max_block()).unwrap_or(16384);
    let (ev_data, gen_cost) = measure(|| match engine.as_ref() {
        Some(e) => e.generate(1, 0, block).unwrap().data,
        None => {
            let mut rng = dataset::SplitMix::new(1);
            (0..block * 8).map(|_| rng.uniform()).collect()
        }
    });
    let basket_bytes = ev_data.len() * 4;
    let raw = ColumnData::F32(ev_data).encode();
    let settings = Settings::new(Codec::Rzip, 4);
    let (packed, comp_cost) = measure(|| compress::compress(settings, &raw));
    let device = DeviceModel::ssd();
    let device_cost =
        Duration::from_secs_f64(packed.len() as f64 / (device.write_mbps * 1e6));
    let n_baskets = (total_mb * 1_000_000usize).div_ceil(basket_bytes);

    let mut out =
        String::from("## Figure 7 — concurrency optimisations (thread timelines)\n\n");
    let mut table =
        Table::new(&["mode", "workers", "wall_ms", "write_MBps", "worker_utilization"]);
    for (mode, locked) in [("before (global lock)", true), ("after (optimized)", false)] {
        let mut graph = Graph::new();
        let mut startup = Vec::new();
        // single-threaded startup phase (the paper's leading stripe)
        startup.push(graph.named("w00", SpanKind::Startup, gen_cost, vec![]));
        for k in 0..n_baskets {
            let unit = format!("w{:02}", k % workers);
            let g = graph.named(&unit, SpanKind::Generate, gen_cost, startup.clone());
            // "before": serialisation+compression under the global lock
            let c = if locked {
                graph.named("lock", SpanKind::Compress, comp_cost, vec![g])
            } else {
                graph.named(&unit, SpanKind::Compress, comp_cost, vec![g])
            };
            graph.named("device", SpanKind::Write, device_cost, vec![c]);
        }
        let r = simulate(&graph, 1);
        let mbps = n_baskets as f64 * basket_bytes as f64 / 1e6 / r.makespan.as_secs_f64();
        // worker-unit utilization (the VTune brown fraction)
        let worker_busy: f64 = r
            .busy
            .iter()
            .filter(|(u, _)| u.starts_with('w'))
            .map(|(_, b)| b.as_secs_f64())
            .sum();
        let util = worker_busy / (workers as f64 * r.makespan.as_secs_f64());
        table.row(vec![
            mode.into(),
            workers.to_string(),
            ms(r.makespan),
            format!("{mbps:.1}"),
            format!("{util:.2}"),
        ]);
        out.push_str(&format!(
            "### {mode}\n\n```\n{}```\n\n",
            crate::simsched::timeline(&graph, &r, 100)
        ));
    }
    save_csv("fig7_concurrency", &table);
    out.push_str(&table.render());
    out.push_str(
        "\nlegend: S startup, g generate, c compress, w write, m merge; \
         `lock` row = the global streamer mutex, `device` row = the SSD queue\n",
    );
    Ok(out)
}

/// §3.4 — serial vs parallel `hadd`. Real execution (I/O + checksum
/// dominated, runs fine on one core) plus a simulated -j sweep from the
/// measured per-file load costs.
pub fn hadd_bench(quick: bool) -> Result<String> {
    let engine = try_engine();
    let n_files = if quick { 4 } else { 8 };
    let entries = if quick { 16_384 } else { 65_536 };
    let inputs: Vec<BackendRef> = (0..n_files)
        .map(|_| {
            synthesize_dataset(
                DatasetKind::Aod,
                entries,
                4096,
                Settings::new(Codec::Rzip, 4),
                engine.as_ref(),
            )
            .map(|(be, _)| be)
        })
        .collect::<Result<_>>()?;

    // real serial run + calibration of per-file load cost
    imt::disable();
    let out_be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
    let (serial, serial_wall) =
        measure(|| hadd(out_be, &inputs, &HaddOptions::default()).unwrap());

    let mut load_costs = Vec::new();
    for input in &inputs {
        let (_, c) = measure(|| {
            // re-load the input (fetch + CRC verify), the parallel phase
            let f = FileReader::open(input.clone()).unwrap();
            let t = &f.directory().trees[0];
            for br in &t.branches {
                for k in &br.baskets {
                    f.fetch_basket(k).unwrap();
                }
            }
        });
        load_costs.push(c);
    }
    let append_cost = Duration::from_secs_f64(serial.stored_bytes as f64 / 8e9);

    let mut table = Table::new(&["mode", "threads", "files", "wall_ms", "speedup"]);
    table.row(vec![
        "serial (measured)".into(),
        "1".into(),
        n_files.to_string(),
        ms(serial_wall),
        "1.00x".into(),
    ]);
    let mut graph1 = Graph::new();
    let loads: Vec<usize> =
        load_costs.iter().map(|&c| graph1.pool(SpanKind::Read, c, vec![])).collect();
    graph1.named("output", SpanKind::Merge, append_cost, loads);
    let t1 = simulate(&graph1, 1).makespan;
    for t in [2usize, 4, 8] {
        let r = simulate(&graph1, t);
        table.row(vec![
            "parallel -j (simulated)".into(),
            t.to_string(),
            n_files.to_string(),
            ms(r.makespan),
            format!("{:.2}x", t1.as_secs_f64() / r.makespan.as_secs_f64()),
        ]);
    }
    save_csv("hadd_merge", &table);
    Ok(format!("## §3.4 — parallel hadd\n\n{}", table.render()))
}

/// Codec characterisation (the §2 compression-choice discussion).
/// Real measurements — single-threaded by nature.
pub fn codec_bench(quick: bool) -> Result<String> {
    let engine = try_engine();
    let entries = if quick { 65_536 } else { 262_144 };
    let block = engine.as_ref().map(|e| e.meta().blocks[0]).unwrap_or(4096);
    let mut cols: Vec<u8> = Vec::new();
    let mut produced = 0usize;
    let mut i = 0u32;
    while produced < entries {
        let blockcols: Vec<ColumnData> = match engine.as_ref() {
            Some(e) => dataset::engine_block(e, DatasetKind::Aod, i + 1, 0, block)?,
            None => {
                let mut rng = dataset::SplitMix::new(i as u64);
                dataset::fallback_block(&mut rng, DatasetKind::Aod, block)
            }
        };
        cols.extend_from_slice(&blockcols[0].encode());
        produced += block;
        i += 1;
    }

    let mut table = Table::new(&["codec", "level", "ratio", "comp_MBps", "decomp_MBps"]);
    let mut cases: Vec<Settings> = vec![Settings::uncompressed()];
    for level in [1u8, 4, 9] {
        cases.push(Settings::new(Codec::Lz4r, level));
        cases.push(Settings::new(Codec::Rzip, level));
    }
    for settings in cases {
        let reps = if quick { 1 } else { 3 };
        let mut compressed = Vec::new();
        let (_, enc) = measure(|| {
            for _ in 0..reps {
                compressed = compress::compress(settings, &cols);
            }
        });
        let enc = enc / reps;
        let (_, dec) = measure(|| {
            for _ in 0..reps {
                let out = compress::decompress(&compressed).unwrap();
                assert_eq!(out.len(), cols.len());
            }
        });
        let dec = dec / reps;
        table.row(vec![
            settings.codec.name().into(),
            settings.level.to_string(),
            format!("{:.2}", cols.len() as f64 / compressed.len() as f64),
            format!("{:.1}", cols.len() as f64 / 1e6 / enc.as_secs_f64()),
            format!("{:.1}", cols.len() as f64 / 1e6 / dec.as_secs_f64()),
        ]);
    }
    save_csv("codec", &table);

    // --- Fig 8 (codec kernels + per-column selection frontier) ---
    //
    // Part 1: scalar reference vs vectorised kernel, same payload.
    // Byte-identity between the two paths is asserted inline so a
    // diverging kernel fails the bench run itself, not just the
    // differential unit tests.
    fn kernel_row(label: &str, bytes: usize, wall: Duration) -> BenchRow {
        BenchRow {
            label: label.to_string(),
            threads: 1,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps: bytes as f64 / 1e6 / wall.as_secs_f64().max(1e-9),
        }
    }
    let mut fig8: Vec<BenchRow> = Vec::new();
    let reps = if quick { 1usize } else { 3 };

    let (crc_wide, t) = measure(|| {
        let mut s = 0u32;
        for _ in 0..reps {
            s = compress::crc32::crc32_update(!0, &cols);
        }
        s
    });
    fig8.push(kernel_row("crc32/wide", cols.len() * reps, t));
    let (crc_scalar, t) = measure(|| {
        let mut s = 0u32;
        for _ in 0..reps {
            s = compress::crc32::crc32_update_scalar(!0, &cols);
        }
        s
    });
    fig8.push(kernel_row("crc32/scalar", cols.len() * reps, t));
    assert_eq!(crc_wide, crc_scalar, "slicing-by-8 CRC32 must match the bitwise kernel");

    let (lz_wide, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out = compress::lz4r::compress(&cols, 4);
        }
        out
    });
    fig8.push(kernel_row("lz4r_compress/wide", cols.len() * reps, t));
    let (lz_scalar, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out = compress::lz4r::compress_scalar(&cols, 4);
        }
        out
    });
    fig8.push(kernel_row("lz4r_compress/scalar", cols.len() * reps, t));
    assert_eq!(lz_wide, lz_scalar, "SWAR lz4r match finder must be byte-identical");

    let (lzd_wide, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out.clear();
            compress::lz4r::decompress_into(&lz_wide, cols.len(), &mut out).unwrap();
        }
        out
    });
    fig8.push(kernel_row("lz4r_decompress/wide", cols.len() * reps, t));
    let (lzd_scalar, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out.clear();
            compress::lz4r::decompress_into_scalar(&lz_wide, cols.len(), &mut out).unwrap();
        }
        out
    });
    fig8.push(kernel_row("lz4r_decompress/scalar", cols.len() * reps, t));
    assert_eq!(lzd_wide, cols, "lz4r wide decode must round-trip");
    assert_eq!(lzd_scalar, cols, "lz4r scalar decode must round-trip");

    let (rz_wide, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out = compress::rzip::compress(&cols, 4);
        }
        out
    });
    fig8.push(kernel_row("rzip_compress/wide", cols.len() * reps, t));
    let (rz_scalar, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out = compress::rzip::compress_scalar(&cols, 4);
        }
        out
    });
    fig8.push(kernel_row("rzip_compress/scalar", cols.len() * reps, t));
    assert_eq!(rz_wide, rz_scalar, "vectorised rzip output must be byte-identical");

    let (rzd_wide, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out.clear();
            compress::rzip::decompress_into(&rz_wide, cols.len(), &mut out).unwrap();
        }
        out
    });
    fig8.push(kernel_row("rzip_decompress/wide", cols.len() * reps, t));
    let (rzd_scalar, t) = measure(|| {
        let mut out = Vec::new();
        for _ in 0..reps {
            out.clear();
            compress::rzip::decompress_into_scalar(&rz_wide, cols.len(), &mut out).unwrap();
        }
        out
    });
    fig8.push(kernel_row("rzip_decompress/scalar", cols.len() * reps, t));
    assert_eq!(rzd_wide, cols, "rzip wide decode must round-trip");
    assert_eq!(rzd_scalar, cols, "rzip scalar decode must round-trip");

    // Part 2: the write-throughput x file-size frontier on a mixed
    // tree. Each global codec is wrong for at least one column; the
    // per-column selector commits a codec per branch and should land
    // Pareto-undominated (no global both smaller AND cheaper).
    // At basket 2048 the default selector probes 10 baskets per column,
    // so even the quick run gives it 16 — enough to commit and show the
    // committed codec's throughput, not just probe noise.
    let frontier_entries = if quick { 32_768 } else { 131_072 };
    let (schema, blocks) = mixed_codec_tree(frontier_entries);
    let strategies: Vec<(&str, Settings, CodecSelection)> = vec![
        ("global-none", Settings::uncompressed(), CodecSelection::Global),
        ("global-lz4r4", Settings::new(Codec::Lz4r, 4), CodecSelection::Global),
        ("global-rzip6", Settings::new(Codec::Rzip, 6), CodecSelection::Global),
        (
            "per-column",
            Settings::new(Codec::Lz4r, 4),
            CodecSelection::PerColumn(SelectConfig::default()),
        ),
    ];
    for (name, compression, selection) in strategies {
        let be: BackendRef = Arc::new(MemBackend::new());
        let cfg = WriterConfig {
            basket_entries: 2048,
            compression,
            selection,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let rep = write_blocks(be, schema.clone(), "events", cfg, blocks.clone())?;
        fig8.push(BenchRow {
            label: format!(
                "frontier/{name} stored={} ratio={:.2} compress_ms={:.1}",
                rep.stored_bytes,
                rep.compression_ratio(),
                rep.compress_time.as_secs_f64() * 1e3,
            ),
            threads: 1,
            wall_ms: rep.wall.as_secs_f64() * 1e3,
            mbps: rep.throughput_mbps(),
        });
    }
    save_bench_json("fig8", &fig8);
    save_observability("fig8", None);

    Ok(format!("## Codec characterisation\n\n{}", table.render()))
}

/// Mixed-codec tree for Fig 8 and its acceptance test: a noise-float
/// column (incompressible — storing raw wins), a narrow-range int
/// column (entropy coding crushes it; LZ tokens cannot), and a
/// text-like tag column (both byte-LZ and entropy coding bite). No
/// single global codec is right for all three, so per-column selection
/// has a real frontier to win.
fn mixed_codec_tree(entries: usize) -> (Schema, Vec<Vec<ColumnData>>) {
    let schema = Schema::new(vec![
        Field::new("energy", ColumnType::F32),
        Field::new("adc", ColumnType::I32),
        Field::new("tag", ColumnType::U8),
    ]);
    const TAGS: [&[u8]; 8] = [
        b"pixel", b"strip", b"tile", b"crystal", b"wire", b"pad", b"fiber", b"slab",
    ];
    let mut rng = dataset::SplitMix::new(0xF168);
    let block = 4096usize;
    let mut blocks = Vec::new();
    let mut produced = 0usize;
    while produced < entries {
        let n = block.min(entries - produced);
        let energy: Vec<f32> = (0..n).map(|_| rng.uniform() * 1e3).collect();
        let adc: Vec<i32> = (0..n).map(|_| (rng.next_u32() % 4) as i32).collect();
        let mut tag = Vec::with_capacity(n);
        while tag.len() < n {
            let w = TAGS[(rng.next_u32() % TAGS.len() as u32) as usize];
            let take = w.len().min(n - tag.len());
            tag.extend_from_slice(&w[..take]);
            if tag.len() < n {
                tag.push(b' ');
            }
        }
        blocks.push(vec![
            ColumnData::F32(energy),
            ColumnData::I32(adc),
            ColumnData::U8(tag),
        ]);
        produced += n;
    }
    (schema, blocks)
}

/// Ablation — basket (cluster) size vs compression ratio, write cost
/// and read cost. The design choice behind ROOT's default 32 kB basket:
/// small baskets pay per-block header + Huffman-table overhead and
/// fragment matches; huge baskets hurt parallel granularity (fewer
/// tasks than workers in Figs 1/2).
pub fn ablation_bench(quick: bool) -> Result<String> {
    let engine = try_engine();
    let entries = if quick { 32_768 } else { 131_072 };
    let mut table = Table::new(&[
        "basket_entries", "baskets", "ratio", "write_ms", "read_ms", "tasks_for_fig2",
    ]);
    for basket in [512usize, 2048, 4096, 16384, 65536] {
        let t0 = Instant::now();
        let (be, rep) = synthesize_dataset(
            DatasetKind::Aod,
            entries,
            basket,
            Settings::new(Codec::Rzip, 4),
            engine.as_ref(),
        )?;
        let write = t0.elapsed();
        let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
        let n_baskets = reader.meta().branches[0].baskets.len();
        let (_, read) = measure(|| reader.read_all().unwrap());
        table.row(vec![
            basket.to_string(),
            n_baskets.to_string(),
            format!("{:.3}", rep.compression_ratio()),
            ms(write),
            ms(read),
            (n_baskets * reader.n_branches()).to_string(),
        ]);
    }
    save_csv("ablation_basket_size", &table);
    Ok(format!(
        "## Ablation — basket size (write/read cost vs ratio vs task granularity)\n\n{}",
        table.render()
    ))
}

/// Drive a [`WindowController`] through a deterministic *virtual-time*
/// prefetch pipeline: a single-issue device queue (seek + bytes/bw per
/// coalesced cluster read, from the calibrated [`DeviceModel`]),
/// `workers` earliest-free decode units fed one task per basket, and
/// an in-order consumer whose stall feeds the real controller — the
/// read-side mirror of [`virtual_adaptive_trace`]. Same costs in →
/// same makespan out, so acceptance ratios are schedule-noise-free.
/// Returns (makespan, peak window target).
fn virtual_prefetch_makespan(
    policy: WindowPolicy,
    cluster_bytes: &[u64],
    n_branches: usize,
    model: &DeviceModel,
    decode: Duration,
    workers: usize,
) -> (Duration, usize) {
    let n = cluster_bytes.len();
    if n == 0 {
        return (Duration::ZERO, 1);
    }
    let mut controller = WindowController::new(policy);
    let fetch_cost = |bytes: u64| {
        model.seek + Duration::from_secs_f64(bytes as f64 / (model.read_mbps * 1e6))
    };
    let mut device_free = Duration::ZERO;
    let mut worker_free = vec![Duration::ZERO; workers.max(1)];
    let mut ready = vec![Duration::ZERO; n];
    let (mut submitted, mut consumed) = (0usize, 0usize);
    let mut t = Duration::ZERO;
    let mut cum_stall = Duration::ZERO;
    let mut cum_decode = Duration::ZERO;
    let mut peak = 1usize;
    while consumed < n {
        let target = controller.target().max(1);
        peak = peak.max(target);
        while submitted < n && submitted - consumed < target {
            // Coalesced fetch: one device op for the whole window.
            let start = device_free.max(t);
            let done = start + fetch_cost(cluster_bytes[submitted]);
            device_free = done;
            // Per-basket decode tasks on the earliest-free workers.
            let mut cluster_ready = done;
            for _ in 0..n_branches {
                let mut idx = 0;
                for (i, d) in worker_free.iter().enumerate() {
                    if *d < worker_free[idx] {
                        idx = i;
                    }
                }
                let fin = worker_free[idx].max(done) + decode;
                worker_free[idx] = fin;
                cluster_ready = cluster_ready.max(fin);
                cum_decode += decode;
            }
            ready[submitted] = cluster_ready;
            submitted += 1;
        }
        // In-order consumption; the wait is the exposed fetch stall.
        let r = ready[consumed];
        if r > t {
            cum_stall += r - t;
            t = r;
        }
        consumed += 1;
        controller.observe(cum_stall, cum_decode, 0);
    }
    (t, peak)
}

/// The no-prefetch baseline in the same virtual time: every basket is
/// its own device op (seek + transfer — concurrent per-basket tasks
/// interleave offsets, so sequentiality is lost), decode overlaps on
/// `workers` units. The makespan is whichever side is the bottleneck.
fn virtual_unprefetched_makespan(
    basket_bytes: &[u64],
    model: &DeviceModel,
    decode: Duration,
    workers: usize,
) -> Duration {
    if basket_bytes.is_empty() {
        return Duration::ZERO;
    }
    let transfer =
        |bytes: u64| Duration::from_secs_f64(bytes as f64 / (model.read_mbps * 1e6));
    let device_total: Duration =
        basket_bytes.iter().map(|&b| model.seek + transfer(b)).sum();
    let decode_total =
        decode.mul_f64(basket_bytes.len() as f64 / workers.max(1) as f64);
    let first_fetch = model.seek + transfer(basket_bytes[0]);
    device_total.max(first_fetch + decode_total)
}

/// Per-basket fetch+decompress+deserialise on an explicit pool — the
/// no-prefetch baseline the read-ahead experiment measures against.
/// Delegates to [`crate::coordinator::read::read_baskets_on_pool`] so
/// the decomposition and ordered reassembly are the product code's,
/// not a benchmark copy.
fn pooled_basket_read(
    file: &Arc<FileReader>,
    pool: &crate::imt::Pool,
) -> Result<Vec<ColumnData>> {
    let reader = TreeReader::open_first(file.clone())?;
    let selection: Vec<usize> = (0..reader.n_branches()).collect();
    crate::coordinator::read::read_baskets_on_pool(&reader, &selection, pool)
}

/// Shared calibration for the read-prefetch experiment and its
/// acceptance test: the synthesized source file (raw bytes + serial
/// baseline columns), per-cluster / per-basket stored sizes, and the
/// measured per-basket decode cost (best of 3) that feeds the
/// virtual-time pipeline.
struct PrefetchCalibration {
    src_bytes: Vec<u8>,
    serial_cols: Vec<ColumnData>,
    cluster_bytes: Vec<u64>,
    basket_bytes: Vec<u64>,
    decode_cost: Duration,
}

fn calibrate_prefetch(
    n_branches: usize,
    entries: usize,
    basket: usize,
    settings: Settings,
) -> Result<PrefetchCalibration> {
    let src = synthesize_flat_f32(n_branches, entries, basket, settings)?;
    let src_len = src.len()? as usize;
    let mut src_bytes = vec![0u8; src_len];
    src.read_at(0, &mut src_bytes)?;
    let src_reader = TreeReader::open_first(Arc::new(FileReader::open(src)?))?;
    let serial_cols = src_reader.read_all()?;
    let mut cluster_bytes = vec![0u64; src_reader.meta().branches[0].baskets.len()];
    let mut basket_bytes: Vec<u64> = Vec::new();
    for br in &src_reader.meta().branches {
        for (k, info) in br.baskets.iter().enumerate() {
            cluster_bytes[k] += info.comp_len as u64;
            basket_bytes.push(info.comp_len as u64);
        }
    }
    let decode_cost = {
        let raw = src_reader.fetch_raw(0, 0)?;
        let mut best = Duration::MAX;
        for _ in 0..3 {
            let (col, d) = measure(|| src_reader.decode(0, 0, &raw));
            col?;
            best = best.min(d);
        }
        best
    };
    Ok(PrefetchCalibration {
        src_bytes,
        serial_cols,
        cluster_bytes,
        basket_bytes,
        decode_cost,
    })
}

/// Read-prefetch experiment (BENCH_fig6.json) — the read-ahead cache
/// closing the read-path latency gap: device sweep (hdd / ssd / nvme /
/// mem) × window policy (none / coalesce-only / fixed-k / adaptive) ×
/// reader count.
///
/// Methodology (the fig1/fig3/fig5 recipe): per-basket decode cost is
/// measured for real; the policy sweep is scheduled deterministically
/// through [`virtual_prefetch_makespan`] over the calibrated device
/// models (8 virtual workers). "measured" rows run the real
/// [`crate::cache::ClusterStream`] against real [`SimDevice`]s (scaled
/// latencies), assert decode identity against the serial baseline,
/// and report the **coalescing factor** from [`SimDevice::device_stats`]
/// — device reads issued by the per-basket baseline vs the prefetcher.
pub fn read_prefetch(quick: bool) -> Result<String> {
    let n_branches = 8usize;
    let entries: usize = if quick { 16_384 } else { 32_768 };
    let basket = 1024usize;
    let settings = Settings::new(Codec::Lz4r, 2);
    let vworkers = 8usize;
    let time_scale = 0.01f64;

    // Source file, serial baseline, stored sizes + measured decode
    // cost — shared with the acceptance test.
    let cal = calibrate_prefetch(n_branches, entries, basket, settings)?;
    let PrefetchCalibration {
        src_bytes,
        serial_cols,
        cluster_bytes,
        basket_bytes,
        decode_cost,
    } = cal;
    let raw_bytes = (entries * n_branches * 4) as u64;

    let mut table = Table::new(&[
        "mode", "device", "policy", "readers", "wall_ms", "read_MBps", "device_reads",
        "coalesce_x", "window", "stall_ms",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    let n_clusters = cluster_bytes.len();
    let n_baskets = basket_bytes.len();

    let policies: Vec<(&str, Option<WindowPolicy>)> = vec![
        ("none", None),
        ("coalesce/w1", Some(WindowPolicy::None)),
        ("fixed/4", Some(WindowPolicy::Fixed(4))),
        ("fixed/8", Some(WindowPolicy::Fixed(8))),
        ("adaptive", Some(WindowPolicy::default())),
    ];
    let models: Vec<(&str, DeviceModel, f64)> = if quick {
        vec![("hdd", DeviceModel::hdd(), time_scale), ("mem", DeviceModel::tmpfs(), 0.0)]
    } else {
        vec![
            ("hdd", DeviceModel::hdd(), time_scale),
            ("ssd", DeviceModel::ssd(), time_scale),
            ("nvme", DeviceModel::nvme(), time_scale),
            ("mem", DeviceModel::tmpfs(), 0.0),
        ]
    };

    // Virtual sweep: calibrated device models, 8 workers, 1 reader.
    for (dev, model, _) in &models {
        for (name, policy) in &policies {
            let (wall, reads, window) = match policy {
                None => (
                    virtual_unprefetched_makespan(&basket_bytes, model, decode_cost, vworkers),
                    n_baskets,
                    "1".to_string(),
                ),
                Some(p) => {
                    let (wall, peak) = virtual_prefetch_makespan(
                        *p,
                        &cluster_bytes,
                        n_branches,
                        model,
                        decode_cost,
                        vworkers,
                    );
                    (wall, n_clusters, format!("<={peak}"))
                }
            };
            let mbps = raw_bytes as f64 / 1e6 / wall.as_secs_f64().max(1e-9);
            table.row(vec![
                "virtual".into(),
                dev.to_string(),
                name.to_string(),
                "1".into(),
                ms(wall),
                format!("{mbps:.1}"),
                reads.to_string(),
                format!("{:.1}", n_baskets as f64 / reads as f64),
                window,
                "-".into(),
            ]);
            bench_rows.push(BenchRow {
                label: format!("virt/{dev}/{name}"),
                threads: vworkers,
                wall_ms: wall.as_secs_f64() * 1e3,
                mbps,
            });
        }
    }

    // Measured sweep: real streams on real simulated devices. The
    // per-basket baseline and every policy must decode identically to
    // the serial columns; DeviceStats isolates each run's reads.
    let host = imt::num_cpus().clamp(2, 4);
    let pool = Arc::new(crate::imt::Pool::new(host));
    let reader_counts: Vec<usize> = vec![1, 2];
    for (dev, model, scale) in &models {
        let sim = Arc::new(SimDevice::new(*model, *scale));
        let be: BackendRef = sim.clone();
        be.write_at(0, &src_bytes)?;
        let file = Arc::new(FileReader::open(be.clone())?);
        let mut baseline_reads = 0u64;
        for (name, policy) in &policies {
            for &readers in &reader_counts {
                let session = Session::with_pool(
                    pool.clone(),
                    SessionConfig {
                        max_inflight_read_windows: 8 * readers,
                        ..Default::default()
                    },
                );
                let before = sim.device_stats();
                let t0 = Instant::now();
                // Across readers: the gating (max) stall and the union
                // of the window bands, so multi-reader rows stay
                // self-consistent.
                let mut stall = Duration::ZERO;
                let mut band: Option<(usize, usize)> = None;
                let results: Vec<Vec<ColumnData>> = match policy {
                    None => std::thread::scope(|s| {
                        let handles: Vec<_> = (0..readers)
                            .map(|_| s.spawn(|| pooled_basket_read(&file, &pool)))
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| {
                                h.join().map_err(|_| {
                                    Error::Sync("baseline reader panicked".into())
                                })?
                            })
                            .collect::<Result<Vec<_>>>()
                    })?,
                    Some(p) => {
                        let run = || -> Result<(Vec<ColumnData>, Duration, (usize, usize))> {
                            let reader = TreeReader::open_first(file.clone())?;
                            let mut stream = reader.stream_in_session(
                                &PrefetchOptions { window: *p, ..Default::default() },
                                &session,
                            )?;
                            let cols = stream.read_all_columns()?;
                            let st = stream.stats();
                            Ok((
                                cols,
                                st.fetch_stall,
                                (st.window.min_entries, st.window.max_entries),
                            ))
                        };
                        let outs: Vec<(Vec<ColumnData>, Duration, (usize, usize))> =
                            std::thread::scope(|s| {
                                let handles: Vec<_> =
                                    (0..readers).map(|_| s.spawn(&run)).collect();
                                handles
                                    .into_iter()
                                    .map(|h| {
                                        h.join().map_err(|_| {
                                            Error::Sync("stream reader panicked".into())
                                        })?
                                    })
                                    .collect::<Result<Vec<_>>>()
                            })?;
                        outs.into_iter()
                            .map(|(cols, st, b)| {
                                stall = stall.max(st);
                                band = Some(match band {
                                    Some((lo, hi)) => (lo.min(b.0), hi.max(b.1)),
                                    None => b,
                                });
                                cols
                            })
                            .collect()
                    }
                };
                let wall = t0.elapsed();
                let window = match band {
                    Some((lo, hi)) => format!("{lo}..{hi}"),
                    None => "1".to_string(),
                };
                let delta = sim.device_stats().since(&before);
                for cols in &results {
                    if *cols != serial_cols {
                        return Err(Error::Coordinator(format!(
                            "read_prefetch: {dev}/{name}/r{readers} decoded data \
                             diverged from the serial baseline"
                        )));
                    }
                }
                if policy.is_none() && readers == 1 {
                    baseline_reads = delta.reads;
                }
                let mbps = (raw_bytes * readers as u64) as f64
                    / 1e6
                    / wall.as_secs_f64().max(1e-9);
                table.row(vec![
                    "measured".into(),
                    dev.to_string(),
                    name.to_string(),
                    readers.to_string(),
                    ms(wall),
                    format!("{mbps:.1}"),
                    delta.reads.to_string(),
                    if delta.reads > 0 && baseline_reads > 0 {
                        format!(
                            "{:.1}",
                            baseline_reads as f64 * readers as f64 / delta.reads as f64
                        )
                    } else {
                        "-".into()
                    },
                    window,
                    ms(stall),
                ]);
                bench_rows.push(BenchRow {
                    label: format!("meas/{dev}/{name}/r{readers}"),
                    threads: host,
                    wall_ms: wall.as_secs_f64() * 1e3,
                    mbps,
                });
            }
        }
    }

    save_csv("fig6_read_prefetch", &table);
    save_bench_json("fig6", &bench_rows);
    save_observability("fig6", None);
    Ok(format!(
        "## Read-ahead cache — coalesced cluster prefetch across devices (Fig 6 companion)\n\
         (virtual rows: calibrated device models + measured decode costs through a \
         deterministic 8-worker pipeline driving the real window controller; measured rows: \
         real ClusterStreams on scaled simulated devices, decode identity asserted against \
         the serial baseline, device reads from DeviceStats)\n\n{}",
        table.render()
    ))
}

/// Remote-reads experiment (BENCH_fig7.json) — fault-tolerant
/// streaming from a simulated object store: fault-rate sweep × policy
/// (raw device / retry+deadline / retry+deadline+hedged reads).
///
/// Each cell streams the same pre-staged file through a real
/// [`crate::cache::ClusterStream`] over a seeded [`RemoteDevice`]
/// (heavy-tailed first-byte latency, bounded request slots, injected
/// transient faults — timeouts, short reads, 5xx blips, stuck
/// requests). The resilient policies must decode byte-identically to
/// the fault-free serial baseline; the raw device is *expected* to
/// fail once faults are injected and its row records that. Per-window
/// submit→decoded latencies come from
/// [`crate::cache::ClusterStream::window_latency`]; the p99 column
/// is the tail hedging exists to compress — a stuck request stalls a
/// retry-only window for its full deadline, while a hedge cuts in
/// after ~p99 and wins.
pub fn remote_reads(quick: bool) -> Result<String> {
    let n_branches = 6usize;
    let entries: usize = if quick { 8_192 } else { 16_384 };
    let basket = 512usize;
    let settings = Settings::new(Codec::Lz4r, 2);

    let cal = calibrate_prefetch(n_branches, entries, basket, settings)?;
    let src_bytes = cal.src_bytes;
    let serial_cols = cal.serial_cols;
    let raw_bytes = (entries * n_branches * 4) as u64;

    // Store model: sub-millisecond latencies at time_scale 1.0 keep
    // the sweep fast while preserving a heavy tail (p99/p50 ≈ 5) for
    // hedging to bite on. Stuck requests dominate the fault mix — the
    // flavour that separates the two resilient policies.
    let p50 = Duration::from_micros(250);
    let p99 = Duration::from_micros(1200);
    let hedge_after = p99 * 2;
    let deadline = p99 * 6;
    let fault_rates: Vec<f64> =
        if quick { vec![0.0, 0.08] } else { vec![0.0, 0.02, 0.12] };
    let policies: [(&str, bool, bool); 3] = [
        ("none", false, false),
        ("retry", true, false),
        ("retry+hedge", true, true),
    ];

    let make_device = |rate: f64| -> Result<Arc<RemoteDevice>> {
        let dev = Arc::new(RemoteDevice::new(
            RemoteConfig {
                read_mbps: 500.0,
                write_mbps: 500.0,
                first_byte_p50: p50,
                first_byte_p99: p99,
                request_slots: 8,
                seed: 11,
                fault_rate: rate,
                timeout_weight: 0.1,
                short_read_weight: 0.1,
                stuck_weight: 0.6,
                stuck_factor: 12.0,
                ..Default::default()
            },
            1.0,
        ));
        dev.preload(0, &src_bytes)?;
        Ok(dev)
    };
    let resilient_cfg = |hedge: bool| ResilientConfig {
        retry: RetryPolicy {
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(5),
            ..Default::default()
        },
        hedge: hedge.then_some(HedgePolicy::at_p99(hedge_after)),
        deadline: Some(deadline),
        ..Default::default()
    };

    let host = imt::num_cpus().clamp(2, 4);
    let pool = Arc::new(imt::Pool::new(host));
    let run = |be: BackendRef| -> Result<(
        Vec<ColumnData>,
        PrefetchStats,
        crate::metrics::HistSnapshot,
        Duration,
    )> {
        let file = Arc::new(FileReader::open(be)?);
        let reader = TreeReader::open_first(file)?;
        let session = Session::with_pool(
            pool.clone(),
            SessionConfig { max_inflight_read_windows: 8, ..Default::default() },
        );
        let t0 = Instant::now();
        let mut stream = reader.stream_in_session(&PrefetchOptions::fixed(8), &session)?;
        let cols = stream.read_all_columns()?;
        let wall = t0.elapsed();
        let st = stream.stats();
        let lats = stream.window_latency();
        Ok((cols, st, lats, wall))
    };

    let mut table = Table::new(&[
        "policy", "fault_rate", "status", "wall_ms", "win_p50_ms", "win_p99_ms",
        "retries", "hedges", "hedge_wins", "deadline_misses", "device_faults",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    for &rate in &fault_rates {
        for &(pname, resilient, hedge) in &policies {
            let dev = make_device(rate)?;
            let be: BackendRef = if resilient {
                Arc::new(ResilientBackend::new(dev.clone(), resilient_cfg(hedge)))
            } else {
                dev.clone()
            };
            match run(be) {
                Ok((cols, st, lats, wall)) => {
                    if cols != serial_cols {
                        return Err(Error::Coordinator(format!(
                            "remote_reads: {pname}@{rate} decoded data diverged from \
                             the fault-free serial baseline"
                        )));
                    }
                    let faults = dev.device_stats().faults;
                    let mbps = raw_bytes as f64 / 1e6 / wall.as_secs_f64().max(1e-9);
                    table.row(vec![
                        pname.into(),
                        format!("{rate:.2}"),
                        "ok".into(),
                        ms(wall),
                        ms(lats.p50()),
                        ms(lats.p99()),
                        st.retries.to_string(),
                        st.hedges.to_string(),
                        st.hedge_wins.to_string(),
                        st.deadline_misses.to_string(),
                        faults.to_string(),
                    ]);
                    bench_rows.push(BenchRow {
                        label: format!("remote/{pname}/f{rate:.2}"),
                        threads: host,
                        wall_ms: wall.as_secs_f64() * 1e3,
                        mbps,
                    });
                }
                Err(e) => {
                    // Only the bare device may fail, and only with
                    // faults injected — that row *is* the baseline the
                    // resilient policies are measured against.
                    if resilient || rate == 0.0 {
                        return Err(e);
                    }
                    table.row(vec![
                        pname.into(),
                        format!("{rate:.2}"),
                        "failed (no retry)".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "0".into(),
                        "0".into(),
                        "0".into(),
                        "0".into(),
                        dev.device_stats().faults.to_string(),
                    ]);
                }
            }
        }
    }

    save_csv("fig7_remote_reads", &table);
    save_bench_json("fig7", &bench_rows);
    save_observability("fig7", None);
    Ok(format!(
        "## Remote reads — retry, deadlines and hedged reads on a faulty object store \
         (Fig 7 companion)\n\
         (real ClusterStreams over a seeded RemoteDevice: lognormal first-byte latency \
         p50 {:.1} ms / p99 {:.1} ms, {} request slots, injected timeout/short-read/5xx/\
         stuck faults; resilient rows assert byte-identity to the fault-free serial \
         baseline; win_p99_ms is the per-window submit→decoded tail hedging compresses)\n\n{}",
        p50.as_secs_f64() * 1e3,
        p99.as_secs_f64() * 1e3,
        8,
        table.render()
    ))
}

/// Build the projection-pushdown comparison pair: the same 64-column
/// f32 dataset written twice — classic layout on the v1 wire (one
/// basket per branch per cluster) and the paged layout on the v3 wire
/// (per-column pages grouped column-major). Returns the two files'
/// bytes plus the schema. Shared by the fig9 harness and its
/// acceptance test so both measure exactly the same files.
fn build_projection_files(
    n_branches: usize,
    entries: usize,
    cluster: usize,
    page: usize,
    settings: Settings,
) -> Result<(Vec<u8>, Vec<u8>, Schema)> {
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::{Layout, TreeWriter};

    let schema = Schema::flat_f32("c", n_branches);
    let blocks: Vec<Vec<ColumnData>> = (0..entries.div_ceil(cluster))
        .map(|blk| {
            let mut rng = dataset::SplitMix::new(blk as u64 + 1);
            (0..n_branches)
                .map(|b| {
                    ColumnData::F32(
                        (0..cluster.min(entries - blk * cluster))
                            .map(|i| {
                                dataset::quantize(
                                    rng.uniform() * (b + 1) as f32 + (i % 31) as f32,
                                )
                            })
                            .collect(),
                    )
                })
                .collect()
        })
        .collect();

    let build = |version: u32, layout: Layout| -> Result<Vec<u8>> {
        use crate::storage::Backend;
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create_versioned(be.clone(), version)?);
        let sink = FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: cluster,
            compression: settings,
            flush: FlushMode::Serial,
            layout,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for block in &blocks {
            w.fill_columns(block)?;
        }
        let (sink, n, _) = w.close()?;
        let meta = sink.into_meta("events".into(), schema.clone(), n)?;
        fw.finish(&Directory { trees: vec![meta] })?;
        let mut bytes = vec![0u8; be.len()? as usize];
        be.read_at(0, &mut bytes)?;
        Ok(bytes)
    };
    let v1 = build(1, Layout::Classic)?;
    let v3 = build(3, Layout::Paged { page_entries: page })?;
    Ok((v1, v3, schema))
}

/// One measured fig9 cell: stage `file_bytes` on a zero-latency
/// simulated device, open it, then read `selection` (None = every
/// branch) through the prefetching read path. Returns the decoded
/// columns, the wall, the device bytes the *scan itself* read (the
/// one-time open/footer fetch is excluded — stats are snapshotted
/// after open) and the scan's device read count.
fn projection_scan(
    file_bytes: &[u8],
    selection: Option<Vec<usize>>,
) -> Result<(Vec<ColumnData>, Duration, u64, u64)> {
    use crate::coordinator::read::{read_columns, ReadOptions};
    let sim = Arc::new(SimDevice::new(DeviceModel::tmpfs(), 0.0));
    let be: BackendRef = sim.clone();
    be.write_at(0, file_bytes)?;
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
    let before = sim.device_stats();
    let t0 = Instant::now();
    let rep = read_columns(
        &reader,
        &ReadOptions {
            branches: selection,
            prefetch: Some(PrefetchOptions::default()),
            ..Default::default()
        },
    )?;
    let wall = t0.elapsed();
    let delta = sim.device_stats().since(&before);
    Ok((rep.columns, wall, delta.bytes_read, delta.reads))
}

/// Figure 9 (BENCH_fig9.json) — projection pushdown on the paged v3
/// columnar layout: a 3-of-64-column scan on per-column pages versus
/// the v1 classic full-cluster decode.
///
/// Both files hold the same data. Every cell is a real prefetched read
/// on a zero-latency simulated device, so the wall is decode-bound and
/// the byte column is the fetch plan's actual device traffic
/// ([`DeviceStats`]-isolated, open/footer excluded). The paper-shaped
/// claim: on v3 the unselected 61 columns' pages never leave the
/// device, so the projected scan reads a few percent of the bytes and
/// decodes only what the analysis asked for; v1's classic layout also
/// stores columns separately, but its full decode — what a
/// whole-event analysis pays — anchors the comparison.
pub fn page_projection(quick: bool) -> Result<String> {
    let n_branches = 64usize;
    let entries: usize = if quick { 8_192 } else { 32_768 };
    let cluster = 2048usize;
    let page = 512usize;
    let settings = Settings::new(Codec::Lz4r, 3);
    let projection = vec![5usize, 17, 42];

    let (v1, v3, _schema) =
        build_projection_files(n_branches, entries, cluster, page, settings)?;
    let raw_selected = (entries * projection.len() * 4) as u64;
    let raw_full = (entries * n_branches * 4) as u64;

    let mut table = Table::new(&[
        "file", "scan", "wall_ms", "device_KB", "device_reads", "decode_MBps", "vs_v1_full",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    let cells: Vec<(&str, &Vec<u8>, Option<Vec<usize>>, u64)> = vec![
        ("v1-classic", &v1, None, raw_full),
        ("v1-classic", &v1, Some(projection.clone()), raw_selected),
        ("v3-paged", &v3, None, raw_full),
        ("v3-paged", &v3, Some(projection.clone()), raw_selected),
    ];
    let mut baseline: Option<(Vec<ColumnData>, Duration, u64)> = None;
    for (file, bytes, sel, raw) in cells {
        let (cols, wall, dev_bytes, dev_reads) = projection_scan(bytes, sel.clone())?;
        // Decode identity across layouts and selections: each selected
        // column must match the v1 full decode, entry for entry.
        match (&baseline, &sel) {
            (None, _) => baseline = Some((cols, wall, dev_bytes)),
            (Some((base, _, _)), sel) => {
                let picks: Vec<usize> =
                    sel.clone().unwrap_or_else(|| (0..n_branches).collect());
                for (i, &b) in picks.iter().enumerate() {
                    if cols[i] != base[b] {
                        return Err(Error::Coordinator(format!(
                            "page_projection: {file} column {b} diverged from the \
                             v1 full decode"
                        )));
                    }
                }
            }
        }
        let (_, base_wall, base_bytes) = baseline.as_ref().expect("baseline set");
        let scan = if sel.is_some() { format!("projected-{}", projection.len()) } else { "full".into() };
        let mbps = raw as f64 / 1e6 / wall.as_secs_f64().max(1e-9);
        table.row(vec![
            file.into(),
            scan.clone(),
            ms(wall),
            format!("{:.1}", dev_bytes as f64 / 1e3),
            dev_reads.to_string(),
            format!("{mbps:.1}"),
            format!(
                "{:.2}x wall, {:.1}% bytes",
                base_wall.as_secs_f64() / wall.as_secs_f64().max(1e-9),
                dev_bytes as f64 * 100.0 / *base_bytes as f64
            ),
        ]);
        bench_rows.push(BenchRow {
            label: format!("{file}/{scan}"),
            threads: 1,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps,
        });
    }
    save_csv("fig9_page_projection", &table);
    save_bench_json("fig9", &bench_rows);
    // Trace the experiment's own paged (v3) file rather than a stand-in.
    let obs: BackendRef = Arc::new(MemBackend::new());
    if obs.write_at(0, &v3).is_ok() {
        save_observability("fig9", Some(obs));
    }
    Ok(format!(
        "## Figure 9 — projection pushdown on the paged columnar layout (format v3)\n\
         (real prefetched reads on a zero-latency simulated device: wall is \
         decode-bound, device bytes/reads are the fetch plan's actual traffic with \
         the one-time footer fetch excluded; decode identity asserted against the \
         v1 full decode)\n\n{}",
        table.render()
    ))
}

/// Build the fig10 chain: `files` same-schema wire-v4 files of
/// `n_branches` f32 columns and `entries` rows each. Branch 0 carries
/// the *chain-global* entry index (exactly representable in f32 at
/// these sizes), so every cluster's zone map is a tight disjoint band
/// and a range predicate on it prunes with cluster precision; the
/// other branches carry seeded noise. Returns each file's bytes.
fn build_chain_files(
    files: usize,
    entries: usize,
    cluster: usize,
    n_branches: usize,
    settings: Settings,
) -> Result<Vec<Vec<u8>>> {
    use crate::format::writer::FileWriter;
    use crate::format::Directory;
    use crate::storage::Backend;
    use crate::tree::sink::FileSink;
    use crate::tree::writer::TreeWriter;

    let schema = Schema::flat_f32("c", n_branches);
    let mut out = Vec::with_capacity(files);
    for file in 0..files {
        let base = (file * entries) as u64;
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be.clone())?);
        let sink = FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: cluster,
            compression: settings,
            flush: FlushMode::Serial,
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for blk in 0..entries.div_ceil(cluster) {
            let rows = cluster.min(entries - blk * cluster);
            let mut rng = dataset::SplitMix::new(((file as u64) << 20) | blk as u64);
            let block: Vec<ColumnData> = (0..n_branches)
                .map(|b| {
                    ColumnData::F32(
                        (0..rows)
                            .map(|i| {
                                if b == 0 {
                                    (base + (blk * cluster + i) as u64) as f32
                                } else {
                                    dataset::quantize(rng.uniform() * (b + 1) as f32)
                                }
                            })
                            .collect(),
                    )
                })
                .collect();
            w.fill_columns(&block)?;
        }
        let (sink, n, _) = w.close()?;
        let meta = sink.into_meta("events".into(), schema.clone(), n)?;
        fw.finish(&Directory { trees: vec![meta] })?;
        let mut bytes = vec![0u8; be.len()? as usize];
        be.read_at(0, &mut bytes)?;
        out.push(bytes);
    }
    Ok(out)
}

/// One measured fig10 cell: stage every file of the chain on its own
/// zero-latency simulated device and scan them as one
/// [`crate::framework::chain::Chain`], optionally with a pushed-down
/// predicate. Returns the concatenated delivered columns (when
/// `collect`), the wall, the chain report, and the summed device
/// bytes/reads of the whole scan (per-file footer opens included —
/// a chained analysis pays them too).
fn chain_scan_cell(
    file_bytes: &[Vec<u8>],
    selection: Option<Vec<usize>>,
    predicate: Option<crate::cache::Predicate>,
    collect: bool,
) -> Result<(
    Vec<ColumnData>,
    Duration,
    crate::framework::chain::ChainReport,
    u64,
    u64,
)> {
    use crate::framework::chain::Chain;
    use crate::storage::Backend;
    let mut sims = Vec::with_capacity(file_bytes.len());
    let mut backends: Vec<BackendRef> = Vec::with_capacity(file_bytes.len());
    for bytes in file_bytes {
        let sim = Arc::new(SimDevice::new(DeviceModel::tmpfs(), 0.0));
        sim.write_at(0, bytes)?;
        backends.push(sim.clone());
        sims.push(sim);
    }
    let before: Vec<_> = sims.iter().map(|s| s.device_stats()).collect();
    let chain = Chain::new(backends);
    let opts = PrefetchOptions { branches: selection, ..Default::default() };
    let mut parts: Vec<Vec<ColumnData>> = Vec::new();
    let t0 = Instant::now();
    let gather = |b: &crate::framework::chain::Batch, parts: &mut Vec<Vec<ColumnData>>| {
        if collect {
            parts.push(b.columns.clone());
        }
    };
    let report = match predicate {
        None => chain.scan(&opts, |b| gather(b, &mut parts))?,
        Some(p) => chain.scan_where(p, &opts, |b| gather(b, &mut parts))?,
    };
    let wall = t0.elapsed();
    let (mut dev_bytes, mut dev_reads) = (0u64, 0u64);
    for (sim, b4) in sims.iter().zip(&before) {
        let delta = sim.device_stats().since(b4);
        dev_bytes += delta.bytes_read;
        dev_reads += delta.reads;
    }
    let mut cols: Vec<ColumnData> = Vec::new();
    for part in parts {
        if cols.is_empty() {
            cols = part;
            continue;
        }
        for (acc, col) in cols.iter_mut().zip(part.iter()) {
            acc.append(col)?;
        }
    }
    Ok((cols, wall, report, dev_bytes, dev_reads))
}

/// Keep only the rows of `cols` whose column `slot` value is `>=
/// cutoff` — the reference row filter the pruned scan must match.
fn keep_rows_ge(cols: &[ColumnData], slot: usize, cutoff: f64) -> Result<Vec<ColumnData>> {
    use crate::serial::value::Value;
    let mut want: Vec<ColumnData> =
        cols.iter().map(|c| ColumnData::new(c.column_type())).collect();
    for i in 0..cols[slot].len() {
        let keep = match cols[slot].get(i) {
            Some(Value::F32(v)) => f64::from(v) >= cutoff,
            _ => false,
        };
        if keep {
            for (w, c) in want.iter_mut().zip(cols) {
                w.push(c.get(i).expect("row in range"))?;
            }
        }
    }
    Ok(want)
}

/// Figure 10 (BENCH_fig10.json) — chained dataset scan with zone-map
/// predicate pushdown (wire v4): a 100-file chain of 64-column files
/// scanned as one stream, 3-of-64 projected and full, with a range
/// predicate selecting the top ~5% of rows on and off.
///
/// Branch 0 is chain-global monotone, so per-cluster zone maps make
/// the predicate prunable with cluster precision: with the predicate
/// on, ~95% of the *selected* pages never leave the device. Every cell
/// is a real chained prefetched scan on zero-latency simulated
/// devices; device bytes include the per-file footer opens (a chain
/// pays them either way), while the `vs_no_pred` column uses the fetch
/// plan's own footer-free accounting. The pruned+filtered rows are
/// asserted identical to the unpruned scan filtered row by row.
pub fn chain_scan(quick: bool) -> Result<String> {
    use crate::cache::Predicate;
    let n_branches = 64usize;
    let files = if quick { 12 } else { 100 };
    let entries = if quick { 1_024 } else { 4_096 };
    let cluster = if quick { 256 } else { 512 };
    let settings = Settings::new(Codec::Lz4r, 3);
    let projection = vec![0usize, 17, 42];
    let cutoff = (files * entries) as f64 * 0.95;
    let pred = Predicate::ge(0, cutoff);

    let chain_files = build_chain_files(files, entries, cluster, n_branches, settings)?;

    let mut table = Table::new(&[
        "scan", "predicate", "wall_ms", "device_KB", "device_reads", "rows", "pages_pruned",
        "vs_no_pred",
    ]);
    let mut bench_rows: Vec<BenchRow> = Vec::new();
    let cells: Vec<(&str, Option<Vec<usize>>, bool, bool)> = vec![
        ("projected-3", Some(projection.clone()), false, true),
        ("projected-3", Some(projection.clone()), true, true),
        ("full-64", None, false, false),
        ("full-64", None, true, false),
    ];
    let mut unpruned: Option<(Vec<ColumnData>, u64)> = None;
    for (scan, sel, with_pred, collect) in cells {
        let (cols, wall, rep, dev_bytes, dev_reads) = chain_scan_cell(
            &chain_files,
            sel.clone(),
            with_pred.then_some(pred),
            collect,
        )?;
        let n_cols = sel.as_ref().map_or(n_branches, |s| s.len());
        let mut vs = "-".to_string();
        if collect && !with_pred {
            unpruned = Some((cols, rep.prefetch.bytes_selected));
        } else if collect && with_pred {
            let (base, base_bytes) =
                unpruned.as_ref().expect("the unpruned projected cell runs first");
            // The acceptance identity: pruned+filtered rows equal the
            // unpruned scan filtered row by row.
            if cols != keep_rows_ge(base, 0, cutoff)? {
                return Err(Error::Coordinator(
                    "chain_scan: pruned scan diverged from the row-filtered \
                     unpruned scan"
                        .into(),
                ));
            }
            vs = format!(
                "{:.1}% plan bytes",
                rep.prefetch.bytes_selected as f64 * 100.0 / (*base_bytes).max(1) as f64
            );
        }
        let raw = rep.rows * n_cols as u64 * 4;
        let mbps = raw as f64 / 1e6 / wall.as_secs_f64().max(1e-9);
        table.row(vec![
            scan.into(),
            if with_pred { format!("x >= {cutoff:.0}") } else { "off".into() },
            ms(wall),
            format!("{:.1}", dev_bytes as f64 / 1e3),
            dev_reads.to_string(),
            rep.rows.to_string(),
            rep.prefetch.pages_pruned.to_string(),
            vs,
        ]);
        bench_rows.push(BenchRow {
            label: format!("{scan}/{}", if with_pred { "pred-on" } else { "pred-off" }),
            threads: 1,
            wall_ms: wall.as_secs_f64() * 1e3,
            mbps,
        });
    }
    save_csv("fig10_chain_scan", &table);
    save_bench_json("fig10", &bench_rows);
    // Trace one real file from the chain rather than a stand-in.
    if let Some(bytes) = chain_files.first() {
        let obs: BackendRef = Arc::new(MemBackend::new());
        if obs.write_at(0, bytes).is_ok() {
            save_observability("fig10", Some(obs));
        }
    }
    Ok(format!(
        "## Figure 10 — chained dataset scan with zone-map predicate pushdown (format v4)\n\
         ({files} files scanned as one chain through a shared session with cross-file \
         read-ahead; the predicate selects the top ~5% of rows on a chain-global \
         monotone branch, so zone maps prune ~95% of the selected pages before any \
         fetch; pruned+filtered rows asserted identical to the unpruned scan filtered \
         row by row)\n\n{}",
        table.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Quick smoke runs of each harness: integration tests proving every
    // figure's pipeline composes end to end.

    #[test]
    fn fig1_smoke() {
        let s = fig1(true).unwrap();
        assert!(s.contains("GenSim") && s.contains("xAOD"));
    }

    #[test]
    fn fig2_smoke() {
        let s = fig2(true).unwrap();
        assert!(s.contains("decompress"));
    }

    /// Fig 8 smoke: the codec harness runs end to end — which also
    /// executes its inline scalar-vs-wide byte-identity assertions and
    /// writes the frontier rows.
    #[test]
    fn codec_bench_smoke() {
        let s = codec_bench(true).unwrap();
        assert!(s.contains("Codec characterisation"), "{s}");
    }

    /// Acceptance (ISSUE 7 frontier claim): on a tree whose columns
    /// want different codecs, per-column selection is Pareto-undominated
    /// by every global codec on the (file size, compression CPU) plane:
    /// it stores fewer bytes than the raw and fast-LZ globals, and
    /// spends less compression CPU than the dense global. The mixed
    /// data is seeded, the flush is serial, and the margins are large
    /// (the int column entropy-codes ~3x denser than byte-LZ; the noise
    /// float column makes rzip-everywhere pay for nothing), so the
    /// assertions hold under timing jitter in the selector's probes.
    #[test]
    fn per_column_selection_lands_on_the_codec_frontier() {
        // 32 baskets per column: 10 probe, 22 committed, so the probe
        // overhead (two raw baskets per column among the probes) stays
        // small against the committed codec's savings.
        let (schema, blocks) = mixed_codec_tree(65_536);
        let run = |compression: Settings, selection: CodecSelection| {
            let be: BackendRef = Arc::new(MemBackend::new());
            let cfg = WriterConfig {
                basket_entries: 2048,
                compression,
                selection,
                flush: FlushMode::Serial,
                ..Default::default()
            };
            write_blocks(be, schema.clone(), "events", cfg, blocks.clone()).unwrap()
        };
        let sel = run(
            Settings::new(Codec::Lz4r, 4),
            CodecSelection::PerColumn(SelectConfig::default()),
        );
        let none = run(Settings::uncompressed(), CodecSelection::Global);
        let lz4 = run(Settings::new(Codec::Lz4r, 4), CodecSelection::Global);
        let rzip = run(Settings::new(Codec::Rzip, 6), CodecSelection::Global);

        assert_eq!(sel.selection.columns, 3);
        assert_eq!(sel.selection.committed, 3, "every column must commit a codec");
        assert!(
            sel.stored_bytes < none.stored_bytes,
            "selection ({}) must store less than uncompressed ({})",
            sel.stored_bytes,
            none.stored_bytes,
        );
        assert!(
            sel.stored_bytes < lz4.stored_bytes,
            "selection ({}) must store less than global lz4r ({})",
            sel.stored_bytes,
            lz4.stored_bytes,
        );
        assert!(
            sel.compress_time < rzip.compress_time,
            "selection ({:?}) must spend less compression CPU than global rzip ({:?})",
            sel.compress_time,
            rzip.compress_time,
        );
        // The full Pareto check: no global codec both stores fewer
        // bytes AND spends less compression CPU than the selector.
        for (name, g) in [("none", &none), ("lz4r", &lz4), ("rzip", &rzip)] {
            assert!(
                !(g.stored_bytes <= sel.stored_bytes
                    && g.compress_time <= sel.compress_time),
                "global {name} dominates per-column selection",
            );
        }
    }

    /// Acceptance: a 4-branch tree on 8 threads gains >= 1.5x from
    /// basket-granularity tasks over the per-branch baseline (the
    /// branch decomposition idles half the workers; baskets fill them).
    /// Costs are measured for real, schedules are deterministic.
    #[test]
    fn narrow_tree_basket_granularity_beats_branch_granularity() {
        let be =
            synthesize_flat_f32(4, 16_384, 1024, Settings::new(Codec::Rzip, 4)).unwrap();
        let reader =
            TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let mut branch_graph = Graph::new();
        let mut basket_graph = Graph::new();
        for b in 0..reader.n_branches() {
            let mut branch_cost = Duration::ZERO;
            for k in 0..reader.meta().branches[b].baskets.len() {
                let (_, cost) = measure(|| reader.read_basket(b, k).unwrap());
                basket_graph.pool(SpanKind::Decompress, cost, vec![]);
                branch_cost += cost;
            }
            branch_graph.pool(SpanKind::Decompress, branch_cost, vec![]);
        }
        let branch = simulate(&branch_graph, 8).makespan.as_secs_f64();
        let basket = simulate(&basket_graph, 8).makespan.as_secs_f64();
        assert!(
            branch >= 1.5 * basket,
            "expected >= 1.5x from basket granularity: branch {:.3} ms vs basket {:.3} ms",
            branch * 1e3,
            basket * 1e3,
        );
    }

    #[test]
    fn fig9_smoke() {
        let s = page_projection(true).unwrap();
        assert!(s.contains("v3-paged") && s.contains("projected-3"), "{s}");
    }

    /// Acceptance (ISSUE 8 tentpole): a projected 3-of-64-column scan
    /// on the paged v3 layout completes >= 3x faster than the v1
    /// classic full-cluster decode and reads <= 10% of its device
    /// bytes. Decode identity across the two layouts is asserted
    /// column for column. The wall margin is huge by construction (3
    /// vs 64 columns decoded on a zero-latency device), so the >= 3x
    /// bound holds under timing jitter; the byte bound is
    /// deterministic (DeviceStats counts the fetch plan's traffic).
    #[test]
    fn projected_v3_scan_beats_v1_full_decode() {
        let (v1, v3, _) =
            build_projection_files(64, 8_192, 2_048, 512, Settings::new(Codec::Lz4r, 3))
                .unwrap();
        let projection = vec![5usize, 17, 42];
        let (full_cols, full_wall, full_bytes, _) = projection_scan(&v1, None).unwrap();
        let (proj_cols, proj_wall, proj_bytes, _) =
            projection_scan(&v3, Some(projection.clone())).unwrap();
        for (i, &b) in projection.iter().enumerate() {
            assert_eq!(proj_cols[i], full_cols[b], "column {b} must decode identically");
        }
        assert!(
            proj_bytes * 10 <= full_bytes,
            "projected v3 scan must read <= 10% of the v1 full decode's bytes: \
             {proj_bytes} vs {full_bytes}"
        );
        assert!(
            full_wall.as_secs_f64() >= 3.0 * proj_wall.as_secs_f64(),
            "projected v3 scan must be >= 3x faster than the v1 full decode: \
             {:.3} ms vs {:.3} ms",
            proj_wall.as_secs_f64() * 1e3,
            full_wall.as_secs_f64() * 1e3,
        );
    }

    /// Fig 10 smoke: the chained-scan harness composes end to end —
    /// which also executes its inline pruned-vs-filtered identity
    /// assertion across all four cells.
    #[test]
    fn fig10_smoke() {
        let s = chain_scan(true).unwrap();
        assert!(s.contains("Figure 10") && s.contains("projected-3"), "{s}");
    }

    /// Acceptance (ISSUE 9 tentpole): over a chain whose predicate
    /// selects the tail ~5% of a monotone branch, zone-map pushdown
    /// prunes the excluded clusters of every selected branch and cuts
    /// the plan's fetched bytes near-proportionally (<= 15% here: 2 of
    /// 24 clusters survive, and the accounting partition pins the
    /// rest), while the delivered rows are identical to row-filtering
    /// the unpruned scan.
    #[test]
    fn chained_predicate_scan_prunes_near_proportionally() {
        use crate::cache::Predicate;
        let files =
            build_chain_files(6, 1_024, 256, 8, Settings::uncompressed()).unwrap();
        let cutoff = (6 * 1_024) as f64 * 0.95;
        let sel = vec![0usize, 3, 5];
        let (base, _, rep0, _, _) =
            chain_scan_cell(&files, Some(sel.clone()), None, true).unwrap();
        let (pruned, _, rep1, _, _) = chain_scan_cell(
            &files,
            Some(sel.clone()),
            Some(Predicate::ge(0, cutoff)),
            true,
        )
        .unwrap();
        assert_eq!(pruned, keep_rows_ge(&base, 0, cutoff).unwrap());
        // 6 files x 4 clusters = 24 clusters; the cutoff (5836.8) keeps
        // the last two zones [5632,5887] and [5888,6143]: 22 pruned per
        // selected branch.
        assert_eq!(rep1.prefetch.pages_pruned, 22 * sel.len() as u64);
        assert!(
            rep1.prefetch.bytes_selected * 100 <= rep0.prefetch.bytes_selected * 15,
            "pruned plan must fetch <= 15% of the unpruned bytes: {} vs {}",
            rep1.prefetch.bytes_selected,
            rep0.prefetch.bytes_selected
        );
        assert_eq!(
            rep1.prefetch.bytes_selected
                + rep1.prefetch.bytes_pruned
                + rep1.prefetch.bytes_skipped,
            rep0.prefetch.bytes_selected + rep0.prefetch.bytes_skipped,
            "selected + pruned + skipped must partition the chain's stored bytes"
        );
    }

    #[test]
    fn fig3_smoke() {
        let s = fig3(true).unwrap();
        assert!(s.contains("imt-on") && s.contains("no-output"));
    }

    #[test]
    fn write_scaling_smoke() {
        let s = write_scaling(true).unwrap();
        assert!(s.contains("pipelined") && s.contains("measured"), "{s}");
        assert!(s.contains("fat1"), "{s}");
    }

    /// Acceptance (the write-side mirror of the read test above): a
    /// narrow 4-branch tree flushed on 8 workers gains >= 1.5x from
    /// the pipelined block-granularity flush over the per-branch
    /// synchronous flush — sync caps at min(branches, T) inside each
    /// flush *and* re-stalls the producer at every cluster boundary,
    /// while the pipeline keeps all 8 workers fed across clusters.
    /// Costs are measured for real, schedules are deterministic.
    #[test]
    fn narrow_tree_pipelined_flush_beats_synchronous_flush() {
        let basket = 1024usize;
        let n_branches = 4usize;
        let n_clusters = 8usize;
        let settings = Settings::new(Codec::Rzip, 4);
        let mut rng = dataset::SplitMix::new(5);
        let mut sync_graph = Graph::new();
        let mut pipe_graph = Graph::new();
        let mut prev: Vec<usize> = Vec::new();
        for _ in 0..n_clusters {
            let mut cur = Vec::new();
            for b in 0..n_branches {
                let col = ColumnData::F32(
                    (0..basket)
                        .map(|i| rng.uniform() * (b + 1) as f32 + (i % 13) as f32)
                        .collect(),
                );
                let (_, cost) = measure(|| {
                    let raw = col.encode();
                    compress::compress(settings, &raw)
                });
                // sync: every basket of cluster c gates all of c+1
                cur.push(sync_graph.pool(SpanKind::Compress, cost, prev.clone()));
                // pipelined: baskets across clusters are independent
                pipe_graph.pool(SpanKind::Compress, cost, vec![]);
            }
            prev = cur;
        }
        let sync = simulate(&sync_graph, 8).makespan.as_secs_f64();
        let pipe = simulate(&pipe_graph, 8).makespan.as_secs_f64();
        assert!(
            sync >= 1.5 * pipe,
            "expected >= 1.5x from pipelined block-granularity flush: \
             sync {:.3} ms vs pipelined {:.3} ms",
            sync * 1e3,
            pipe * 1e3,
        );
    }

    #[test]
    fn multi_writer_smoke() {
        let s = multi_writer(true).unwrap();
        assert!(s.contains("session") && s.contains("solo-seq"), "{s}");
        assert!(s.contains("measured"), "{s}");
    }

    /// Acceptance (ISSUE 3): 4 concurrent writers sharing one session
    /// on 8 workers achieve >= 2.5x the aggregate throughput of the
    /// same 4 writers run one-after-another, and every output is
    /// byte-identical to its solo run. Producer and per-basket costs
    /// are measured for real; the 8-worker schedule is deterministic
    /// ([`crate::simsched`], the same methodology as the fig1/fig3
    /// acceptance tests); byte-identity is asserted on real runs over
    /// a real shared pool.
    #[test]
    fn four_shared_writers_beat_sequential_writers_on_eight_workers() {
        let basket = 1024usize;
        let n_branches = 2usize;
        let clusters = 8usize;
        let n_writers = 4usize;
        let settings = Settings::new(Codec::Lz4r, 3);
        let gen_cluster = |w: usize, c: usize| -> Vec<ColumnData> {
            let mut rng = dataset::SplitMix::new(((w as u64) << 20) | (c as u64 + 1));
            (0..n_branches)
                .map(|b| {
                    ColumnData::F32(
                        (0..basket)
                            .map(|i| rng.uniform() * (b + 1) as f32 + (i % 19) as f32)
                            .collect(),
                    )
                })
                .collect()
        };

        // -- throughput: measured costs, deterministic 8-worker schedule
        let (_, gen_cost) = measure(|| gen_cluster(0, 0));
        let producer_cost = gen_cost * 9; // generate + 8x reco stand-in
        let mut costs: Vec<Vec<Duration>> = Vec::new();
        for c in 0..clusters {
            let cols = gen_cluster(0, c);
            costs.push(
                cols.iter()
                    .map(|col| {
                        measure(|| {
                            let raw = col.encode();
                            compress::compress(settings, &raw)
                        })
                        .1
                    })
                    .collect(),
            );
        }
        let add_writer = |g: &mut Graph, w: usize| {
            let unit = format!("writer-{w}");
            let mut prev: Option<usize> = None;
            for per_branch in &costs {
                let deps: Vec<usize> = prev.into_iter().collect();
                let p = g.named(&unit, SpanKind::Generate, producer_cost, deps);
                prev = Some(p);
                for &c in per_branch {
                    g.pool(SpanKind::Compress, c, vec![p]);
                }
            }
        };
        let mut solo_sum = Duration::ZERO;
        for w in 0..n_writers {
            let mut g = Graph::new();
            add_writer(&mut g, w);
            solo_sum += simulate(&g, 8).makespan;
        }
        let mut g = Graph::new();
        for w in 0..n_writers {
            add_writer(&mut g, w);
        }
        let shared = simulate(&g, 8).makespan;
        assert!(
            solo_sum.as_secs_f64() >= 2.5 * shared.as_secs_f64(),
            "expected >= 2.5x aggregate throughput from the shared session: \
             sequential {:.3} ms vs shared {:.3} ms ({:.2}x)",
            solo_sum.as_secs_f64() * 1e3,
            shared.as_secs_f64() * 1e3,
            solo_sum.as_secs_f64() / shared.as_secs_f64(),
        );

        // -- byte identity: real concurrent run under one shared session
        use crate::coordinator::write::{write_files, WriteJob};
        use crate::session::{Session, SessionConfig};
        use crate::storage::Backend;
        let schema = Schema::flat_f32("v", n_branches);
        let cfg = |flush: FlushMode| WriterConfig {
            basket_entries: basket,
            compression: settings,
            flush,
            granularity: FlushGranularity::Block,
            max_inflight_clusters: 2,
            ..Default::default()
        };
        let dump = |be: &BackendRef| {
            let mut bytes = vec![0u8; be.len().unwrap() as usize];
            be.read_at(0, &mut bytes).unwrap();
            bytes
        };
        let serial_bytes: Vec<Vec<u8>> = (0..n_writers)
            .map(|w| {
                let be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
                write_blocks(
                    be.clone(),
                    schema.clone(),
                    "events",
                    cfg(FlushMode::Serial),
                    (0..clusters).map(|c| gen_cluster(w, c)).collect::<Vec<_>>(),
                )
                .unwrap();
                dump(&be)
            })
            .collect();
        let pool = Arc::new(crate::imt::Pool::new(8));
        let session = Session::with_pool(pool, SessionConfig::for_writers(n_writers, 2));
        let backends: Vec<BackendRef> = (0..n_writers)
            .map(|_| Arc::new(crate::storage::mem::MemBackend::new()) as BackendRef)
            .collect();
        let jobs: Vec<WriteJob> = backends
            .iter()
            .enumerate()
            .map(|(w, be)| WriteJob {
                backend: be.clone(),
                schema: schema.clone(),
                name: "events".into(),
                config: cfg(FlushMode::Pipelined),
                blocks: (0..clusters).map(|c| gen_cluster(w, c)).collect(),
            })
            .collect();
        write_files(&session, jobs).unwrap();
        for (w, be) in backends.iter().enumerate() {
            assert_eq!(
                dump(be),
                serial_bytes[w],
                "writer {w}: shared-session file diverged from its serial bytes"
            );
        }
    }

    #[test]
    fn adaptive_sizing_smoke() {
        let s = adaptive_sizing(true).unwrap();
        assert!(s.contains("adaptive") && s.contains("fixed"), "{s}");
        assert!(s.contains("measured"), "{s}");
    }

    /// Acceptance (ISSUE 4): a narrow fast producer on 8 workers. The
    /// adaptive sizer — started at the stock default size (4096),
    /// mid-band — must reach ≥ 1.2× the throughput of the worst fixed size and
    /// ≥ 0.95× of the best fixed size in the sweep, with
    /// entry-identical decoded output. Per-size producer and
    /// serialise+compress costs are measured for real (rzip's fixed
    /// per-call setup is what makes tiny clusters expensive); the
    /// 8-worker schedules are deterministic ([`crate::simsched`]), and
    /// the adaptive trace comes from the real [`ClusterSizer`] driven
    /// through the deterministic virtual-time pipeline — the same
    /// methodology as the fig1/fig3/fig4 acceptance tests.
    #[test]
    fn adaptive_sizing_beats_fixed_for_narrow_fast_producer() {
        let n_branches = 2usize;
        let entries = 65_536usize;
        let settings = Settings::new(Codec::Rzip, 4);
        let (min_c, max_c) = (128usize, 16_384usize);
        let ladder: Vec<usize> = std::iter::successors(Some(min_c), |c| Some(c * 2))
            .take_while(|c| *c <= max_c)
            .collect();
        let costs = measure_size_costs(&ladder, n_branches, settings);

        let fixed_makespan = |c: usize| -> f64 {
            let mut sizes = vec![c; entries / c];
            if entries % c > 0 {
                sizes.push(entries % c);
            }
            simulate(&sizing_graph(&sizes, &costs, n_branches), 8).makespan.as_secs_f64()
        };
        let fixed: Vec<(usize, f64)> = ladder.iter().map(|&c| (c, fixed_makespan(c))).collect();
        let (worst_c, worst) = fixed
            .iter()
            .copied()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        let (best_c, best) = fixed
            .iter()
            .copied()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();

        let cfg = AdaptiveConfig {
            min_entries: min_c,
            max_entries: max_c,
            hysteresis: 1,
            warmup: 2,
            ..Default::default()
        };
        // Start at the stock default, exactly like the harness row.
        let trace =
            virtual_adaptive_trace(entries, 4096usize.clamp(min_c, max_c), cfg, 8, 4, &costs, n_branches);
        assert_eq!(trace.iter().sum::<usize>(), entries, "trace covers every entry");
        let adaptive =
            simulate(&sizing_graph(&trace, &costs, n_branches), 8).makespan.as_secs_f64();

        assert!(
            worst >= 1.2 * adaptive,
            "adaptive must be >= 1.2x the worst fixed size (fixed/{worst_c}): \
             worst {:.3} ms vs adaptive {:.3} ms ({:.2}x); trace {:?}",
            worst * 1e3,
            adaptive * 1e3,
            worst / adaptive,
            &trace[..trace.len().min(12)],
        );
        assert!(
            adaptive <= best / 0.95,
            "adaptive must reach >= 0.95x of the best fixed size (fixed/{best_c}): \
             best {:.3} ms vs adaptive {:.3} ms ({:.2}x); trace tail {:?}",
            best * 1e3,
            adaptive * 1e3,
            best / adaptive,
            &trace[trace.len().saturating_sub(6)..],
        );

        // Entry identity on real runs: fixed-serial ground truth vs the
        // adaptive pipelined writer on a private 8-worker pool.
        use crate::imt::Pool;
        use crate::session::{Session, SessionConfig};
        let small = 8192usize;
        let blocks: Vec<Vec<ColumnData>> = (0..small / 1024)
            .map(|blk| {
                let mut rng = dataset::SplitMix::new(blk as u64 + 11);
                (0..n_branches)
                    .map(|b| {
                        ColumnData::F32(
                            (0..1024)
                                .map(|i| rng.uniform() * (b + 1) as f32 + (i % 17) as f32)
                                .collect(),
                        )
                    })
                    .collect()
            })
            .collect();
        let decode = |be: &BackendRef| -> Vec<Vec<u8>> {
            let reader =
                TreeReader::open_first(Arc::new(FileReader::open(be.clone()).unwrap()))
                    .unwrap();
            reader.read_all().unwrap().iter().map(|c| c.encode()).collect()
        };
        let fixed_be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
        write_blocks(
            fixed_be.clone(),
            Schema::flat_f32("n", n_branches),
            "events",
            WriterConfig {
                basket_entries: 512,
                compression: Settings::new(Codec::Lz4r, 3),
                flush: FlushMode::Serial,
                ..Default::default()
            },
            blocks.clone(),
        )
        .unwrap();
        let pool = Arc::new(Pool::new(8));
        let session = Session::with_pool(pool, SessionConfig::for_writers(1, 4));
        let adaptive_be: BackendRef = Arc::new(crate::storage::mem::MemBackend::new());
        let rep = crate::coordinator::write::write_blocks_in_session(
            &session,
            adaptive_be.clone(),
            Schema::flat_f32("n", n_branches),
            "events",
            WriterConfig {
                basket_entries: 128,
                compression: Settings::new(Codec::Lz4r, 3),
                flush: FlushMode::Pipelined,
                granularity: FlushGranularity::Block,
                max_inflight_clusters: 4,
                sizing: ClusterSizing::Adaptive(AdaptiveConfig {
                    min_entries: 64,
                    max_entries: 4096,
                    hysteresis: 1,
                    warmup: 1,
                    ..Default::default()
                }),
                ..Default::default()
            },
            blocks,
        )
        .unwrap();
        assert!(rep.sizing.clusters > 0);
        assert_eq!(
            decode(&adaptive_be),
            decode(&fixed_be),
            "adaptive-sized output must decode entry-identical to the fixed writer"
        );
    }

    #[test]
    fn fig6_smoke() {
        let s = fig6(true).unwrap();
        assert!(s.contains("nvme") && s.contains("hdd"));
    }

    #[test]
    fn fig7_smoke() {
        let s = fig7(true).unwrap();
        assert!(s.contains("before") && s.contains("after"));
    }

    #[test]
    fn hadd_smoke() {
        let s = hadd_bench(true).unwrap();
        assert!(s.contains("parallel -j"));
    }

    #[test]
    fn read_prefetch_smoke() {
        let s = read_prefetch(true).unwrap();
        assert!(s.contains("adaptive") && s.contains("hdd"), "{s}");
        assert!(s.contains("measured") && s.contains("coalesce"), "{s}");
    }

    #[test]
    fn remote_reads_smoke() {
        let s = remote_reads(true).unwrap();
        assert!(s.contains("retry+hedge") && s.contains("fault_rate"), "{s}");
        // The fault-free raw-device row and every resilient row decode
        // byte-identically (asserted inside the harness); at least one
        // resilient row must have survived injected faults.
        assert!(s.contains("ok"), "{s}");
    }

    /// Acceptance (ISSUE 5): on the simulated HDD with 8 workers,
    /// adaptive prefetch achieves >= 2x the no-prefetch read
    /// throughput and >= 0.95x the best fixed window — asserted on the
    /// deterministic virtual-time pipeline over the calibrated device
    /// model and measured decode costs (the fig1/fig3/fig5
    /// methodology) — while a real run against a real `SimDevice`
    /// decodes identically to the serial baseline and, per
    /// `DeviceStats`, coalescing cuts issued device reads by >= 4x on
    /// the multi-basket window.
    #[test]
    fn adaptive_prefetch_beats_unprefetched_hdd_reads_on_eight_workers() {
        let n_branches = 8usize;
        let entries = 16_384usize;
        let basket = 1024usize;
        let settings = Settings::new(Codec::Lz4r, 2);
        // Same calibration the experiment itself runs on.
        let PrefetchCalibration {
            src_bytes,
            serial_cols,
            cluster_bytes,
            basket_bytes,
            decode_cost,
        } = calibrate_prefetch(n_branches, entries, basket, settings).unwrap();

        // Deterministic throughput ratios on the calibrated HDD model.
        let model = DeviceModel::hdd();
        let none = virtual_unprefetched_makespan(&basket_bytes, &model, decode_cost, 8);
        let mut best_fixed = Duration::MAX;
        let mut best_k = 0usize;
        for k in [1usize, 2, 4, 8] {
            let (wall, _) = virtual_prefetch_makespan(
                WindowPolicy::Fixed(k),
                &cluster_bytes,
                n_branches,
                &model,
                decode_cost,
                8,
            );
            if wall < best_fixed {
                best_fixed = wall;
                best_k = k;
            }
        }
        let (adaptive, peak) = virtual_prefetch_makespan(
            WindowPolicy::default(),
            &cluster_bytes,
            n_branches,
            &model,
            decode_cost,
            8,
        );
        assert!(
            none >= adaptive * 2,
            "adaptive prefetch must be >= 2x the no-prefetch read: \
             none {:.1} ms vs adaptive {:.1} ms ({:.2}x, peak window {peak})",
            none.as_secs_f64() * 1e3,
            adaptive.as_secs_f64() * 1e3,
            none.as_secs_f64() / adaptive.as_secs_f64(),
        );
        assert!(
            adaptive.as_secs_f64() <= best_fixed.as_secs_f64() / 0.95,
            "adaptive must reach >= 0.95x of the best fixed window (fixed/{best_k}): \
             best {:.1} ms vs adaptive {:.1} ms ({:.2}x)",
            best_fixed.as_secs_f64() * 1e3,
            adaptive.as_secs_f64() * 1e3,
            best_fixed.as_secs_f64() / adaptive.as_secs_f64(),
        );

        // Real run on a real simulated HDD (scaled latencies): decode
        // identity + the DeviceStats coalescing assertion.
        let sim = Arc::new(SimDevice::new(DeviceModel::hdd(), 0.002));
        let be: BackendRef = sim.clone();
        be.write_at(0, &src_bytes).unwrap();
        let file = Arc::new(FileReader::open(be.clone()).unwrap());
        let pool = Arc::new(crate::imt::Pool::new(imt::num_cpus().clamp(2, 4)));

        let before = sim.device_stats();
        let base_cols = pooled_basket_read(&file, &pool).unwrap();
        let base_reads = sim.device_stats().since(&before).reads;
        assert_eq!(base_cols, serial_cols, "baseline decode identity");
        assert_eq!(base_reads, basket_bytes.len() as u64, "one read per basket");

        let session = Session::with_pool(
            pool,
            SessionConfig { max_inflight_read_windows: 8, ..Default::default() },
        );
        let reader = TreeReader::open_first(file).unwrap();
        let before = sim.device_stats();
        let mut stream = reader
            .stream_in_session(&PrefetchOptions::default(), &session)
            .unwrap();
        let cols = stream.read_all_columns().unwrap();
        let pf_reads = sim.device_stats().since(&before).reads;
        assert_eq!(cols, serial_cols, "prefetched decode identity");
        assert!(
            base_reads >= 4 * pf_reads,
            "coalescing must cut issued device reads by >= 4x: \
             {base_reads} per-basket reads vs {pf_reads} coalesced fetches"
        );
        let st = stream.stats();
        assert_eq!(st.clusters, cluster_bytes.len() as u64);
        assert_eq!(st.baskets, basket_bytes.len() as u64);
        assert!(
            st.coalescing_factor() >= 4.0,
            "stream-side coalescing factor must agree: {:.1}",
            st.coalescing_factor()
        );
    }
}
