//! # rootio-par
//!
//! Reproduction of *"Increasing Parallelism in the ROOT I/O Subsystem"*
//! (Amadio, Bockelman, Canal, Piparo, Tejedor, Zhang — 2018).
//!
//! A self-contained parallel columnar I/O subsystem modelled on the ROOT
//! I/O stack, with every substrate the paper depends on built from
//! scratch:
//!
//! * [`compress`] — block compression codecs (LZ4-style and a
//!   deflate-style LZ77 + canonical-Huffman codec) behind ROOT-like
//!   9-byte block headers, plus CRC32 integrity, plus a thread-local /
//!   shared scratch-buffer pool ([`compress::pool`]) so steady-state
//!   basket (de)compression performs no heap allocation. The inner
//!   loops are vectorised word-at-a-time (SWAR match probing in the LZ
//!   codecs, slicing-by-8 CRC32, batched multi-symbol Huffman decode),
//!   each behind a `#[cfg]`-gated portable scalar twin that pins
//!   byte-identical output. [`compress::select`] adds per-column
//!   adaptive codec selection: a per-branch controller probes
//!   codec×level candidates on a column's early baskets, commits the
//!   ratio×throughput winner, and re-probes on drift — every basket
//!   records its own codec, so readers stay oblivious.
//! * [`serial`] — schema-driven object streamers: rows of typed values
//!   split into per-column buffers (ROOT's TBuffer + streamer-info).
//! * [`format`] — the `RNTF` container file format (TFile/TKey/TDirectory
//!   analogue): append-only records plus a footer directory. Wire v3
//!   adds the RNTuple-style *paged* layout: clusters stored
//!   column-major as independently compressed per-column pages, with
//!   the page directory (entry span, offset, CRC, per-page codec) and
//!   cluster spans in the footer. Wire v4 adds per-page min/max *zone
//!   maps*, recorded at page seal and carried in the directory so scan
//!   planners can exclude pages without touching their bytes; v1–v3
//!   files still decode (zone-less pages simply never prune).
//! * [`tree`] — TTree/TBranch/TBasket analogue: columnar trees of typed
//!   branches, basketised, written/read through [`format`]. Cluster
//!   sizes are fixed or *adaptive* ([`tree::sizer`]): a per-writer
//!   feedback controller resizes clusters between pipelined flushes
//!   from the stall/compress ratio and the session's admission-wait
//!   pressure, with hysteresis, clamps and a replayable decision
//!   trace. `WriterConfig::layout` picks the cluster layout: classic
//!   one-basket-per-branch, or paged ([`tree::writer::Layout`]) where
//!   each column's pages seal as independent tasks and variable-length
//!   branches (`list<f32>`) split into offset/element page pairs whose
//!   element payloads are page-relative (position-independent, so
//!   merges raw-copy them).
//! * [`imt`] — implicit multi-threading: a global *work-stealing* task
//!   pool (per-worker LIFO deques, FIFO stealing, an injector queue,
//!   condvar parking — no polling) with scoped task groups, the engine
//!   behind all "IMT on" paths (TBB analogue).
//! * [`storage`] — storage backends: local files, deterministic
//!   simulated devices (HDD / SSD / NVMe / tmpfs) for the paper's
//!   device-comparison experiments, a seeded remote object-store
//!   simulation ([`storage::remote`]: heavy-tailed first-byte latency,
//!   bounded request slots, injectable faults), reusable fault
//!   injection ([`storage::fault`]), and a resilience wrapper
//!   ([`storage::resilient`]: deadlines, retry with seeded backoff,
//!   hedged reads, circuit breaker) that turns flaky devices into
//!   clean-data-or-one-error backends.
//! * [`merger`] — `TBufferMerger`: many writer threads, one output
//!   thread, a bounded queue of in-memory tree files merged into a
//!   single physical file (paper §3.2, Figures 4–6).
//! * [`runtime`] — PJRT runtime: loads the AOT-compiled JAX/Pallas
//!   compute graphs from `artifacts/*.hlo.txt` and executes them from
//!   the hot path. Python never runs at request time.
//! * [`framework`] — a CMSSW-like mini framework: N concurrent streams
//!   generating, processing and writing events (paper §3.1, Figure 3).
//!   [`framework::chain`] adds the TChain analogue: a
//!   [`Chain`](framework::chain::Chain) scans N same-schema files as one
//!   stream of row batches, priming the next file's prefetcher while
//!   the current file drains so file boundaries never stall, and
//!   `Chain::scan_where` pushes a `branch op constant` predicate down
//!   into every file's fetch plan (zone-excluded pages are never
//!   fetched, then survivors are re-filtered row by row — exactly the
//!   rows a full scan plus filter would deliver).
//! * [`coordinator`] — the paper's contribution: parallel column
//!   reading at basket granularity (per-(branch, basket) tasks with
//!   ordered reassembly, scaling as `min(total_baskets, T)` instead of
//!   `min(branches, T)`), parallel basket decompression with cluster
//!   splitting and interleaved processing, and parallel column
//!   writing.
//! * [`session`] — the shared I/O session: one pool handle, one
//!   completion domain and globally-bounded in-flight budgets (write
//!   clusters, read-ahead windows *and* hedged duplicate reads) with
//!   per-member fair admission, shared by every `FileWriter` /
//!   `TreeWriter` / merger / `ClusterStream` a job opens (the
//!   multi-tree, multi-file I/O coordinator).
//! * [`cache`] — the parallel read-ahead cache (TTreeCache + parallel
//!   unzip analogue): a cluster prefetcher that walks the cluster list
//!   ahead of the consumer, coalesces each window's baskets into one
//!   vectored `read_at`, decodes per basket on the IMT pool, and
//!   streams decoded clusters in order through `TreeReader::stream` —
//!   with the prefetch window sized adaptively by the write sizer's
//!   controller (fetch-stall vs decode throughput). On unreliable
//!   storage it degrades instead of failing: priority-tagged fetches,
//!   head-only windows while the backend reports itself degraded, and
//!   inline refetch of shed read-ahead. On paged (v3) files the fetch
//!   plan is *projection-aware*: a branch selection
//!   (`ReadOptions::branches` / `PrefetchOptions::branches`) coalesces
//!   only the selected columns' page ranges, and the report's
//!   `bytes_selected`/`bytes_skipped` split shows what pushdown
//!   avoided reading. `PrefetchOptions::predicate` pushes a zone-map
//!   predicate into the same plan: pages whose v4 min/max zone
//!   provably excludes every matching row are dropped from the fetch
//!   windows before any device read, accounted as
//!   `pages_pruned`/`bytes_pruned` in [`cache::PrefetchStats`].
//! * [`metrics`] — observability for the whole pipeline. A sharded
//!   per-thread [`metrics::Recorder`] (no lock on the record path;
//!   disabled = one branch) collects spans for every subsystem — pool
//!   tasks, budget admission waits, coalesced/scatter device reads,
//!   retries/hedges/breaker trips, basket decode, page seals, zone
//!   prunes, chain file-advances — and renders them as an ASCII
//!   timeline (the "VTune" for Figure 7), CSV, or Chrome trace-event
//!   JSON loadable in Perfetto. [`metrics::Registry`] folds every
//!   stats struct into one named counter/gauge tree with log-bucketed
//!   latency histograms (window submit→decoded, basket compress,
//!   device read). Surfaced on the CLI as `rootio trace`,
//!   `rootio stats` and the `rootio summary` bench-trajectory gate.
//! * [`hadd`] — serial and parallel merging of existing files (§3.4).

pub mod cache;
pub mod compress;
pub mod coordinator;
pub mod error;
pub mod format;
pub mod framework;
pub mod hadd;
pub mod imt;
pub mod merger;
pub mod metrics;
pub mod runtime;
pub mod serial;
pub mod session;
pub mod storage;
pub mod tree;

pub use error::{Error, Result};
pub mod experiments;
pub mod simsched;
