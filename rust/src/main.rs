//! `rootio` — CLI for the parallel I/O subsystem reproduction.
//!
//! ```text
//! rootio bench <fig1|fig2|fig3|write|multiwrite|adaptive|prefetch|remote|fig6|fig7|projection|chain|hadd|codec|all> [--quick]
//! rootio generate --out <path> [--dataset reco|aod|gensim|xaod]
//!                 [--entries N] [--codec none|lz4|zlib] [--level L]
//! rootio inspect <path>
//! rootio read <path> [--threads N] [--granularity basket|branch]
//! rootio analyze <path> [--threads N]
//! ```
//!
//! Argument parsing is hand-rolled (no external CLI crates available in
//! this environment — see Cargo.toml).

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::baskets::{self, PipelineOptions};
use rootio_par::coordinator::read::{read_columns, Granularity, ReadOptions};
use rootio_par::error::Result;
use rootio_par::format::reader::FileReader;
use rootio_par::framework::dataset::DatasetKind;
use rootio_par::runtime::Engine;
use rootio_par::storage::local::LocalFile;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::{experiments, imt};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("rootio: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Split `args` into positional arguments and `--key value` options.
fn parse(args: &[String]) -> (Vec<&str>, HashMap<&str, &str>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key, args[i + 1].as_str());
                i += 2;
            } else {
                opts.insert(key, "true");
                i += 1;
            }
        } else {
            pos.push(a);
            i += 1;
        }
    }
    (pos, opts)
}

fn usage() -> Result<()> {
    println!(
        "usage:\n  rootio bench <fig1|fig2|fig3|write|multiwrite|adaptive|prefetch|remote|fig6|fig7|projection|chain|hadd|codec|all> [--quick]\n  \
         rootio generate --out <path> [--dataset reco|aod|gensim|xaod] [--entries N] \
         [--codec none|lz4|zlib] [--level L]\n  rootio inspect <path>\n  \
         rootio read <path> [--threads N] [--granularity basket|branch]\n  \
         rootio analyze <path> [--threads N]"
    );
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let (pos, opts) = parse(args);
    match pos.first().copied() {
        Some("bench") => bench(pos.get(1).copied().unwrap_or("all"), &opts),
        Some("generate") => generate(&opts),
        Some("inspect") => inspect(pos.get(1).copied()),
        Some("read") => read(pos.get(1).copied(), &opts),
        Some("analyze") => analyze(pos.get(1).copied(), &opts),
        _ => usage(),
    }
}

fn bench(which: &str, opts: &HashMap<&str, &str>) -> Result<()> {
    let quick = opts.contains_key("quick");
    let all = which == "all";
    let mut outputs = Vec::new();
    if all || which == "fig1" {
        outputs.push(experiments::fig1(quick)?);
    }
    if all || which == "fig2" {
        outputs.push(experiments::fig2(quick)?);
    }
    if all || which == "fig3" {
        outputs.push(experiments::fig3(quick)?);
    }
    if all || which == "write" {
        outputs.push(experiments::write_scaling(quick)?);
    }
    if all || which == "multiwrite" {
        outputs.push(experiments::multi_writer(quick)?);
    }
    if all || which == "adaptive" {
        outputs.push(experiments::adaptive_sizing(quick)?);
    }
    if all || which == "prefetch" {
        outputs.push(experiments::read_prefetch(quick)?);
    }
    if all || which == "remote" {
        outputs.push(experiments::remote_reads(quick)?);
    }
    if all || which == "fig6" {
        outputs.push(experiments::fig6(quick)?);
    }
    if all || which == "fig7" {
        outputs.push(experiments::fig7(quick)?);
    }
    if all || which == "projection" || which == "fig9" {
        outputs.push(experiments::page_projection(quick)?);
    }
    if all || which == "chain" || which == "fig10" {
        outputs.push(experiments::chain_scan(quick)?);
    }
    if all || which == "hadd" {
        outputs.push(experiments::hadd_bench(quick)?);
    }
    if all || which == "codec" {
        outputs.push(experiments::codec_bench(quick)?);
    }
    if all || which == "ablation" {
        outputs.push(experiments::ablation_bench(quick)?);
    }
    if outputs.is_empty() {
        return usage();
    }
    for o in outputs {
        println!("{o}\n");
    }
    Ok(())
}

fn generate(opts: &HashMap<&str, &str>) -> Result<()> {
    let out = opts
        .get("out")
        .copied()
        .ok_or_else(|| rootio_par::Error::Coordinator("generate: --out required".into()))?;
    let dataset = match opts.get("dataset").copied().unwrap_or("aod") {
        "reco" => DatasetKind::Reco,
        "aod" => DatasetKind::Aod,
        "gensim" => DatasetKind::GenSim,
        "xaod" => DatasetKind::Xaod,
        other => {
            return Err(rootio_par::Error::Coordinator(format!("unknown dataset '{other}'")))
        }
    };
    let entries: usize = opts.get("entries").and_then(|v| v.parse().ok()).unwrap_or(65_536);
    let codec: Codec = opts.get("codec").copied().unwrap_or("zlib").parse()?;
    let level: u8 = opts.get("level").and_then(|v| v.parse().ok()).unwrap_or(4);
    let engine = Engine::load_default().ok();

    // Synthesize in memory, then copy to the real file.
    let (mem, report) = experiments::util::synthesize_dataset(
        dataset,
        entries,
        4096,
        Settings::new(codec, level),
        engine.as_ref(),
    )?;
    copy_backend_to_file(&mem, out)?;
    println!(
        "wrote {out}: {} entries, {} branches, {:.1} MB raw, {:.1} MB stored (ratio {:.2})",
        report.entries,
        dataset.n_branches(),
        report.raw_bytes as f64 / 1e6,
        report.stored_bytes as f64 / 1e6,
        report.compression_ratio()
    );
    Ok(())
}

fn copy_backend_to_file(src: &BackendRef, path: &str) -> Result<()> {
    use rootio_par::storage::Backend;
    let len = src.len()?;
    let mut buf = vec![0u8; len as usize];
    src.read_at(0, &mut buf)?;
    let dst = LocalFile::create(path)?;
    dst.write_at(0, &buf)?;
    dst.sync()
}

fn open_file(path: Option<&str>) -> Result<Arc<FileReader>> {
    let path =
        path.ok_or_else(|| rootio_par::Error::Coordinator("missing file argument".into()))?;
    let backend: BackendRef = Arc::new(LocalFile::open(path)?);
    Ok(Arc::new(FileReader::open(backend)?))
}

fn inspect(path: Option<&str>) -> Result<()> {
    let file = open_file(path)?;
    for tree in &file.directory().trees {
        println!(
            "tree '{}': {} entries, {} branches",
            tree.name,
            tree.entries,
            tree.branches.len()
        );
        for br in &tree.branches {
            println!(
                "  branch {:<12} {:<7} {:>4} baskets  {:>10} raw  {:>10} stored  ({:.2}x)",
                br.name,
                format!("[{}]", br.ty.name()),
                br.baskets.len(),
                br.raw_bytes(),
                br.stored_bytes(),
                br.raw_bytes() as f64 / br.stored_bytes().max(1) as f64,
            );
        }
    }
    Ok(())
}

fn read(path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let file = open_file(path)?;
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    if threads > 0 {
        imt::enable(threads);
    }
    let granularity = match opts.get("granularity").copied().unwrap_or("basket") {
        "basket" => Granularity::Basket,
        "branch" => Granularity::Branch,
        other => {
            return Err(rootio_par::Error::Coordinator(format!(
                "unknown granularity '{other}' (basket|branch)"
            )))
        }
    };
    let reader = TreeReader::open_first(file)?;
    let rep = read_columns(&reader, &ReadOptions { granularity, ..Default::default() })?;
    println!(
        "read {} branches / {} entries: {:.1} MB in {:.1} ms ({:.1} MB/s, imt={}, {:?} tasks)",
        rep.branches_read,
        rep.entries,
        rep.raw_bytes as f64 / 1e6,
        rep.wall.as_secs_f64() * 1e3,
        rep.throughput_mbps(),
        imt::threads(),
        granularity,
    );
    Ok(())
}

fn analyze(path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let file = open_file(path)?;
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    if threads > 0 {
        imt::enable(threads);
    }
    let engine = Engine::load_default()?;
    let reader = TreeReader::open_first(file)?;
    let rep = baskets::run(&reader, Some(&engine), &PipelineOptions::default())?;
    println!(
        "analyzed {} events in {:.1} ms ({:.1} MB/s decompression)",
        rep.analyzed,
        rep.wall.as_secs_f64() * 1e3,
        rep.decompression_mbps()
    );
    if let Some(hist) = rep.hist {
        let max = hist.iter().cloned().fold(1.0f32, f32::max);
        let meta = engine.meta();
        println!("mass spectrum [{:.0}, {:.0}] GeV:", meta.hist_lo, meta.hist_hi);
        for (i, &count) in hist.iter().enumerate() {
            let lo =
                meta.hist_lo + (meta.hist_hi - meta.hist_lo) * i as f64 / hist.len() as f64;
            let bar = "#".repeat((count / max * 50.0) as usize);
            println!("{lo:6.1} | {bar} {count}");
        }
    }
    Ok(())
}
