//! `rootio` — CLI for the parallel I/O subsystem reproduction.
//!
//! ```text
//! rootio bench <fig1|fig2|fig3|write|multiwrite|adaptive|prefetch|remote|fig6|fig7|projection|chain|hadd|codec|all> [--quick]
//! rootio generate --out <path> [--dataset reco|aod|gensim|xaod]
//!                 [--entries N] [--codec none|lz4|zlib] [--level L]
//! rootio inspect <path>
//! rootio read <path> [--threads N] [--granularity basket|branch]
//! rootio analyze <path> [--threads N]
//! rootio trace <bench|read|write> [path] [--out trace.json] [--threads N]
//! rootio stats [path] [--threads N]
//! rootio summary [--dir .] [--baseline bench_baselines.json] [--out BENCH_summary.json]
//! ```
//!
//! Argument parsing is hand-rolled (no external CLI crates available in
//! this environment — see Cargo.toml).

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

use rootio_par::cache::{Predicate, PrefetchOptions};
use rootio_par::compress::{Codec, Settings};
use rootio_par::coordinator::baskets::{self, PipelineOptions};
use rootio_par::coordinator::read::{read_columns, Granularity, ReadOptions};
use rootio_par::coordinator::write::write_blocks_in_session;
use rootio_par::error::Result;
use rootio_par::format::reader::FileReader;
use rootio_par::framework::chain::Chain;
use rootio_par::framework::dataset::DatasetKind;
use rootio_par::metrics::{json, Recorder};
use rootio_par::runtime::Engine;
use rootio_par::serial::column::ColumnData;
use rootio_par::serial::schema::Schema;
use rootio_par::session::{Session, SessionConfig};
use rootio_par::storage::local::LocalFile;
use rootio_par::storage::mem::MemBackend;
use rootio_par::storage::BackendRef;
use rootio_par::tree::reader::TreeReader;
use rootio_par::tree::writer::{FlushMode, Layout, WriterConfig};
use rootio_par::{experiments, imt};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("rootio: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Split `args` into positional arguments and `--key value` options.
fn parse(args: &[String]) -> (Vec<&str>, HashMap<&str, &str>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key, args[i + 1].as_str());
                i += 2;
            } else {
                opts.insert(key, "true");
                i += 1;
            }
        } else {
            pos.push(a);
            i += 1;
        }
    }
    (pos, opts)
}

fn usage() -> Result<()> {
    println!(
        "usage:\n  rootio bench <fig1|fig2|fig3|write|multiwrite|adaptive|prefetch|remote|fig6|fig7|projection|chain|hadd|codec|all> [--quick]\n  \
         rootio generate --out <path> [--dataset reco|aod|gensim|xaod] [--entries N] \
         [--codec none|lz4|zlib] [--level L]\n  rootio inspect <path>\n  \
         rootio read <path> [--threads N] [--granularity basket|branch]\n  \
         rootio analyze <path> [--threads N]\n  \
         rootio trace <bench|read|write> [path] [--out trace.json] [--threads N]\n  \
         rootio stats [path] [--threads N]\n  \
         rootio summary [--dir .] [--baseline bench_baselines.json] [--out BENCH_summary.json]"
    );
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let (pos, opts) = parse(args);
    match pos.first().copied() {
        Some("bench") => bench(pos.get(1).copied().unwrap_or("all"), &opts),
        Some("generate") => generate(&opts),
        Some("inspect") => inspect(pos.get(1).copied()),
        Some("read") => read(pos.get(1).copied(), &opts),
        Some("analyze") => analyze(pos.get(1).copied(), &opts),
        Some("trace") => trace(pos.get(1).copied(), pos.get(2).copied(), &opts),
        Some("stats") => stats(pos.get(1).copied(), &opts),
        Some("summary") => summary(&opts),
        _ => usage(),
    }
}

fn bench(which: &str, opts: &HashMap<&str, &str>) -> Result<()> {
    let quick = opts.contains_key("quick");
    let all = which == "all";
    let mut outputs = Vec::new();
    if all || which == "fig1" {
        outputs.push(experiments::fig1(quick)?);
    }
    if all || which == "fig2" {
        outputs.push(experiments::fig2(quick)?);
    }
    if all || which == "fig3" {
        outputs.push(experiments::fig3(quick)?);
    }
    if all || which == "write" {
        outputs.push(experiments::write_scaling(quick)?);
    }
    if all || which == "multiwrite" {
        outputs.push(experiments::multi_writer(quick)?);
    }
    if all || which == "adaptive" {
        outputs.push(experiments::adaptive_sizing(quick)?);
    }
    if all || which == "prefetch" {
        outputs.push(experiments::read_prefetch(quick)?);
    }
    if all || which == "remote" {
        outputs.push(experiments::remote_reads(quick)?);
    }
    if all || which == "fig6" {
        outputs.push(experiments::fig6(quick)?);
    }
    if all || which == "fig7" {
        outputs.push(experiments::fig7(quick)?);
    }
    if all || which == "projection" || which == "fig9" {
        outputs.push(experiments::page_projection(quick)?);
    }
    if all || which == "chain" || which == "fig10" {
        outputs.push(experiments::chain_scan(quick)?);
    }
    if all || which == "hadd" {
        outputs.push(experiments::hadd_bench(quick)?);
    }
    if all || which == "codec" {
        outputs.push(experiments::codec_bench(quick)?);
    }
    if all || which == "ablation" {
        outputs.push(experiments::ablation_bench(quick)?);
    }
    if outputs.is_empty() {
        return usage();
    }
    for o in outputs {
        println!("{o}\n");
    }
    Ok(())
}

fn generate(opts: &HashMap<&str, &str>) -> Result<()> {
    let out = opts
        .get("out")
        .copied()
        .ok_or_else(|| rootio_par::Error::Coordinator("generate: --out required".into()))?;
    let dataset = match opts.get("dataset").copied().unwrap_or("aod") {
        "reco" => DatasetKind::Reco,
        "aod" => DatasetKind::Aod,
        "gensim" => DatasetKind::GenSim,
        "xaod" => DatasetKind::Xaod,
        other => {
            return Err(rootio_par::Error::Coordinator(format!("unknown dataset '{other}'")))
        }
    };
    let entries: usize = opts.get("entries").and_then(|v| v.parse().ok()).unwrap_or(65_536);
    let codec: Codec = opts.get("codec").copied().unwrap_or("zlib").parse()?;
    let level: u8 = opts.get("level").and_then(|v| v.parse().ok()).unwrap_or(4);
    let engine = Engine::load_default().ok();

    // Synthesize in memory, then copy to the real file.
    let (mem, report) = experiments::util::synthesize_dataset(
        dataset,
        entries,
        4096,
        Settings::new(codec, level),
        engine.as_ref(),
    )?;
    copy_backend_to_file(&mem, out)?;
    println!(
        "wrote {out}: {} entries, {} branches, {:.1} MB raw, {:.1} MB stored (ratio {:.2})",
        report.entries,
        dataset.n_branches(),
        report.raw_bytes as f64 / 1e6,
        report.stored_bytes as f64 / 1e6,
        report.compression_ratio()
    );
    Ok(())
}

fn copy_backend_to_file(src: &BackendRef, path: &str) -> Result<()> {
    use rootio_par::storage::Backend;
    let len = src.len()?;
    let mut buf = vec![0u8; len as usize];
    src.read_at(0, &mut buf)?;
    let dst = LocalFile::create(path)?;
    dst.write_at(0, &buf)?;
    dst.sync()
}

fn open_file(path: Option<&str>) -> Result<Arc<FileReader>> {
    let path =
        path.ok_or_else(|| rootio_par::Error::Coordinator("missing file argument".into()))?;
    let backend: BackendRef = Arc::new(LocalFile::open(path)?);
    Ok(Arc::new(FileReader::open(backend)?))
}

fn inspect(path: Option<&str>) -> Result<()> {
    let file = open_file(path)?;
    for tree in &file.directory().trees {
        println!(
            "tree '{}': {} entries, {} branches",
            tree.name,
            tree.entries,
            tree.branches.len()
        );
        for br in &tree.branches {
            println!(
                "  branch {:<12} {:<7} {:>4} baskets  {:>10} raw  {:>10} stored  ({:.2}x)",
                br.name,
                format!("[{}]", br.ty.name()),
                br.baskets.len(),
                br.raw_bytes(),
                br.stored_bytes(),
                br.raw_bytes() as f64 / br.stored_bytes().max(1) as f64,
            );
        }
    }
    Ok(())
}

fn read(path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let file = open_file(path)?;
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    if threads > 0 {
        imt::enable(threads);
    }
    let granularity = match opts.get("granularity").copied().unwrap_or("basket") {
        "basket" => Granularity::Basket,
        "branch" => Granularity::Branch,
        other => {
            return Err(rootio_par::Error::Coordinator(format!(
                "unknown granularity '{other}' (basket|branch)"
            )))
        }
    };
    let reader = TreeReader::open_first(file)?;
    let rep = read_columns(&reader, &ReadOptions { granularity, ..Default::default() })?;
    println!(
        "read {} branches / {} entries: {:.1} MB in {:.1} ms ({:.1} MB/s, imt={}, {:?} tasks)",
        rep.branches_read,
        rep.entries,
        rep.raw_bytes as f64 / 1e6,
        rep.wall.as_secs_f64() * 1e3,
        rep.throughput_mbps(),
        imt::threads(),
        granularity,
    );
    Ok(())
}

fn analyze(path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let file = open_file(path)?;
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    if threads > 0 {
        imt::enable(threads);
    }
    let engine = Engine::load_default()?;
    let reader = TreeReader::open_first(file)?;
    let rep = baskets::run(&reader, Some(&engine), &PipelineOptions::default())?;
    println!(
        "analyzed {} events in {:.1} ms ({:.1} MB/s decompression)",
        rep.analyzed,
        rep.wall.as_secs_f64() * 1e3,
        rep.decompression_mbps()
    );
    if let Some(hist) = rep.hist {
        let max = hist.iter().cloned().fold(1.0f32, f32::max);
        let meta = engine.meta();
        println!("mass spectrum [{:.0}, {:.0}] GeV:", meta.hist_lo, meta.hist_hi);
        for (i, &count) in hist.iter().enumerate() {
            let lo =
                meta.hist_lo + (meta.hist_hi - meta.hist_lo) * i as f64 / hist.len() as f64;
            let bar = "#".repeat((count / max * 50.0) as usize);
            println!("{lo:6.1} | {bar} {count}");
        }
    }
    Ok(())
}

/// Write `files` small paged (v3/v4) tree files into fresh in-memory
/// backends through `session` — a deliberately tight cluster budget so
/// the trace shows real admission waits, pipelined flushes so sealing
/// overlaps filling, and a chain-monotone branch 0 so a later
/// `scan_where` can zone-prune.
fn traced_write_files(session: &Session, files: usize) -> Result<Vec<BackendRef>> {
    let n_branches = 16usize;
    let entries = 8_192usize;
    let schema = Schema::flat_f32("b", n_branches);
    let cfg = WriterConfig {
        basket_entries: 1024,
        compression: Settings::new(Codec::Lz4r, 3),
        flush: FlushMode::Pipelined,
        max_inflight_clusters: 2,
        layout: Layout::Paged { page_entries: 256 },
        ..Default::default()
    };
    let mut out = Vec::new();
    for f in 0..files {
        let be: BackendRef = Arc::new(MemBackend::new());
        let block: Vec<ColumnData> = (0..n_branches)
            .map(|b| {
                ColumnData::F32(
                    (0..entries)
                        .map(|i| {
                            if b == 0 {
                                (f * entries + i) as f32
                            } else {
                                ((i * 31 + b * 7 + f) % 997) as f32
                            }
                        })
                        .collect(),
                )
            })
            .collect();
        write_blocks_in_session(
            session,
            be.clone(),
            schema.clone(),
            "events",
            cfg.clone(),
            vec![block],
        )?;
        out.push(be);
    }
    Ok(out)
}

/// Distinct subsystems present in a recorder's spans, sorted.
fn trace_subsystems(rec: &Recorder) -> Vec<&'static str> {
    let mut subs: Vec<&'static str> =
        rec.snapshot().iter().map(|s| s.kind.subsystem()).collect();
    subs.sort_unstable();
    subs.dedup();
    subs
}

/// `rootio trace <bench|read|write>` — run a real pipeline under an
/// enabled recorder and export a Chrome trace-event (Perfetto-loadable)
/// JSON file, plus the ASCII timeline on stdout.
fn trace(what: Option<&str>, path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let out = opts.get("out").copied().unwrap_or("trace.json");
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(8);
    imt::enable(threads);
    let rec = Recorder::new();
    match what.unwrap_or("bench") {
        // Full pipeline: a tight-budget pipelined write of a small file
        // chain, then an 8-worker predicate scan of that chain — spans
        // from the pool, budgets, writer, prefetcher, storage, chain
        // and codec layers land in one timeline.
        "bench" => {
            let files = {
                let session = Session::new(SessionConfig {
                    max_inflight_clusters: 2,
                    recorder: rec.clone(),
                    ..Default::default()
                });
                let files = traced_write_files(&session, 3)?;
                session.drain()?;
                files
            };
            let total_rows = 3 * 8_192;
            let cutoff = total_rows as f64 * 0.9;
            let chain = Chain::new(files).with_recorder(rec.clone());
            let mut rows = 0u64;
            let report = chain.scan_where(
                Predicate::ge(0, cutoff),
                &PrefetchOptions::fixed(4),
                |b| rows += b.rows() as u64,
            )?;
            println!(
                "traced chain scan: {} files, {} rows matched, {} pages pruned",
                report.files, rows, report.prefetch.pages_pruned
            );
        }
        // Traced read of a real on-disk file through the prefetcher.
        "read" => {
            let file = open_file(path)?;
            let session = Session::new(SessionConfig {
                recorder: rec.clone(),
                ..Default::default()
            });
            let reader = TreeReader::open_first(file)?;
            let mut stream = reader.stream_in_session(&PrefetchOptions::fixed(4), &session)?;
            let cols = stream.read_all_columns()?;
            println!("traced read: {} columns, {} entries", cols.len(), reader.entries());
        }
        // Traced write phase only.
        "write" => {
            let session = Session::new(SessionConfig {
                max_inflight_clusters: 2,
                recorder: rec.clone(),
                ..Default::default()
            });
            let files = traced_write_files(&session, 3)?;
            session.drain()?;
            println!("traced write: {} files", files.len());
        }
        other => {
            return Err(rootio_par::Error::Coordinator(format!(
                "unknown trace target '{other}' (bench|read|write)"
            )))
        }
    }
    rec.check()?;
    std::fs::write(out, rec.to_chrome_json())
        .map_err(|e| rootio_par::Error::Coordinator(format!("writing {out}: {e}")))?;
    let subs = trace_subsystems(&rec);
    println!(
        "\n{}\nwrote {out}: {} spans on {} threads across {} subsystems ({}); \
         useful fraction {:.2} — open in ui.perfetto.dev",
        rec.timeline_ascii(100),
        rec.snapshot().len(),
        rec.n_threads(),
        subs.len(),
        subs.join(", "),
        rec.useful_fraction(),
    );
    Ok(())
}

/// `rootio stats [path]` — one-shot metrics-registry dump: stream the
/// file (or a synthesized stand-in) through a session and print the
/// unified counter/gauge/histogram tree as JSON.
fn stats(path: Option<&str>, opts: &HashMap<&str, &str>) -> Result<()> {
    let threads: usize = opts.get("threads").and_then(|v| v.parse().ok()).unwrap_or(4);
    let be: BackendRef = match path {
        Some(p) => Arc::new(LocalFile::open(p)?),
        None => experiments::util::synthesize_flat_f32(
            8,
            16_384,
            1024,
            Settings::new(Codec::Lz4r, 3),
        )?,
    };
    let pool = Arc::new(imt::Pool::new(threads));
    let session = Session::with_pool(pool, SessionConfig::default());
    let reader = TreeReader::open_first(Arc::new(FileReader::open(be)?))?;
    let mut stream = reader.stream_in_session(&PrefetchOptions::fixed(4), &session)?;
    stream.read_all_columns()?;
    let mut snap = session.metrics().snapshot();
    snap.put_prefetch("prefetch", &stream.stats());
    snap.put_session(&session.stats());
    snap.put_pool(&rootio_par::compress::pool::stats());
    println!("{}", snap.to_json());
    Ok(())
}

/// One bench's headline numbers pulled out of its `BENCH_*.json`.
struct BenchHeadline {
    bench: String,
    best_mbps: f64,
    min_wall_ms: f64,
}

fn load_bench_headline(path: &std::path::Path) -> Result<BenchHeadline> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| rootio_par::Error::Coordinator(format!("{}: {e}", path.display())))?;
    let doc = json::parse(&text)?;
    let bench = doc
        .get("bench")
        .and_then(json::Json::as_str)
        .ok_or_else(|| {
            rootio_par::Error::Coordinator(format!("{}: missing \"bench\"", path.display()))
        })?
        .to_string();
    let mut best_mbps = 0.0f64;
    let mut min_wall_ms = f64::INFINITY;
    for row in doc.get("rows").and_then(json::Json::as_arr).unwrap_or(&[]) {
        if let Some(m) = row.get("MBps").and_then(json::Json::as_f64) {
            best_mbps = best_mbps.max(m);
        }
        if let Some(w) = row.get("wall_ms").and_then(json::Json::as_f64) {
            if w > 0.0 {
                min_wall_ms = min_wall_ms.min(w);
            }
        }
    }
    if !min_wall_ms.is_finite() {
        min_wall_ms = 0.0;
    }
    Ok(BenchHeadline { bench, best_mbps, min_wall_ms })
}

/// `rootio summary` — collect every `BENCH_*.json` in `--dir` into one
/// `BENCH_summary.json`, compare each bench's headline throughput to
/// the committed baselines and fail on a >2x regression. `STATS_*.json`
/// and `TRACE_*.json` artifacts in the directory are indexed alongside.
fn summary(opts: &HashMap<&str, &str>) -> Result<()> {
    let dir = opts.get("dir").copied().unwrap_or(".");
    let out = opts.get("out").copied().unwrap_or("BENCH_summary.json");

    // Baselines are optional: no file means no gate (first runs on a
    // new machine still produce a summary).
    let baseline_text = match opts.get("baseline").copied() {
        Some(p) => Some(std::fs::read_to_string(p).map_err(|e| {
            rootio_par::Error::Coordinator(format!("baseline {p}: {e}"))
        })?),
        None => std::fs::read_to_string("bench_baselines.json")
            .or_else(|_| std::fs::read_to_string("rust/bench_baselines.json"))
            .ok(),
    };
    let mut baselines: Vec<(String, f64)> = Vec::new();
    if let Some(text) = &baseline_text {
        let doc = json::parse(text)?;
        for b in doc.get("benches").and_then(json::Json::as_arr).unwrap_or(&[]) {
            if let (Some(name), Some(mbps)) = (
                b.get("bench").and_then(json::Json::as_str),
                b.get("MBps").and_then(json::Json::as_f64),
            ) {
                baselines.push((name.to_string(), mbps));
            }
        }
    }

    let mut heads: Vec<BenchHeadline> = Vec::new();
    let mut stats_files: Vec<String> = Vec::new();
    let mut trace_files: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir)
        .map_err(|e| rootio_par::Error::Coordinator(format!("reading {dir}: {e}")))?
    {
        let entry =
            entry.map_err(|e| rootio_par::Error::Coordinator(format!("reading {dir}: {e}")))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.ends_with(".json") || name == out {
            continue;
        }
        if name.starts_with("BENCH_") {
            heads.push(load_bench_headline(&entry.path())?);
        } else if name.starts_with("STATS_") {
            stats_files.push(name);
        } else if name.starts_with("TRACE_") {
            trace_files.push(name);
        }
    }
    heads.sort_by(|a, b| a.bench.cmp(&b.bench));
    stats_files.sort();
    trace_files.sort();
    if heads.is_empty() {
        return Err(rootio_par::Error::Coordinator(format!(
            "summary: no BENCH_*.json files in {dir} (run `rootio bench` first)"
        )));
    }

    let mut regressed: Vec<String> = Vec::new();
    let mut body = String::from("{\"summary\":[");
    for (i, h) in heads.iter().enumerate() {
        let base = baselines.iter().find(|(n, _)| *n == h.bench).map(|(_, m)| *m);
        // Gate: >2x throughput regression against the pinned baseline.
        let bad = matches!(base, Some(b) if b > 0.0 && h.best_mbps < b / 2.0);
        if bad {
            regressed.push(format!(
                "{} ({:.1} MB/s vs baseline {:.1})",
                h.bench,
                h.best_mbps,
                base.unwrap_or(0.0)
            ));
        }
        println!(
            "{:<10} best {:>9.1} MB/s  min wall {:>9.2} ms  baseline {:>9}  {}",
            h.bench,
            h.best_mbps,
            h.min_wall_ms,
            base.map_or("-".into(), |b| format!("{b:.1}")),
            if bad { "REGRESSED" } else { "ok" },
        );
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!(
            "{{\"bench\":\"{}\",\"best_MBps\":{:.3},\"min_wall_ms\":{:.3},\
             \"baseline_MBps\":{},\"regressed\":{}}}",
            json::escape(&h.bench),
            h.best_mbps,
            h.min_wall_ms,
            base.map_or("null".into(), |b| format!("{b:.3}")),
            bad,
        ));
    }
    body.push_str("],\"stats_files\":[");
    for (i, f) in stats_files.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!("\"{}\"", json::escape(f)));
    }
    body.push_str("],\"trace_files\":[");
    for (i, f) in trace_files.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!("\"{}\"", json::escape(f)));
    }
    body.push_str("]}\n");
    let out_path = std::path::Path::new(dir).join(out);
    std::fs::write(&out_path, body).map_err(|e| {
        rootio_par::Error::Coordinator(format!("writing {}: {e}", out_path.display()))
    })?;
    println!(
        "wrote {} ({} benches, {} stats, {} traces)",
        out_path.display(),
        heads.len(),
        stats_files.len(),
        trace_files.len()
    );
    if !regressed.is_empty() {
        return Err(rootio_par::Error::Coordinator(format!(
            "bench-trajectory regression (>2x vs baseline): {}",
            regressed.join(", ")
        )));
    }
    Ok(())
}
