//! `TBufferMerger` (paper §3.2, Figures 4–6): parallel writing from many
//! threads into a *single* output file.
//!
//! Workers obtain a [`MergerFile`] via [`TBufferMerger::get_file`] — an
//! in-memory tree writer. Filling it serialises and compresses baskets
//! on the worker thread (in parallel across workers; with IMT on, the
//! default [`WriterConfig`] additionally *pipelines* each worker's
//! flush, so a worker keeps filling its next cluster while earlier
//! baskets compress on the pool). Calling [`MergerFile::write`] joins
//! that pipeline and ships the finished [`TreeBuffer`] into a bounded
//! queue; a dedicated output thread pops buffers and *appends their
//! already-compressed baskets* to the output file, rebasing entry
//! numbers — the cheap part, so a single output thread keeps up until
//! the device itself saturates (exactly the regime the paper's
//! Figure 6 explores).
//!
//! Worker files attach to one shared [`Session`]: their pipelined
//! flushes run on the session pool under the session's global
//! in-flight budget with per-worker fair admission, so many workers
//! cannot oversubscribe the pool or balloon buffered clusters — pass
//! a job-wide session to [`TBufferMerger::create_in_session`] to share
//! that bound with every other output of the job.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, Directory, TreeMeta};
use crate::format::writer::FileWriter;
use crate::metrics::{Recorder, SpanKind};
use crate::serial::schema::Schema;
use crate::session::{Session, SessionConfig};
use crate::storage::BackendRef;
use crate::tree::buffer::TreeBuffer;
use crate::tree::sink::BufferSink;
use crate::tree::sizer::SizerSummary;
use crate::tree::writer::{TreeWriter, WriterConfig};

/// Merger configuration.
#[derive(Clone, Debug)]
pub struct MergerConfig {
    /// Output tree name.
    pub tree_name: String,
    /// Queue depth before workers block on `write` (backpressure).
    pub queue_depth: usize,
    /// Writer tuning handed to every worker file.
    pub writer: WriterConfig,
}

impl Default for MergerConfig {
    fn default() -> Self {
        MergerConfig {
            tree_name: "events".into(),
            queue_depth: 16,
            writer: WriterConfig::default(),
        }
    }
}

/// Statistics from a completed merge session.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeStats {
    pub buffers_merged: u64,
    pub entries: u64,
    pub stored_bytes: u64,
    pub raw_bytes: u64,
    /// Wall time the output thread spent appending to the device.
    pub output_write_time: Duration,
    /// Wall time from construction to close.
    pub wall: Duration,
    /// Smallest cluster-size *target* any worker file used (0 until a
    /// non-empty buffer merges; tail baskets may hold fewer entries).
    pub cluster_entries_min: usize,
    /// Largest cluster-size target any worker file used.
    pub cluster_entries_max: usize,
    /// Total adaptive resize steps across all worker files (0 when
    /// every worker ran `ClusterSizing::Fixed`).
    pub resizes: u64,
}

struct OutputState {
    file: Arc<FileWriter>,
    branches: Vec<BranchMeta>,
    entries: u64,
    /// Per-branch element totals (paged variable-length branches):
    /// the global element coordinate buffer-relative element pages are
    /// rebased onto.
    elem_counts: Vec<u64>,
    /// Merged cluster spans (paged buffers only), already rebased.
    clusters: Vec<crate::format::directory::ClusterSpan>,
    stats: MergeStats,
}

/// Poison-proof state lock: a panicked merger worker must surface as
/// [`Error::Sync`] from the next merger operation, never cascade a
/// second panic through the output thread or `close` (the same
/// failure model [`crate::tree::sink`] uses).
fn lock_state(m: &Mutex<OutputState>) -> Result<MutexGuard<'_, OutputState>> {
    m.lock()
        .map_err(|_| Error::Sync("merger state lock poisoned by a panicked worker".into()))
}

/// Queue message: a worker buffer (with its writer's cluster-size
/// report), or the close() sentinel.
enum MergeMsg {
    Buffer(TreeBuffer, SizerSummary),
    Shutdown,
}

/// The single-output-file parallel merger.
pub struct TBufferMerger {
    tx: SyncSender<MergeMsg>,
    output: Option<JoinHandle<Result<()>>>,
    state: Arc<Mutex<OutputState>>,
    schema: Schema,
    config: MergerConfig,
    recorder: Option<Arc<Recorder>>,
    /// The session every worker file attaches to: one pool, one shared
    /// in-flight budget across all workers' pipelined flushes.
    session: Session,
    started: Instant,
}

impl TBufferMerger {
    /// Open the output file on `backend` and start the output thread.
    /// Worker files share a fresh session sized for up to 8 concurrent
    /// workers at the configured per-writer in-flight cap; use
    /// [`TBufferMerger::create_in_session`] to share a job-wide one.
    pub fn create(backend: BackendRef, schema: Schema, config: MergerConfig) -> Result<Self> {
        Self::create_with_recorder(backend, schema, config, None)
    }

    /// As [`create`], with Figure-7 style span recording.
    pub fn create_with_recorder(
        backend: BackendRef,
        schema: Schema,
        config: MergerConfig,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<Self> {
        let session =
            Session::new(SessionConfig::for_writers(8, config.writer.max_inflight_clusters));
        Self::create_in_session(backend, schema, config, recorder, &session)
    }

    /// Open the merger under an existing shared [`Session`]: every
    /// worker file's flush pipeline draws from that session's pool and
    /// in-flight budget, alongside whatever other writers the job has
    /// open.
    pub fn create_in_session(
        backend: BackendRef,
        schema: Schema,
        config: MergerConfig,
        recorder: Option<Arc<Recorder>>,
        session: &Session,
    ) -> Result<Self> {
        let file = Arc::new(FileWriter::create(backend)?);
        let branches: Vec<BranchMeta> = schema
            .fields
            .iter()
            .map(|f| BranchMeta::simple(f.name.clone(), f.ty, Vec::new()))
            .collect();
        let n = branches.len();
        let state = Arc::new(Mutex::new(OutputState {
            file,
            branches,
            entries: 0,
            elem_counts: vec![0; n],
            clusters: Vec::new(),
            stats: MergeStats::default(),
        }));
        let (tx, rx) = sync_channel::<MergeMsg>(config.queue_depth.max(1));
        let thread_state = state.clone();
        let thread_recorder = recorder.clone();
        let output = std::thread::Builder::new()
            .name("merger-output".into())
            .spawn(move || output_loop(rx, thread_state, thread_recorder))
            .map_err(Error::Io)?;
        Ok(TBufferMerger {
            tx,
            output: Some(output),
            state,
            schema,
            config,
            recorder,
            session: session.clone(),
            started: Instant::now(),
        })
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// A fresh in-memory file for one worker thread (ROOT's
    /// `TBufferMerger::GetFile()`), attached to the merger's session.
    pub fn get_file(&self) -> MergerFile {
        let sink = BufferSink::new(self.schema.clone());
        let writer = TreeWriter::attached(
            self.schema.clone(),
            sink,
            self.config.writer.clone(),
            &self.session,
        );
        let writer = match &self.recorder {
            Some(r) => writer.with_recorder(r.clone()),
            None => writer,
        };
        MergerFile { writer: Some(writer), tx: self.tx.clone(), recorder: self.recorder.clone() }
    }

    /// The session worker files attach to.
    pub fn session(&self) -> &Session {
        &self.session
    }

    /// Drain all buffers queued so far, write the footer, return stats.
    /// `MergerFile`s written after close began get an error from
    /// [`MergerFile::write`]; live handles do not block the close
    /// (unlike channel-close semantics, which would deadlock on a
    /// forgotten handle).
    pub fn close(mut self) -> Result<MergeStats> {
        let _ = self.tx.send(MergeMsg::Shutdown);
        if let Some(h) = self.output.take() {
            h.join().map_err(|_| Error::Coordinator("output thread panicked".into()))??;
        }
        let mut st = lock_state(&self.state)?;
        let meta = TreeMeta {
            name: self.config.tree_name.clone(),
            schema: self.schema.clone(),
            entries: st.entries,
            branches: std::mem::take(&mut st.branches),
            clusters: std::mem::take(&mut st.clusters),
        };
        meta.check()?;
        st.file.finish(&Directory { trees: vec![meta] })?;
        st.stats.wall = self.started.elapsed();
        Ok(st.stats)
    }
}

fn output_loop(
    rx: Receiver<MergeMsg>,
    state: Arc<Mutex<OutputState>>,
    recorder: Option<Arc<Recorder>>,
) -> Result<()> {
    loop {
        let (buf, sizing) = match rx.recv() {
            Ok(MergeMsg::Buffer(b, s)) => (b, s),
            Ok(MergeMsg::Shutdown) | Err(_) => break,
        };
        let t0 = Instant::now();
        merge_one(&state, &buf)?;
        let dt = t0.elapsed();
        if let Some(r) = &recorder {
            let end = r.elapsed();
            r.push(SpanKind::Merge, end.saturating_sub(dt), end);
        }
        let mut st = lock_state(&state)?;
        st.stats.buffers_merged += 1;
        st.stats.entries += buf.entries;
        st.stats.stored_bytes += buf.stored_bytes() as u64;
        st.stats.raw_bytes += buf.raw_bytes() as u64;
        st.stats.output_write_time += dt;
        if sizing.max_entries > 0 {
            st.stats.cluster_entries_min = if st.stats.cluster_entries_min == 0 {
                sizing.min_entries
            } else {
                st.stats.cluster_entries_min.min(sizing.min_entries)
            };
            st.stats.cluster_entries_max =
                st.stats.cluster_entries_max.max(sizing.max_entries);
        }
        st.stats.resizes += sizing.resizes();
    }
    Ok(())
}


fn merge_one(state: &Arc<Mutex<OutputState>>, buf: &TreeBuffer) -> Result<()> {
    // Snapshot the entry/element bases, then append baskets. Only the
    // output thread mutates branches, so the lock is uncontended; it
    // exists to let `close` read a consistent view.
    let (file, base, elem_bases) = {
        let st = lock_state(state)?;
        if st.branches.len() != buf.branches.len() {
            return Err(Error::Coordinator(format!(
                "buffer has {} branches, output has {}",
                buf.branches.len(),
                st.branches.len()
            )));
        }
        (st.file.clone(), st.entries, st.elem_counts.clone())
    };
    let mut new_infos: Vec<(Vec<BasketInfo>, Vec<BasketInfo>)> =
        Vec::with_capacity(buf.branches.len());
    for (b, bb) in buf.branches.iter().enumerate() {
        if !bb.elems.is_empty() && bb.elems.len() != bb.baskets.len() {
            return Err(Error::Coordinator(format!(
                "buffer branch {b}: {} element pages for {} offset pages",
                bb.elems.len(),
                bb.baskets.len()
            )));
        }
        let mut infos = Vec::with_capacity(bb.baskets.len());
        let mut elem_infos = Vec::with_capacity(bb.elems.len());
        for (i, k) in bb.baskets.iter().enumerate() {
            let (offset, crc) = file.append(&k.bytes)?;
            infos.push(BasketInfo {
                offset,
                comp_len: k.bytes.len() as u32,
                raw_len: k.raw_len,
                first_entry: base + k.first_entry,
                n_entries: k.n_entries,
                crc,
                settings: k.settings,
                zone: k.zone,
            });
            // A paged variable-length branch: its element page goes
            // directly after the offset page (the v3 adjacency
            // invariant — sequential appends make them contiguous).
            if let Some(e) = bb.elems.get(i) {
                let (eoff, ecrc) = file.append(&e.bytes)?;
                elem_infos.push(BasketInfo {
                    offset: eoff,
                    comp_len: e.bytes.len() as u32,
                    raw_len: e.raw_len,
                    first_entry: elem_bases[b] + e.first_entry,
                    n_entries: e.n_entries,
                    crc: ecrc,
                    settings: e.settings,
                    zone: e.zone,
                });
            }
        }
        new_infos.push((infos, elem_infos));
    }
    let mut st = lock_state(state)?;
    for (b, (infos, elem_infos)) in new_infos.into_iter().enumerate() {
        st.elem_counts[b] += elem_infos.iter().map(|e| e.n_entries as u64).sum::<u64>();
        st.branches[b].baskets.extend(infos);
        st.branches[b].elems.extend(elem_infos);
    }
    st.clusters.extend(buf.clusters.iter().map(|c| {
        crate::format::directory::ClusterSpan {
            first_entry: base + c.first_entry,
            n_entries: c.n_entries,
        }
    }));
    st.entries = base + buf.entries;
    Ok(())
}

/// Worker-side handle: an in-memory tree file plus the merge queue.
pub struct MergerFile {
    writer: Option<TreeWriter<BufferSink>>,
    tx: SyncSender<MergeMsg>,
    recorder: Option<Arc<Recorder>>,
}

impl MergerFile {
    /// Append one row (ROOT's `tree->Fill()`).
    pub fn fill(&mut self, row: crate::serial::value::Row) -> Result<()> {
        self.writer_mut()?.fill(row)
    }

    /// Bulk column-block append (the PJRT event-block path).
    pub fn fill_columns(&mut self, block: &[crate::serial::column::ColumnData]) -> Result<()> {
        self.writer_mut()?.fill_columns(block)
    }

    pub fn entries(&self) -> u64 {
        self.writer.as_ref().map(|w| w.entries()).unwrap_or(0)
    }

    fn writer_mut(&mut self) -> Result<&mut TreeWriter<BufferSink>> {
        self.writer.as_mut().ok_or_else(|| {
            Error::Coordinator("MergerFile already written (f->Write() is one-shot)".into())
        })
    }

    /// Finish this buffer and enqueue it for merging (ROOT's
    /// `f->Write()`): blocks when the merge queue is full.
    pub fn write(&mut self) -> Result<()> {
        let writer = self.writer.take().ok_or_else(|| {
            Error::Coordinator("MergerFile already written (f->Write() is one-shot)".into())
        })?;
        let (sink, entries, stats) = writer.close()?;
        let buf = sink.into_buffer(entries)?;
        if buf.is_empty() {
            return Ok(());
        }
        let send = || {
            self.tx
                .send(MergeMsg::Buffer(buf, stats.sizing))
                .map_err(|_| Error::Coordinator("merger output thread is gone".into()))
        };
        match &self.recorder {
            // Queue wait is "running but not useful" — VTune's green.
            Some(r) => r.record(SpanKind::Running, send),
            None => send(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings as CSettings};
    use crate::format::reader::FileReader;
    use crate::serial::schema::{ColumnType, Field};
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::reader::TreeReader;
    use crate::tree::writer::FlushMode;

    fn schema() -> Schema {
        Schema::new(vec![Field::new("n", ColumnType::I32)])
    }

    fn config() -> MergerConfig {
        MergerConfig {
            tree_name: "mytree".into(),
            queue_depth: 4,
            writer: WriterConfig {
                basket_entries: 64,
                compression: CSettings::new(Codec::Lz4r, 3),
                flush: FlushMode::Serial,
                ..Default::default()
            },
        }
    }

    /// The paper's Figure 5 example: nWorkers threads, each filling a
    /// contiguous range, merged into one file.
    fn write_tree(n_entries: usize, n_workers: usize) -> (Arc<MemBackend>, MergeStats) {
        let be = Arc::new(MemBackend::new());
        let merger = TBufferMerger::create(be.clone(), schema(), config()).unwrap();
        let per = n_entries / n_workers;
        std::thread::scope(|s| {
            for w in 0..n_workers {
                let mut f = merger.get_file();
                s.spawn(move || {
                    for i in 0..per {
                        f.fill(vec![Value::I32((w * per + i) as i32)]).unwrap();
                    }
                    f.write().unwrap();
                });
            }
        });
        let stats = merger.close().unwrap();
        (be, stats)
    }

    #[test]
    fn figure5_example_roundtrip() {
        let (be, stats) = write_tree(1000, 4);
        assert_eq!(stats.entries, 1000);
        assert_eq!(stats.buffers_merged, 4);
        let file = Arc::new(FileReader::open(be).unwrap());
        let r = TreeReader::open(file, "mytree").unwrap();
        assert_eq!(r.entries(), 1000);
        let cols = r.read_all().unwrap();
        // Entries are a permutation-free multiset union of worker ranges:
        // each worker's block is contiguous, blocks may interleave.
        let mut vals: Vec<i32> = (0..1000)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::I32(v) => v,
                _ => unreachable!(),
            })
            .collect();
        vals.sort();
        assert_eq!(vals, (0..1000).collect::<Vec<i32>>());
    }

    #[test]
    fn single_worker_preserves_order() {
        let (be, _) = write_tree(500, 1);
        let file = Arc::new(FileReader::open(be).unwrap());
        let r = TreeReader::open(file, "mytree").unwrap();
        let cols = r.read_all().unwrap();
        for i in 0..500 {
            assert_eq!(cols[0].get(i), Some(Value::I32(i as i32)));
        }
    }

    #[test]
    fn pipelined_workers_preserve_entry_multiset() {
        // Workers fill with the pipelined flush (the default config):
        // compression overlaps filling on the IMT pool, and the merged
        // output must hold exactly the same entries.
        let be = Arc::new(MemBackend::new());
        let mut cfg = config();
        cfg.writer.flush = FlushMode::Pipelined;
        crate::imt::enable(2);
        let merger = TBufferMerger::create(be.clone(), schema(), cfg).unwrap();
        std::thread::scope(|s| {
            for w in 0..3 {
                let mut f = merger.get_file();
                s.spawn(move || {
                    for i in 0..300 {
                        f.fill(vec![Value::I32(w * 1000 + i)]).unwrap();
                    }
                    f.write().unwrap();
                });
            }
        });
        let stats = merger.close().unwrap();
        crate::imt::disable();
        assert_eq!(stats.entries, 900);
        let file = Arc::new(FileReader::open(be).unwrap());
        let r = TreeReader::open(file, "mytree").unwrap();
        let cols = r.read_all().unwrap();
        let mut vals: Vec<i32> = (0..900)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::I32(v) => v,
                _ => unreachable!(),
            })
            .collect();
        vals.sort();
        let mut want: Vec<i32> =
            (0..3).flat_map(|w| (0..300).map(move |i| w * 1000 + i)).collect();
        want.sort();
        assert_eq!(vals, want);
    }

    #[test]
    fn workers_share_the_session_budget() {
        let be = Arc::new(MemBackend::new());
        let pool = Arc::new(crate::imt::Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::for_writers(3, 2));
        let mut cfg = config();
        cfg.writer.flush = FlushMode::Pipelined;
        let merger =
            TBufferMerger::create_in_session(be.clone(), schema(), cfg, None, &session)
                .unwrap();
        std::thread::scope(|s| {
            for w in 0..3 {
                let mut f = merger.get_file();
                s.spawn(move || {
                    for i in 0..256 {
                        f.fill(vec![Value::I32(w * 1000 + i)]).unwrap();
                    }
                    f.write().unwrap();
                });
            }
        });
        let stats = merger.close().unwrap();
        assert_eq!(stats.entries, 3 * 256);
        let st = session.stats();
        assert_eq!(st.writers_opened, 3, "all worker files registered on the session");
        assert!(st.admissions >= 3 * 4, "every flushed cluster was admitted");
        assert_eq!(st.in_flight_clusters, 0, "budget fully released after close");
    }

    #[test]
    fn adaptive_workers_report_cluster_band_and_preserve_entries() {
        use crate::tree::sizer::{AdaptiveConfig, ClusterSizing};
        let be = Arc::new(MemBackend::new());
        let pool = Arc::new(crate::imt::Pool::new(2));
        let session = Session::with_pool(pool, SessionConfig::for_writers(2, 2));
        let mut cfg = config();
        cfg.writer.flush = FlushMode::Pipelined;
        cfg.writer.basket_entries = 32;
        cfg.writer.sizing = ClusterSizing::Adaptive(AdaptiveConfig {
            min_entries: 16,
            max_entries: 256,
            hysteresis: 1,
            warmup: 0,
            ..Default::default()
        });
        let merger =
            TBufferMerger::create_in_session(be.clone(), schema(), cfg, None, &session)
                .unwrap();
        std::thread::scope(|s| {
            for w in 0..2 {
                let mut f = merger.get_file();
                s.spawn(move || {
                    for i in 0..500 {
                        f.fill(vec![Value::I32(w * 10_000 + i)]).unwrap();
                    }
                    f.write().unwrap();
                });
            }
        });
        let stats = merger.close().unwrap();
        assert_eq!(stats.entries, 1000);
        assert!(stats.cluster_entries_min >= 16, "band floor respected");
        assert!(stats.cluster_entries_max <= 256, "band ceiling respected");
        assert!(stats.cluster_entries_min <= stats.cluster_entries_max);
        // Entry multiset must survive whatever sizes were chosen.
        let file = Arc::new(FileReader::open(be).unwrap());
        let r = TreeReader::open(file, "mytree").unwrap();
        let cols = r.read_all().unwrap();
        let mut vals: Vec<i32> = (0..1000)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::I32(v) => v,
                _ => unreachable!(),
            })
            .collect();
        vals.sort();
        let mut want: Vec<i32> =
            (0..2).flat_map(|w| (0..500).map(move |i| w * 10_000 + i)).collect();
        want.sort();
        assert_eq!(vals, want);
    }

    #[test]
    fn write_is_one_shot() {
        let be = Arc::new(MemBackend::new());
        let merger = TBufferMerger::create(be, schema(), config()).unwrap();
        let mut f = merger.get_file();
        f.fill(vec![Value::I32(1)]).unwrap();
        f.write().unwrap();
        assert!(f.write().is_err());
        assert!(f.fill(vec![Value::I32(2)]).is_err());
        merger.close().unwrap();
    }

    #[test]
    fn empty_merger_closes_clean() {
        let be = Arc::new(MemBackend::new());
        let merger = TBufferMerger::create(be.clone(), schema(), config()).unwrap();
        let stats = merger.close().unwrap();
        assert_eq!(stats.entries, 0);
        // file is still a valid (empty) tree
        let file = Arc::new(FileReader::open(be).unwrap());
        assert_eq!(file.directory().trees[0].entries, 0);
    }

    #[test]
    fn many_buffers_per_worker() {
        let be = Arc::new(MemBackend::new());
        let merger = TBufferMerger::create(be.clone(), schema(), config()).unwrap();
        for round in 0..10 {
            let mut f = merger.get_file();
            for i in 0..100 {
                f.fill(vec![Value::I32(round * 100 + i)]).unwrap();
            }
            f.write().unwrap();
        }
        let stats = merger.close().unwrap();
        assert_eq!(stats.entries, 1000);
        assert_eq!(stats.buffers_merged, 10);
        let file = Arc::new(FileReader::open(be).unwrap());
        let r = TreeReader::open(file, "mytree").unwrap();
        let cols = r.read_all().unwrap();
        // single producer -> queue order preserved
        for i in 0..1000 {
            assert_eq!(cols[0].get(i), Some(Value::I32(i as i32)));
        }
    }
}
