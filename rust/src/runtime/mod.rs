//! PJRT runtime: executes the AOT-compiled JAX/Pallas compute graphs
//! from `artifacts/*.hlo.txt` on the request path. Python never runs at
//! request time — `make artifacts` is the only Python step.
//!
//! ## Threading model
//!
//! The `xla` crate's `PjRtClient` is reference-counted with a
//! non-atomic `Rc`, so it must never be touched from two threads. The
//! engine therefore runs a dedicated **runtime service thread** that
//! owns the client and every compiled executable; callers submit
//! requests over a channel and block on a per-request response channel.
//! PJRT dispatch is microseconds against event-block compute of
//! hundreds of microseconds, so a single dispatcher does not bottleneck
//! the coordinator (measured in EXPERIMENTS.md §Perf).

mod meta;

pub use meta::ArtifactsMeta;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// A generated event block: `n` events × `ncols` f32 columns, flattened
/// row-major (event-major) exactly as the L2 graph emits it.
#[derive(Clone, Debug)]
pub struct EventBlock {
    pub n: usize,
    pub ncols: usize,
    /// row-major (n, ncols)
    pub data: Vec<f32>,
}

impl EventBlock {
    /// Extract column `c` as a contiguous vector.
    pub fn column(&self, c: usize) -> Vec<f32> {
        (0..self.n).map(|i| self.data[i * self.ncols + c]).collect()
    }

    /// All columns, column-major (what the tree writer wants).
    pub fn columns(&self) -> Vec<Vec<f32>> {
        (0..self.ncols).map(|c| self.column(c)).collect()
    }
}

/// Result of the analysis graph on one block.
#[derive(Clone, Debug)]
pub struct AnalysisResult {
    /// Per-event invariant mass.
    pub mass: Vec<f32>,
    /// Histogram counts (length = meta.nbins).
    pub hist: Vec<f32>,
}

enum Request {
    Generate { seed: [u32; 2], block: usize, resp: Sender<Result<Vec<f32>>> },
    Analyze { data: Vec<f32>, block: usize, resp: Sender<Result<(Vec<f32>, Vec<f32>)>> },
    Shutdown,
}

/// Handle to the runtime service thread.
pub struct Engine {
    tx: Sender<Request>,
    meta: ArtifactsMeta,
    handle: Option<JoinHandle<()>>,
}

impl Engine {
    /// Load every artifact under `dir` and compile it on the service
    /// thread. Fails fast if any artifact is missing or un-compilable.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let meta = ArtifactsMeta::load(&dir)?;
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread_meta = meta.clone();
        let handle = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || service_loop(dir, thread_meta, rx, ready_tx))
            .map_err(Error::Io)?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread died during startup".into()))??;
        Ok(Engine { tx, meta, handle: Some(handle) })
    }

    /// Default artifacts location (`$ROOTIO_ARTIFACTS` or `./artifacts`).
    pub fn load_default() -> Result<Engine> {
        let dir = std::env::var("ROOTIO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Engine::load(dir)
    }

    pub fn meta(&self) -> &ArtifactsMeta {
        &self.meta
    }

    /// Largest supported block size.
    pub fn max_block(&self) -> usize {
        *self.meta.blocks.last().expect("at least one block size")
    }

    /// Generate one event block via the AOT PRNG+shaping graph.
    pub fn generate(&self, seed: u32, stream: u32, block: usize) -> Result<EventBlock> {
        self.meta.check_block(block)?;
        let (resp, rx) = channel();
        self.tx
            .send(Request::Generate { seed: [seed, stream], block, resp })
            .map_err(|_| Error::Runtime("runtime thread is gone".into()))?;
        let data =
            rx.recv().map_err(|_| Error::Runtime("runtime thread dropped request".into()))??;
        Ok(EventBlock { n: block, ncols: self.meta.ncols, data })
    }

    /// Run the analysis graph on a row-major (block, ncols) buffer.
    pub fn analyze(&self, data: Vec<f32>, block: usize) -> Result<AnalysisResult> {
        self.meta.check_block(block)?;
        if data.len() != block * self.meta.ncols {
            return Err(Error::Runtime(format!(
                "analyze: buffer has {} floats, want {}x{}",
                data.len(),
                block,
                self.meta.ncols
            )));
        }
        let (resp, rx) = channel();
        self.tx
            .send(Request::Analyze { data, block, resp })
            .map_err(|_| Error::Runtime("runtime thread is gone".into()))?;
        let (mass, hist) =
            rx.recv().map_err(|_| Error::Runtime("runtime thread dropped request".into()))??;
        Ok(AnalysisResult { mass, hist })
    }

    /// Analyze an [`EventBlock`] directly.
    pub fn analyze_block(&self, block: &EventBlock) -> Result<AnalysisResult> {
        self.analyze(block.data.clone(), block.n)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn compile_artifact(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| Error::Runtime(format!("load {}: {e}", path.display())))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| Error::Runtime(format!("compile {name}: {e}")))
}

fn service_loop(
    dir: PathBuf,
    meta: ArtifactsMeta,
    rx: std::sync::mpsc::Receiver<Request>,
    ready: Sender<Result<()>>,
) {
    // Build client + executables; report startup outcome.
    let setup = (|| -> Result<_> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
        let mut gens = HashMap::new();
        let mut anas = HashMap::new();
        for &b in &meta.blocks {
            gens.insert(b, compile_artifact(&client, &dir, &format!("gen_{b}"))?);
            anas.insert(b, compile_artifact(&client, &dir, &format!("analyze_{b}"))?);
        }
        Ok((client, gens, anas))
    })();
    let (_client, gens, anas) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Generate { seed, block, resp } => {
                let out = (|| -> Result<Vec<f32>> {
                    let exe = gens.get(&block).unwrap();
                    let lit = xla::Literal::vec1(&seed[..]);
                    let bufs = exe
                        .execute::<xla::Literal>(&[lit])
                        .map_err(|e| Error::Runtime(format!("execute gen: {e}")))?;
                    let lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| Error::Runtime(format!("fetch gen: {e}")))?;
                    let out = lit
                        .to_tuple1()
                        .map_err(|e| Error::Runtime(format!("untuple gen: {e}")))?;
                    out.to_vec::<f32>().map_err(|e| Error::Runtime(format!("gen to_vec: {e}")))
                })();
                let _ = resp.send(out);
            }
            Request::Analyze { data, block, resp } => {
                let out = (|| -> Result<(Vec<f32>, Vec<f32>)> {
                    let exe = anas.get(&block).unwrap();
                    let lit = xla::Literal::vec1(&data)
                        .reshape(&[block as i64, meta.ncols as i64])
                        .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
                    let bufs = exe
                        .execute::<xla::Literal>(&[lit])
                        .map_err(|e| Error::Runtime(format!("execute analyze: {e}")))?;
                    let lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| Error::Runtime(format!("fetch analyze: {e}")))?;
                    let (mass, hist) = lit
                        .to_tuple2()
                        .map_err(|e| Error::Runtime(format!("untuple analyze: {e}")))?;
                    Ok((
                        mass.to_vec::<f32>()
                            .map_err(|e| Error::Runtime(format!("mass to_vec: {e}")))?,
                        hist.to_vec::<f32>()
                            .map_err(|e| Error::Runtime(format!("hist to_vec: {e}")))?,
                    ))
                })();
                let _ = resp.send(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::env::var("ROOTIO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        if p.join("meta.txt").exists() {
            Some(p)
        } else {
            eprintln!("skipping runtime test: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn generate_and_analyze_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(dir).unwrap();
        let block = engine.meta().blocks[0];
        let ev = engine.generate(42, 0, block).unwrap();
        assert_eq!(ev.data.len(), block * engine.meta().ncols);
        // physics sanity: pt >= 0, |eta| <= 2.5
        let pt = ev.column(0);
        assert!(pt.iter().all(|&x| x >= 0.0 && x.is_finite()));
        let eta = ev.column(1);
        assert!(eta.iter().all(|&x| x.abs() <= 2.5 + 1e-5));

        let res = engine.analyze_block(&ev).unwrap();
        assert_eq!(res.mass.len(), block);
        assert_eq!(res.hist.len(), engine.meta().nbins);
        let total: f32 = res.hist.iter().sum();
        assert_eq!(total as usize, block, "histogram counts all events");
        assert!(res.mass.iter().all(|&m| m >= 0.0 && m.is_finite()));
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(dir).unwrap();
        let block = engine.meta().blocks[0];
        let a = engine.generate(7, 3, block).unwrap();
        let b = engine.generate(7, 3, block).unwrap();
        let c = engine.generate(7, 4, block).unwrap();
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn concurrent_requests_from_many_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = std::sync::Arc::new(Engine::load(dir).unwrap());
        let block = engine.meta().blocks[0];
        std::thread::scope(|s| {
            for t in 0..8 {
                let engine = engine.clone();
                s.spawn(move || {
                    let ev = engine.generate(1, t as u32, block).unwrap();
                    let res = engine.analyze_block(&ev).unwrap();
                    assert_eq!(res.hist.iter().sum::<f32>() as usize, block);
                });
            }
        });
    }

    #[test]
    fn bad_block_size_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(dir).unwrap();
        assert!(engine.generate(0, 0, 12345).is_err());
        assert!(engine.analyze(vec![0.0; 8], 12345).is_err());
    }

    #[test]
    fn missing_artifacts_dir_is_an_error() {
        assert!(Engine::load("/nonexistent/artifacts").is_err());
    }
}
