//! Artifact metadata (`artifacts/meta.txt`, emitted by `aot.py`).

use std::path::Path;

use crate::error::{Error, Result};

/// Shapes and constants shared between the L2 graphs and the rust side.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactsMeta {
    pub ncols: usize,
    pub nbins: usize,
    pub hist_lo: f64,
    pub hist_hi: f64,
    /// Supported event-block sizes, ascending.
    pub blocks: Vec<usize>,
}

impl ArtifactsMeta {
    /// Parse `meta.txt` (whitespace-separated `key value...` lines).
    pub fn parse(text: &str) -> Result<Self> {
        let mut ncols = None;
        let mut nbins = None;
        let mut hist_lo = None;
        let mut hist_hi = None;
        let mut blocks: Vec<usize> = Vec::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            let Some(key) = it.next() else { continue };
            match key {
                "ncols" => ncols = it.next().and_then(|v| v.parse().ok()),
                "nbins" => nbins = it.next().and_then(|v| v.parse().ok()),
                "hist_lo" => hist_lo = it.next().and_then(|v| v.parse().ok()),
                "hist_hi" => hist_hi = it.next().and_then(|v| v.parse().ok()),
                "blocks" => blocks = it.filter_map(|v| v.parse().ok()).collect(),
                _ => {}
            }
        }
        let meta = ArtifactsMeta {
            ncols: ncols.ok_or_else(|| Error::Runtime("meta.txt: missing ncols".into()))?,
            nbins: nbins.ok_or_else(|| Error::Runtime("meta.txt: missing nbins".into()))?,
            hist_lo: hist_lo.ok_or_else(|| Error::Runtime("meta.txt: missing hist_lo".into()))?,
            hist_hi: hist_hi.ok_or_else(|| Error::Runtime("meta.txt: missing hist_hi".into()))?,
            blocks,
        };
        if meta.blocks.is_empty() {
            return Err(Error::Runtime("meta.txt: no block sizes".into()));
        }
        if !meta.blocks.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::Runtime("meta.txt: blocks not ascending".into()));
        }
        Ok(meta)
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn check_block(&self, block: usize) -> Result<()> {
        if self.blocks.contains(&block) {
            Ok(())
        } else {
            Err(Error::Runtime(format!(
                "block size {block} not compiled (available: {:?})",
                self.blocks
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "ncols 8\nnbins 64\nhist_lo 0.0\nhist_hi 160.0\nblocks 4096 16384\n";

    #[test]
    fn parse_sample() {
        let m = ArtifactsMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.ncols, 8);
        assert_eq!(m.nbins, 64);
        assert_eq!(m.blocks, vec![4096, 16384]);
        m.check_block(4096).unwrap();
        assert!(m.check_block(999).is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(ArtifactsMeta::parse("ncols 8\n").is_err());
        assert!(ArtifactsMeta::parse("").is_err());
        assert!(ArtifactsMeta::parse(
            "ncols 8\nnbins 64\nhist_lo 0\nhist_hi 1\nblocks\n"
        )
        .is_err());
    }

    #[test]
    fn unknown_keys_ignored() {
        let m = ArtifactsMeta::parse(&format!("comment hello\n{SAMPLE}")).unwrap();
        assert_eq!(m.ncols, 8);
    }

    #[test]
    fn unsorted_blocks_rejected() {
        let bad = "ncols 8\nnbins 64\nhist_lo 0\nhist_hi 1\nblocks 16384 4096\n";
        assert!(ArtifactsMeta::parse(bad).is_err());
    }
}
