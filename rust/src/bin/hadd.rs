//! `hadd` — merge RNTF files (paper §3.4).
//!
//! ```text
//! hadd [-j [N]] <output.rntf> <input.rntf>...
//! ```
//!
//! `-j` enables parallel input reading on N threads (default: all
//! cores), mirroring ROOT's `hadd -j`.

use std::process::ExitCode;
use std::sync::Arc;

use rootio_par::error::Result;
use rootio_par::hadd::{hadd, HaddOptions};
use rootio_par::imt;
use rootio_par::storage::local::LocalFile;
use rootio_par::storage::BackendRef;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("hadd: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let mut parallel = false;
    let mut jobs = 0usize;
    if let Some(pos) = args.iter().position(|a| a == "-j") {
        parallel = true;
        args.remove(pos);
        // optional numeric argument right after -j
        if pos < args.len() {
            if let Ok(n) = args[pos].parse::<usize>() {
                jobs = n;
                args.remove(pos);
            }
        }
    }
    if args.len() < 2 {
        eprintln!("usage: hadd [-j [N]] <output.rntf> <input.rntf>...");
        return Err(rootio_par::Error::Coordinator("need an output and at least one input".into()));
    }
    if parallel {
        imt::enable(jobs);
    }
    let output: BackendRef = Arc::new(LocalFile::create(&args[0])?);
    let inputs: Vec<BackendRef> = args[1..]
        .iter()
        .map(|p| LocalFile::open(p).map(|f| Arc::new(f) as BackendRef))
        .collect::<Result<_>>()?;
    let rep = hadd(output, &inputs, &HaddOptions { parallel, tree: None })?;
    println!(
        "merged {} files -> {}: {} entries, {:.1} MB stored, {:.1} ms ({}, \
         baskets {}..{} entries)",
        rep.files,
        args[0],
        rep.entries,
        rep.stored_bytes as f64 / 1e6,
        rep.wall.as_secs_f64() * 1e3,
        if parallel { "parallel" } else { "serial" },
        rep.cluster_entries_min,
        rep.cluster_entries_max,
    );
    Ok(())
}
