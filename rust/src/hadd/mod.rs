//! `hadd`: merging many existing files into one (paper §3.4).
//!
//! Fast merge in the ROOT sense: baskets are copied *without*
//! re-compression; only entry numbers are rebased. The parallel mode
//! (`hadd -j`) loads and checksum-verifies the input files as
//! task-group jobs in an I/O [`Session`]'s completion domain (a
//! private one, or the job-wide session via [`hadd_in_session`]) —
//! the dominant cost — while
//! the output side consumes the buffers *in input order as each one
//! completes*, pipelining device appends with the remaining reads. A
//! small reorder stash keeps the append order equal to the input
//! order, so serial and parallel merges produce byte-identical files,
//! and each buffer is dropped as soon as its bytes are on the device
//! (peak memory is no longer all inputs at once).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, Directory, TreeMeta};
use crate::format::reader::FileReader;
use crate::format::writer::FileWriter;
use crate::serial::schema::Schema;
use crate::session::{Session, SessionConfig};
use crate::storage::BackendRef;
use crate::tree::buffer::{BasketPayload, TreeBuffer};

/// hadd options.
#[derive(Clone, Debug)]
pub struct HaddOptions {
    /// Parallel input reading (the `-j` flag). Uses the IMT pool.
    pub parallel: bool,
    /// Merge only this tree (default: first tree of the first file).
    pub tree: Option<String>,
}

impl Default for HaddOptions {
    fn default() -> Self {
        HaddOptions { parallel: false, tree: None }
    }
}

/// Merge accounting.
#[derive(Clone, Copy, Debug)]
pub struct HaddReport {
    pub files: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub wall: std::time::Duration,
    /// Smallest basket (entries) observed across the merged inputs —
    /// hadd never re-baskets, so this reports the cluster-size spread
    /// the *writers* chose (0 for an empty merge). Inputs written
    /// with `ClusterSizing::Adaptive` show up here as a wide band.
    pub cluster_entries_min: u32,
    /// Largest basket (entries) observed across the merged inputs.
    pub cluster_entries_max: u32,
}

use crate::cache::plan::DEFAULT_COALESCE_GAP;

/// Load one input file's tree into an in-memory [`TreeBuffer`]
/// (compressed bytes, CRC-verified). Fetches are **coalesced**
/// ([`crate::cache::fetch_baskets_coalesced`]): the writer lays
/// baskets out back-to-back, so a whole input loads in a handful of
/// large sequential reads (each capped at
/// [`crate::cache::plan::MAX_BULK_FETCH`] so scratch stays bounded)
/// instead of one seeking read per basket — on seek-dominated devices
/// that is where `hadd`'s input time goes.
fn load_input(input: &BackendRef, tree: &Option<String>) -> Result<TreeBuffer> {
    let reader = FileReader::open(input.clone())?;
    let meta = match tree {
        Some(name) => reader
            .directory()
            .tree(name)
            .ok_or_else(|| Error::Format(format!("no tree '{name}'")))?,
        None => reader
            .directory()
            .trees
            .first()
            .ok_or_else(|| Error::Format("input has no trees".into()))?,
    };
    let mut buf = TreeBuffer::new(meta.schema.clone());
    buf.entries = meta.entries;
    let infos: Vec<BasketInfo> =
        meta.branches.iter().flat_map(|br| br.baskets.iter().copied()).collect();
    let mut payloads =
        crate::cache::fetch_baskets_coalesced(input, &infos, DEFAULT_COALESCE_GAP)?
            .into_iter();
    for (bb, br) in buf.branches.iter_mut().zip(&meta.branches) {
        for k in &br.baskets {
            let bytes = payloads.next().ok_or_else(|| {
                Error::Sync("hadd: coalesced fetch lost a basket payload".into())
            })?;
            bb.baskets.push(BasketPayload {
                bytes,
                raw_len: k.raw_len,
                first_entry: k.first_entry,
                n_entries: k.n_entries,
                settings: k.settings,
            });
        }
    }
    Ok(buf)
}

/// Streaming output side of the merge: appends each input's baskets in
/// input order, rebasing entry numbers; buffers drop as soon as their
/// bytes are appended.
struct Appender {
    fw: Arc<FileWriter>,
    schema: Option<Schema>,
    branches: Vec<BranchMeta>,
    entries: u64,
    stored: u64,
    /// Basket-size spread (entries) across everything appended.
    cluster_min: u32,
    cluster_max: u32,
}

impl Appender {
    fn new(fw: Arc<FileWriter>) -> Self {
        Appender {
            fw,
            schema: None,
            branches: Vec::new(),
            entries: 0,
            stored: 0,
            cluster_min: 0,
            cluster_max: 0,
        }
    }

    fn push(&mut self, index: usize, buf: &TreeBuffer) -> Result<()> {
        match &self.schema {
            None => {
                self.schema = Some(buf.schema.clone());
                self.branches = buf
                    .schema
                    .fields
                    .iter()
                    .map(|f| BranchMeta { name: f.name.clone(), ty: f.ty, baskets: Vec::new() })
                    .collect();
            }
            Some(s) if *s != buf.schema => {
                return Err(Error::Schema(format!("input {index} has a different schema")));
            }
            Some(_) => {}
        }
        for (dst, src) in self.branches.iter_mut().zip(&buf.branches) {
            for k in &src.baskets {
                let (offset, crc) = self.fw.append(&k.bytes)?;
                self.stored += k.bytes.len() as u64;
                if k.n_entries > 0 {
                    self.cluster_min = if self.cluster_min == 0 {
                        k.n_entries
                    } else {
                        self.cluster_min.min(k.n_entries)
                    };
                    self.cluster_max = self.cluster_max.max(k.n_entries);
                }
                dst.baskets.push(BasketInfo {
                    offset,
                    comp_len: k.bytes.len() as u32,
                    raw_len: k.raw_len,
                    first_entry: self.entries + k.first_entry,
                    n_entries: k.n_entries,
                    crc,
                    settings: k.settings,
                });
            }
        }
        self.entries += buf.entries;
        Ok(())
    }

    fn finish(self, name: String) -> Result<(TreeMeta, u64, u64, (u32, u32))> {
        let schema = self
            .schema
            .ok_or_else(|| Error::Coordinator("hadd: no inputs appended".into()))?;
        let meta = TreeMeta { name, schema, entries: self.entries, branches: self.branches };
        meta.check()?;
        Ok((meta, self.entries, self.stored, (self.cluster_min, self.cluster_max)))
    }
}

/// Merge `inputs` into a fresh file on `output`, under a private
/// session on the global IMT pool. Jobs that already hold a shared
/// [`Session`] should call [`hadd_in_session`] so the loader tasks
/// land in the same pool/completion domain as the job's writers.
pub fn hadd(output: BackendRef, inputs: &[BackendRef], opts: &HaddOptions) -> Result<HaddReport> {
    hadd_in_session(output, inputs, opts, &Session::new(SessionConfig::default()))
}

/// Merge `inputs` into a fresh file on `output`; parallel input loads
/// run as task-group jobs in `session`'s completion domain.
pub fn hadd_in_session(
    output: BackendRef,
    inputs: &[BackendRef],
    opts: &HaddOptions,
    session: &Session,
) -> Result<HaddReport> {
    if inputs.is_empty() {
        return Err(Error::Coordinator("hadd: no input files".into()));
    }
    let t0 = Instant::now();
    let fw = Arc::new(FileWriter::create(output)?);
    let mut appender = Appender::new(fw.clone());

    if opts.parallel && session.is_parallel() {
        // Pipelined -j: loads run as task-group jobs; the appender
        // consumes buffers in input order as they complete, so device
        // appends overlap the remaining reads.
        let group = session.task_group();
        let (tx, rx) = std::sync::mpsc::channel();
        for (i, input) in inputs.iter().enumerate() {
            let tx = tx.clone();
            let input = input.clone();
            let tree = opts.tree.clone();
            group.spawn(move || {
                let _ = tx.send((i, load_input(&input, &tree)));
            });
        }
        drop(tx);
        let mut stash: BTreeMap<usize, TreeBuffer> = BTreeMap::new();
        let mut next = 0usize;
        while next < inputs.len() {
            let (i, loaded) = match rx.try_recv() {
                Ok(msg) => msg,
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    let pending = group.pending();
                    if pending > 0 {
                        // Help run loader jobs (or park until one
                        // completes) instead of blocking on the
                        // channel, so this also works when called
                        // from inside a pool worker.
                        group.wait_below(pending - 1);
                        continue;
                    }
                    // pending hit 0 between our try_recv and the read
                    // above — the final result may have been sent in
                    // that window, so poll once more before declaring
                    // a loader dead (panicked without delivering).
                    match rx.try_recv() {
                        Ok(msg) => msg,
                        Err(_) => {
                            group.join()?;
                            return Err(Error::Coordinator(
                                "hadd: input loader dropped its result".into(),
                            ));
                        }
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    group.join()?;
                    return Err(Error::Coordinator(
                        "hadd: input loader dropped its result".into(),
                    ));
                }
            };
            stash.insert(i, loaded?);
            while let Some(buf) = stash.remove(&next) {
                appender.push(next, &buf)?;
                next += 1;
            }
        }
        group.join()?;
    } else {
        // Serial: load-append one input at a time (streaming, so peak
        // memory is one input even without -j).
        for (i, input) in inputs.iter().enumerate() {
            let buf = load_input(input, &opts.tree)?;
            appender.push(i, &buf)?;
        }
    }

    let name = opts.tree.clone().unwrap_or_else(|| "events".into());
    let (meta, entries, stored, (cluster_min, cluster_max)) = appender.finish(name)?;
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(HaddReport {
        files: inputs.len(),
        entries,
        stored_bytes: stored,
        wall: t0.elapsed(),
        cluster_entries_min: cluster_min,
        cluster_entries_max: cluster_max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::coordinator::write::write_blocks;
    use crate::serial::column::ColumnData;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use crate::tree::reader::TreeReader;
    use crate::tree::writer::FlushMode;

    fn make_input(start: i32, n: usize) -> BackendRef {
        let schema = Schema::flat_f32("v", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let block: Vec<ColumnData> = (0..2)
            .map(|b| ColumnData::F32((0..n).map(|i| (start + i as i32 + b) as f32).collect()))
            .collect();
        let cfg = crate::tree::writer::WriterConfig {
            basket_entries: 64,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        write_blocks(be.clone(), schema, "events", cfg, vec![block]).unwrap();
        be
    }

    fn read_first_col(be: BackendRef) -> Vec<f32> {
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let cols = r.read_all().unwrap();
        (0..r.entries() as usize)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::F32(v) => v,
                _ => unreachable!(),
            })
            .collect()
    }

    fn dump(be: &BackendRef) -> Vec<u8> {
        let len = be.len().unwrap() as usize;
        let mut bytes = vec![0u8; len];
        be.read_at(0, &mut bytes).unwrap();
        bytes
    }

    #[test]
    fn serial_merge_concatenates_in_order() {
        let inputs = vec![make_input(0, 100), make_input(100, 100), make_input(200, 50)];
        let out: BackendRef = Arc::new(MemBackend::new());
        let rep = hadd(out.clone(), &inputs, &HaddOptions::default()).unwrap();
        assert_eq!(rep.files, 3);
        assert_eq!(rep.entries, 250);
        // inputs were cut at 64-entry clusters with uneven tails: the
        // reported basket-size spread covers tail..full baskets
        assert_eq!(rep.cluster_entries_max, 64);
        assert!(rep.cluster_entries_min >= 1 && rep.cluster_entries_min <= 64);
        let vals = read_first_col(out);
        assert_eq!(vals, (0..250).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_merge_byte_identical_to_serial() {
        let inputs: Vec<BackendRef> =
            (0..6).map(|i| make_input(i * 100, 100)).collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        crate::imt::enable(4);
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: None }).unwrap();
        crate::imt::disable();
        // the pipelined append order equals the input order, so the
        // output is byte-identical, not merely equivalent
        assert_eq!(dump(&serial_out), dump(&par_out));
        assert_eq!(read_first_col(serial_out), read_first_col(par_out));
    }

    #[test]
    fn hadd_in_explicit_session_matches_serial_bytes() {
        // A dedicated-pool session: -j parallelism without touching the
        // global IMT switch, byte-identical to the serial merge.
        let inputs: Vec<BackendRef> = (0..4).map(|i| make_input(i * 50, 50)).collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        let pool = Arc::new(crate::imt::Pool::new(3));
        let session = crate::session::Session::with_pool(
            pool,
            crate::session::SessionConfig::default(),
        );
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd_in_session(
            par_out.clone(),
            &inputs,
            &HaddOptions { parallel: true, tree: None },
            &session,
        )
        .unwrap();
        assert_eq!(dump(&serial_out), dump(&par_out));
    }

    #[test]
    fn schema_mismatch_rejected() {
        let a = make_input(0, 10);
        let b: BackendRef = Arc::new(MemBackend::new());
        let schema = Schema::flat_f32("other", 3);
        let block: Vec<ColumnData> =
            (0..3).map(|_| ColumnData::F32(vec![1.0; 10])).collect();
        write_blocks(
            b.clone(),
            schema,
            "events",
            crate::tree::writer::WriterConfig::default(),
            vec![block],
        )
        .unwrap();
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[a, b], &HaddOptions::default()).is_err());
    }

    #[test]
    fn empty_inputs_rejected() {
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[], &HaddOptions::default()).is_err());
    }
}
