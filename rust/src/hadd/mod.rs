//! `hadd`: merging many existing files into one (paper §3.4).
//!
//! Fast merge in the ROOT sense: baskets are copied *without*
//! re-compression; only entry numbers are rebased. The parallel mode
//! (`hadd -j`) reads and validates the input files on the IMT pool —
//! the dominant cost — while the output append stays in input order so
//! serial and parallel merges produce byte-identical directories.

use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, Directory, TreeMeta};
use crate::format::reader::FileReader;
use crate::format::writer::FileWriter;
use crate::imt;
use crate::storage::BackendRef;
use crate::tree::buffer::{BasketPayload, TreeBuffer};

/// hadd options.
#[derive(Clone, Debug)]
pub struct HaddOptions {
    /// Parallel input reading (the `-j` flag). Uses the IMT pool.
    pub parallel: bool,
    /// Merge only this tree (default: first tree of the first file).
    pub tree: Option<String>,
}

impl Default for HaddOptions {
    fn default() -> Self {
        HaddOptions { parallel: false, tree: None }
    }
}

/// Merge accounting.
#[derive(Clone, Copy, Debug)]
pub struct HaddReport {
    pub files: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub wall: std::time::Duration,
}

/// Load one input file's tree into an in-memory [`TreeBuffer`]
/// (compressed bytes, CRC-verified).
fn load_input(input: &BackendRef, tree: &Option<String>) -> Result<TreeBuffer> {
    let reader = FileReader::open(input.clone())?;
    let meta = match tree {
        Some(name) => reader
            .directory()
            .tree(name)
            .ok_or_else(|| Error::Format(format!("no tree '{name}'")))?,
        None => reader
            .directory()
            .trees
            .first()
            .ok_or_else(|| Error::Format("input has no trees".into()))?,
    };
    let mut buf = TreeBuffer::new(meta.schema.clone());
    buf.entries = meta.entries;
    for (bb, br) in buf.branches.iter_mut().zip(&meta.branches) {
        for k in &br.baskets {
            bb.baskets.push(BasketPayload {
                bytes: reader.fetch_basket(k)?,
                raw_len: k.raw_len,
                first_entry: k.first_entry,
                n_entries: k.n_entries,
            });
        }
    }
    Ok(buf)
}

/// Merge `inputs` into a fresh file on `output`.
pub fn hadd(output: BackendRef, inputs: &[BackendRef], opts: &HaddOptions) -> Result<HaddReport> {
    if inputs.is_empty() {
        return Err(Error::Coordinator("hadd: no input files".into()));
    }
    let t0 = Instant::now();

    // Phase 1: read + checksum-verify inputs (parallel with -j).
    let buffers: Vec<Result<TreeBuffer>> = if opts.parallel && imt::is_enabled() {
        imt::parallel_map(inputs.len(), |i| load_input(&inputs[i], &opts.tree))
    } else {
        inputs.iter().map(|b| load_input(b, &opts.tree)).collect()
    };
    let buffers: Vec<TreeBuffer> = buffers.into_iter().collect::<Result<_>>()?;

    // Schema consistency across inputs.
    let schema = buffers[0].schema.clone();
    for (i, b) in buffers.iter().enumerate() {
        if b.schema != schema {
            return Err(Error::Schema(format!("input {i} has a different schema")));
        }
    }

    // Phase 2: append in input order, rebasing entries.
    let fw = Arc::new(FileWriter::create(output)?);
    let mut branches: Vec<BranchMeta> = schema
        .fields
        .iter()
        .map(|f| BranchMeta { name: f.name.clone(), ty: f.ty, baskets: Vec::new() })
        .collect();
    let mut entries = 0u64;
    let mut stored = 0u64;
    for buf in &buffers {
        for (dst, src) in branches.iter_mut().zip(&buf.branches) {
            for k in &src.baskets {
                let (offset, crc) = fw.append(&k.bytes)?;
                stored += k.bytes.len() as u64;
                dst.baskets.push(BasketInfo {
                    offset,
                    comp_len: k.bytes.len() as u32,
                    raw_len: k.raw_len,
                    first_entry: entries + k.first_entry,
                    n_entries: k.n_entries,
                    crc,
                });
            }
        }
        entries += buf.entries;
    }
    let meta = TreeMeta {
        name: opts.tree.clone().unwrap_or_else(|| "events".into()),
        schema,
        entries,
        branches,
    };
    meta.check()?;
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(HaddReport { files: inputs.len(), entries, stored_bytes: stored, wall: t0.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::coordinator::write::write_blocks;
    use crate::serial::column::ColumnData;
    use crate::serial::schema::Schema;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::tree::reader::TreeReader;

    fn make_input(start: i32, n: usize) -> BackendRef {
        let schema = Schema::flat_f32("v", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let block: Vec<ColumnData> = (0..2)
            .map(|b| ColumnData::F32((0..n).map(|i| (start + i as i32 + b) as f32).collect()))
            .collect();
        let cfg = crate::tree::writer::WriterConfig {
            basket_entries: 64,
            compression: Settings::new(Codec::Lz4r, 3),
            parallel_flush: false,
        };
        write_blocks(be.clone(), schema, "events", cfg, vec![block]).unwrap();
        be
    }

    fn read_first_col(be: BackendRef) -> Vec<f32> {
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let cols = r.read_all().unwrap();
        (0..r.entries() as usize)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::F32(v) => v,
                _ => unreachable!(),
            })
            .collect()
    }

    #[test]
    fn serial_merge_concatenates_in_order() {
        let inputs = vec![make_input(0, 100), make_input(100, 100), make_input(200, 50)];
        let out: BackendRef = Arc::new(MemBackend::new());
        let rep = hadd(out.clone(), &inputs, &HaddOptions::default()).unwrap();
        assert_eq!(rep.files, 3);
        assert_eq!(rep.entries, 250);
        let vals = read_first_col(out);
        assert_eq!(vals, (0..250).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_merge_identical_to_serial() {
        let inputs: Vec<BackendRef> =
            (0..6).map(|i| make_input(i * 100, 100)).collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        crate::imt::enable(4);
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: None }).unwrap();
        crate::imt::disable();
        assert_eq!(read_first_col(serial_out), read_first_col(par_out));
    }

    #[test]
    fn schema_mismatch_rejected() {
        let a = make_input(0, 10);
        let b: BackendRef = Arc::new(MemBackend::new());
        let schema = Schema::flat_f32("other", 3);
        let block: Vec<ColumnData> =
            (0..3).map(|_| ColumnData::F32(vec![1.0; 10])).collect();
        write_blocks(
            b.clone(),
            schema,
            "events",
            crate::tree::writer::WriterConfig::default(),
            vec![block],
        )
        .unwrap();
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[a, b], &HaddOptions::default()).is_err());
    }

    #[test]
    fn empty_inputs_rejected() {
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[], &HaddOptions::default()).is_err());
    }
}
