//! `hadd`: merging many existing files into one (paper §3.4).
//!
//! Fast merge in the ROOT sense: baskets are copied *without*
//! re-compression; only entry numbers are rebased. The parallel mode
//! (`hadd -j`) loads and checksum-verifies the input files as
//! task-group jobs in an I/O [`Session`]'s completion domain (a
//! private one, or the job-wide session via [`hadd_in_session`]) —
//! the dominant cost — while
//! the output side consumes the buffers *in input order as each one
//! completes*, pipelining device appends with the remaining reads. A
//! small reorder stash keeps the append order equal to the input
//! order, so serial and parallel merges produce byte-identical files,
//! and each buffer is dropped as soon as its bytes are on the device
//! (peak memory is no longer all inputs at once).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::format::directory::{BasketInfo, BranchMeta, Directory, TreeMeta};
use crate::format::reader::FileReader;
use crate::format::writer::FileWriter;
use crate::serial::schema::Schema;
use crate::session::{Session, SessionConfig};
use crate::storage::BackendRef;
use crate::tree::buffer::{BasketPayload, TreeBuffer};

/// hadd options.
#[derive(Clone, Debug)]
pub struct HaddOptions {
    /// Parallel input reading (the `-j` flag). Uses the IMT pool.
    pub parallel: bool,
    /// Merge only this tree (default: first tree of the first file).
    pub tree: Option<String>,
}

impl Default for HaddOptions {
    fn default() -> Self {
        HaddOptions { parallel: false, tree: None }
    }
}

/// Merge accounting.
#[derive(Clone, Copy, Debug)]
pub struct HaddReport {
    pub files: usize,
    pub entries: u64,
    pub stored_bytes: u64,
    pub wall: std::time::Duration,
    /// Smallest basket (entries) observed across the merged inputs —
    /// hadd never re-baskets, so this reports the cluster-size spread
    /// the *writers* chose (0 for an empty merge). Inputs written
    /// with `ClusterSizing::Adaptive` show up here as a wide band.
    pub cluster_entries_min: u32,
    /// Largest basket (entries) observed across the merged inputs.
    pub cluster_entries_max: u32,
}

use crate::cache::plan::DEFAULT_COALESCE_GAP;

/// Load one input file's tree into an in-memory [`TreeBuffer`]
/// (compressed bytes, CRC-verified). Fetches are **coalesced**
/// ([`crate::cache::fetch_baskets_coalesced`]): the writer lays
/// baskets out back-to-back, so a whole input loads in a handful of
/// large sequential reads (each capped at
/// [`crate::cache::plan::MAX_BULK_FETCH`] so scratch stays bounded)
/// instead of one seeking read per basket — on seek-dominated devices
/// that is where `hadd`'s input time goes.
fn load_input(input: &BackendRef, tree: &Option<String>) -> Result<TreeBuffer> {
    let reader = FileReader::open(input.clone())?;
    let meta = match tree {
        Some(name) => reader
            .directory()
            .tree(name)
            .ok_or_else(|| Error::Format(format!("no tree '{name}'")))?,
        None => reader
            .directory()
            .trees
            .first()
            .ok_or_else(|| Error::Format("input has no trees".into()))?,
    };
    let mut buf = TreeBuffer::new(meta.schema.clone());
    buf.entries = meta.entries;
    buf.clusters = meta.clusters.clone();
    // Interleave each paged list branch's offset/element pages so a
    // stored pair (adjacent on disk) coalesces into one read.
    let infos: Vec<BasketInfo> = meta
        .branches
        .iter()
        .flat_map(|br| {
            br.baskets.iter().enumerate().flat_map(|(i, k)| {
                std::iter::once(*k).chain(br.elems.get(i).copied())
            })
        })
        .collect();
    let mut payloads =
        crate::cache::fetch_baskets_coalesced(input, &infos, DEFAULT_COALESCE_GAP)?
            .into_iter();
    let mut take = |k: &BasketInfo| -> Result<BasketPayload> {
        let bytes = payloads
            .next()
            .ok_or_else(|| Error::Sync("hadd: coalesced fetch lost a basket payload".into()))?;
        Ok(BasketPayload {
            bytes,
            raw_len: k.raw_len,
            first_entry: k.first_entry,
            n_entries: k.n_entries,
            settings: k.settings,
            zone: k.zone,
        })
    };
    for (bb, br) in buf.branches.iter_mut().zip(&meta.branches) {
        for (i, k) in br.baskets.iter().enumerate() {
            bb.baskets.push(take(k)?);
            if let Some(e) = br.elems.get(i) {
                bb.elems.push(take(e)?);
            }
        }
    }
    Ok(buf)
}

/// Streaming output side of the merge: appends each input's baskets in
/// input order, rebasing entry numbers; buffers drop as soon as their
/// bytes are appended.
struct Appender {
    fw: Arc<FileWriter>,
    schema: Option<Schema>,
    branches: Vec<BranchMeta>,
    entries: u64,
    /// Per-branch element totals: the global element coordinate each
    /// input's element pages are rebased onto (paged list branches).
    elem_counts: Vec<u64>,
    /// Rebased cluster spans of paged (v3) inputs.
    clusters: Vec<crate::format::directory::ClusterSpan>,
    stored: u64,
    /// Basket-size spread (entries) across everything appended.
    cluster_min: u32,
    cluster_max: u32,
}

impl Appender {
    fn new(fw: Arc<FileWriter>) -> Self {
        Appender {
            fw,
            schema: None,
            branches: Vec::new(),
            entries: 0,
            elem_counts: Vec::new(),
            clusters: Vec::new(),
            stored: 0,
            cluster_min: 0,
            cluster_max: 0,
        }
    }

    fn push(&mut self, index: usize, buf: &TreeBuffer) -> Result<()> {
        match &self.schema {
            None => {
                self.schema = Some(buf.schema.clone());
                self.branches = buf
                    .schema
                    .fields
                    .iter()
                    .map(|f| BranchMeta::simple(f.name.clone(), f.ty, Vec::new()))
                    .collect();
                self.elem_counts = vec![0; self.branches.len()];
            }
            Some(s) if *s != buf.schema => {
                return Err(Error::Schema(format!("input {index} has a different schema")));
            }
            Some(_) => {}
        }
        for (b, (dst, src)) in self.branches.iter_mut().zip(&buf.branches).enumerate() {
            if !src.elems.is_empty() && src.elems.len() != src.baskets.len() {
                return Err(Error::Format(format!(
                    "input {index} branch {b}: {} element pages for {} offset pages",
                    src.elems.len(),
                    src.baskets.len()
                )));
            }
            for (i, k) in src.baskets.iter().enumerate() {
                let (offset, crc) = self.fw.append(&k.bytes)?;
                self.stored += k.bytes.len() as u64;
                if k.n_entries > 0 {
                    self.cluster_min = if self.cluster_min == 0 {
                        k.n_entries
                    } else {
                        self.cluster_min.min(k.n_entries)
                    };
                    self.cluster_max = self.cluster_max.max(k.n_entries);
                }
                dst.baskets.push(BasketInfo {
                    offset,
                    comp_len: k.bytes.len() as u32,
                    raw_len: k.raw_len,
                    first_entry: self.entries + k.first_entry,
                    n_entries: k.n_entries,
                    crc,
                    settings: k.settings,
                    zone: k.zone,
                });
                // Element page of a paged list branch: raw-copied
                // directly after its offset page (sequential appends
                // keep the v3 adjacency invariant without decoding —
                // offsets inside the page are page-relative, so the
                // bytes are position-independent); only the directory
                // coordinates are rebased.
                if let Some(e) = src.elems.get(i) {
                    let (eoff, ecrc) = self.fw.append(&e.bytes)?;
                    self.stored += e.bytes.len() as u64;
                    dst.elems.push(BasketInfo {
                        offset: eoff,
                        comp_len: e.bytes.len() as u32,
                        raw_len: e.raw_len,
                        first_entry: self.elem_counts[b] + e.first_entry,
                        n_entries: e.n_entries,
                        crc: ecrc,
                        settings: e.settings,
                        zone: e.zone,
                    });
                }
            }
            self.elem_counts[b] +=
                src.elems.iter().map(|e| e.n_entries as u64).sum::<u64>();
        }
        self.clusters.extend(buf.clusters.iter().map(|c| {
            crate::format::directory::ClusterSpan {
                first_entry: self.entries + c.first_entry,
                n_entries: c.n_entries,
            }
        }));
        self.entries += buf.entries;
        Ok(())
    }

    fn finish(self, name: String) -> Result<(TreeMeta, u64, u64, (u32, u32))> {
        let schema = self
            .schema
            .ok_or_else(|| Error::Coordinator("hadd: no inputs appended".into()))?;
        let meta = TreeMeta {
            name,
            schema,
            entries: self.entries,
            branches: self.branches,
            clusters: self.clusters,
        };
        meta.check()?;
        Ok((meta, self.entries, self.stored, (self.cluster_min, self.cluster_max)))
    }
}

/// Merge `inputs` into a fresh file on `output`, under a private
/// session on the global IMT pool. Jobs that already hold a shared
/// [`Session`] should call [`hadd_in_session`] so the loader tasks
/// land in the same pool/completion domain as the job's writers.
pub fn hadd(output: BackendRef, inputs: &[BackendRef], opts: &HaddOptions) -> Result<HaddReport> {
    hadd_in_session(output, inputs, opts, &Session::new(SessionConfig::default()))
}

/// Merge `inputs` into a fresh file on `output`; parallel input loads
/// run as task-group jobs in `session`'s completion domain.
pub fn hadd_in_session(
    output: BackendRef,
    inputs: &[BackendRef],
    opts: &HaddOptions,
    session: &Session,
) -> Result<HaddReport> {
    if inputs.is_empty() {
        return Err(Error::Coordinator("hadd: no input files".into()));
    }
    let t0 = Instant::now();
    let fw = Arc::new(FileWriter::create(output)?);
    let mut appender = Appender::new(fw.clone());

    if opts.parallel && session.is_parallel() {
        // Pipelined -j: loads run as task-group jobs; the appender
        // consumes buffers in input order as they complete, so device
        // appends overlap the remaining reads.
        let group = session.task_group();
        let (tx, rx) = std::sync::mpsc::channel();
        for (i, input) in inputs.iter().enumerate() {
            let tx = tx.clone();
            let input = input.clone();
            let tree = opts.tree.clone();
            group.spawn(move || {
                let _ = tx.send((i, load_input(&input, &tree)));
            });
        }
        drop(tx);
        let mut stash: BTreeMap<usize, TreeBuffer> = BTreeMap::new();
        let mut next = 0usize;
        while next < inputs.len() {
            let (i, loaded) = match rx.try_recv() {
                Ok(msg) => msg,
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    let pending = group.pending();
                    if pending > 0 {
                        // Help run loader jobs (or park until one
                        // completes) instead of blocking on the
                        // channel, so this also works when called
                        // from inside a pool worker.
                        group.wait_below(pending - 1);
                        continue;
                    }
                    // pending hit 0 between our try_recv and the read
                    // above — the final result may have been sent in
                    // that window, so poll once more before declaring
                    // a loader dead (panicked without delivering).
                    match rx.try_recv() {
                        Ok(msg) => msg,
                        Err(_) => {
                            group.join()?;
                            return Err(Error::Coordinator(
                                "hadd: input loader dropped its result".into(),
                            ));
                        }
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    group.join()?;
                    return Err(Error::Coordinator(
                        "hadd: input loader dropped its result".into(),
                    ));
                }
            };
            stash.insert(i, loaded?);
            while let Some(buf) = stash.remove(&next) {
                appender.push(next, &buf)?;
                next += 1;
            }
        }
        group.join()?;
    } else {
        // Serial: load-append one input at a time (streaming, so peak
        // memory is one input even without -j).
        for (i, input) in inputs.iter().enumerate() {
            let buf = load_input(input, &opts.tree)?;
            appender.push(i, &buf)?;
        }
    }

    let name = opts.tree.clone().unwrap_or_else(|| "events".into());
    let (meta, entries, stored, (cluster_min, cluster_max)) = appender.finish(name)?;
    fw.finish(&Directory { trees: vec![meta] })?;
    Ok(HaddReport {
        files: inputs.len(),
        entries,
        stored_bytes: stored,
        wall: t0.elapsed(),
        cluster_entries_min: cluster_min,
        cluster_entries_max: cluster_max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Codec, Settings};
    use crate::coordinator::write::write_blocks;
    use crate::serial::column::ColumnData;
    use crate::serial::value::Value;
    use crate::storage::mem::MemBackend;
    use crate::storage::Backend;
    use crate::tree::reader::TreeReader;
    use crate::tree::writer::FlushMode;

    fn make_input(start: i32, n: usize) -> BackendRef {
        let schema = Schema::flat_f32("v", 2);
        let be: BackendRef = Arc::new(MemBackend::new());
        let block: Vec<ColumnData> = (0..2)
            .map(|b| ColumnData::F32((0..n).map(|i| (start + i as i32 + b) as f32).collect()))
            .collect();
        let cfg = crate::tree::writer::WriterConfig {
            basket_entries: 64,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            ..Default::default()
        };
        write_blocks(be.clone(), schema, "events", cfg, vec![block]).unwrap();
        be
    }

    fn read_first_col(be: BackendRef) -> Vec<f32> {
        let r = TreeReader::open_first(Arc::new(FileReader::open(be).unwrap())).unwrap();
        let cols = r.read_all().unwrap();
        (0..r.entries() as usize)
            .map(|i| match cols[0].get(i).unwrap() {
                Value::F32(v) => v,
                _ => unreachable!(),
            })
            .collect()
    }

    fn dump(be: &BackendRef) -> Vec<u8> {
        let len = be.len().unwrap() as usize;
        let mut bytes = vec![0u8; len];
        be.read_at(0, &mut bytes).unwrap();
        bytes
    }

    #[test]
    fn serial_merge_concatenates_in_order() {
        let inputs = vec![make_input(0, 100), make_input(100, 100), make_input(200, 50)];
        let out: BackendRef = Arc::new(MemBackend::new());
        let rep = hadd(out.clone(), &inputs, &HaddOptions::default()).unwrap();
        assert_eq!(rep.files, 3);
        assert_eq!(rep.entries, 250);
        // inputs were cut at 64-entry clusters with uneven tails: the
        // reported basket-size spread covers tail..full baskets
        assert_eq!(rep.cluster_entries_max, 64);
        assert!(rep.cluster_entries_min >= 1 && rep.cluster_entries_min <= 64);
        let vals = read_first_col(out);
        assert_eq!(vals, (0..250).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_merge_byte_identical_to_serial() {
        let inputs: Vec<BackendRef> =
            (0..6).map(|i| make_input(i * 100, 100)).collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        crate::imt::enable(4);
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: None }).unwrap();
        crate::imt::disable();
        // the pipelined append order equals the input order, so the
        // output is byte-identical, not merely equivalent
        assert_eq!(dump(&serial_out), dump(&par_out));
        assert_eq!(read_first_col(serial_out), read_first_col(par_out));
    }

    #[test]
    fn hadd_in_explicit_session_matches_serial_bytes() {
        // A dedicated-pool session: -j parallelism without touching the
        // global IMT switch, byte-identical to the serial merge.
        let inputs: Vec<BackendRef> = (0..4).map(|i| make_input(i * 50, 50)).collect();
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        let pool = Arc::new(crate::imt::Pool::new(3));
        let session = crate::session::Session::with_pool(
            pool,
            crate::session::SessionConfig::default(),
        );
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd_in_session(
            par_out.clone(),
            &inputs,
            &HaddOptions { parallel: true, tree: None },
            &session,
        )
        .unwrap();
        assert_eq!(dump(&serial_out), dump(&par_out));
    }

    fn make_paged_input(start: u32, n: u32) -> BackendRef {
        use crate::serial::schema::{ColumnType, Field};
        use crate::tree::writer::{Layout, TreeWriter, WriterConfig};
        let schema = Schema::new(vec![
            Field::new("x", ColumnType::F32),
            Field::new("hits", ColumnType::ListF32),
        ]);
        let be: BackendRef = Arc::new(MemBackend::new());
        let fw = Arc::new(
            crate::format::writer::FileWriter::create(be.clone()).unwrap(),
        );
        let sink = crate::tree::sink::FileSink::new(fw.clone(), schema.len());
        let cfg = WriterConfig {
            basket_entries: 32,
            compression: Settings::new(Codec::Lz4r, 3),
            flush: FlushMode::Serial,
            layout: Layout::Paged { page_entries: 8 },
            ..Default::default()
        };
        let mut w = TreeWriter::new(schema.clone(), sink, cfg);
        for i in start..start + n {
            let list: Vec<f32> = (0..i % 4).map(|j| (i + j) as f32).collect();
            w.fill(vec![Value::F32(i as f32), Value::ListF32(list)]).unwrap();
        }
        let (sink, entries, _) = w.close().unwrap();
        let meta = sink.into_meta("events".into(), schema, entries).unwrap();
        fw.finish(&Directory { trees: vec![meta] }).unwrap();
        be
    }

    /// Satellite (ISSUE 8): hadd raw-copies paged v3 inputs — page
    /// pairs carried without decode, directories rebased — and the
    /// merged file both validates and decodes to the concatenation.
    /// The parallel merge must stay byte-identical to the serial one.
    #[test]
    fn paged_v3_inputs_raw_copy_without_decode() {
        let inputs =
            vec![make_paged_input(0, 100), make_paged_input(100, 60), make_paged_input(160, 9)];
        let serial_out: BackendRef = Arc::new(MemBackend::new());
        let rep = hadd(serial_out.clone(), &inputs, &HaddOptions::default()).unwrap();
        assert_eq!(rep.entries, 169);
        // Raw copy: every stored page in the output byte-matches its
        // source page (same compressed payloads, only coordinates
        // rebased), including offset/element pairs.
        let out_reader =
            TreeReader::open_first(Arc::new(FileReader::open(serial_out.clone()).unwrap()))
                .unwrap();
        let out_meta = out_reader.meta().clone();
        let out_file = out_reader.file().clone();
        let mut page_base = vec![0usize; out_meta.branches.len()];
        for be in &inputs {
            let f = Arc::new(FileReader::open(be.clone()).unwrap());
            let m = &f.directory().trees[0];
            for (b, br) in m.branches.iter().enumerate() {
                let out_br = &out_meta.branches[b];
                for (k, info) in br.baskets.iter().enumerate() {
                    let src = f.fetch_basket(info).unwrap();
                    let dst =
                        out_file.fetch_basket(&out_br.baskets[page_base[b] + k]).unwrap();
                    assert_eq!(src, dst, "page payload changed in the merge");
                    if let Some(e) = br.elems.get(k) {
                        let src_e = f.fetch_basket(e).unwrap();
                        let dst_e =
                            out_file.fetch_basket(&out_br.elems[page_base[b] + k]).unwrap();
                        assert_eq!(src_e, dst_e, "element page payload changed");
                    }
                }
                page_base[b] += br.baskets.len();
            }
        }
        out_meta.check().unwrap();
        assert!(out_meta.branches[1].is_paged_list());
        assert_eq!(
            out_meta.clusters.iter().map(|c| c.n_entries).sum::<u64>(),
            169,
            "cluster spans rebase to cover the concatenation"
        );
        // Decoded concatenation matches reading the inputs in order.
        let merged = out_reader.read_all().unwrap();
        let mut want_x = Vec::new();
        for be in &inputs {
            let r = TreeReader::open_first(Arc::new(FileReader::open(be.clone()).unwrap()))
                .unwrap();
            let cols = r.read_all().unwrap();
            for i in 0..r.entries() as usize {
                want_x.push(cols[0].get(i).unwrap());
                assert_eq!(
                    cols[1].get(i).unwrap(),
                    merged[1].get(want_x.len() - 1).unwrap(),
                    "variable-length entry {i} diverged after merge"
                );
            }
        }
        for (i, w) in want_x.iter().enumerate() {
            assert_eq!(merged[0].get(i).unwrap(), *w);
        }
        // Parallel -j merge stays byte-identical.
        crate::imt::enable(4);
        let par_out: BackendRef = Arc::new(MemBackend::new());
        hadd(par_out.clone(), &inputs, &HaddOptions { parallel: true, tree: None }).unwrap();
        crate::imt::disable();
        assert_eq!(dump(&serial_out), dump(&par_out));
    }

    #[test]
    fn schema_mismatch_rejected() {
        let a = make_input(0, 10);
        let b: BackendRef = Arc::new(MemBackend::new());
        let schema = Schema::flat_f32("other", 3);
        let block: Vec<ColumnData> =
            (0..3).map(|_| ColumnData::F32(vec![1.0; 10])).collect();
        write_blocks(
            b.clone(),
            schema,
            "events",
            crate::tree::writer::WriterConfig::default(),
            vec![block],
        )
        .unwrap();
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[a, b], &HaddOptions::default()).is_err());
    }

    #[test]
    fn empty_inputs_rejected() {
        let out: BackendRef = Arc::new(MemBackend::new());
        assert!(hadd(out, &[], &HaddOptions::default()).is_err());
    }
}
