//! Library-wide error type.

use std::fmt;

/// Unified error for every layer of the I/O subsystem.
#[derive(Debug)]
pub enum Error {
    /// Underlying OS / backend I/O failure.
    Io(std::io::Error),
    /// Malformed container file (bad magic, truncated footer, ...).
    Format(String),
    /// Codec failure (corrupt block, bad header, checksum mismatch).
    Codec(String),
    /// Schema/streamer mismatch (wrong type for column, unknown field).
    Schema(String),
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Coordinator-level invariant violation (basket index gap, ...).
    Coordinator(String),
    /// Concurrency failure: a flush task panicked or poisoned a lock.
    /// Surfaced as an error so a single bad task aborts the write
    /// cleanly instead of cascading panics through the writer.
    Sync(String),
    /// A request missed its per-request deadline (remote storage).
    /// Transient: the resilient layer retries or hedges it.
    Timeout(String),
    /// Load shedding: the circuit breaker refused a speculative
    /// (read-ahead) request while the backend is unhealthy. Transient
    /// by definition — the work is retried once demand becomes real.
    Shed(String),
}

impl Error {
    /// Whether this failure is worth retrying: deadline misses, shed
    /// speculative work, and the I/O error kinds a remote object store
    /// surfaces for 5xx-style blips. Corruption (`Format`/`Codec`) and
    /// logic errors are deliberately *not* transient — retrying them
    /// would re-read the same bad bytes.
    pub fn is_transient(&self) -> bool {
        use std::io::ErrorKind;
        match self {
            Error::Timeout(_) | Error::Shed(_) => true,
            Error::Io(e) => matches!(
                e.kind(),
                ErrorKind::Interrupted
                    | ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::TimedOut
                    | ErrorKind::WouldBlock
                    | ErrorKind::BrokenPipe
            ),
            _ => false,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Schema(m) => write!(f, "schema error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Sync(m) => write!(f, "sync error: {m}"),
            Error::Timeout(m) => write!(f, "deadline exceeded: {m}"),
            Error::Shed(m) => write!(f, "request shed: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;
