//! Library-wide error type.

use std::fmt;

/// Unified error for every layer of the I/O subsystem.
#[derive(Debug)]
pub enum Error {
    /// Underlying OS / backend I/O failure.
    Io(std::io::Error),
    /// Malformed container file (bad magic, truncated footer, ...).
    Format(String),
    /// Codec failure (corrupt block, bad header, checksum mismatch).
    Codec(String),
    /// Schema/streamer mismatch (wrong type for column, unknown field).
    Schema(String),
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Coordinator-level invariant violation (basket index gap, ...).
    Coordinator(String),
    /// Concurrency failure: a flush task panicked or poisoned a lock.
    /// Surfaced as an error so a single bad task aborts the write
    /// cleanly instead of cascading panics through the writer.
    Sync(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Schema(m) => write!(f, "schema error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Sync(m) => write!(f, "sync error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;
