//! In-memory tree contents: the unit shipped over the `TBufferMerger`
//! queue (the paper's Figure 4 "buffers").
//!
//! Baskets inside a `TreeBuffer` are *already compressed* — the whole
//! point of the merger design is that workers pay the serialisation +
//! compression cost in parallel and the single output thread only
//! appends bytes.

use crate::serial::schema::Schema;

/// One compressed basket awaiting merge.
#[derive(Clone, Debug)]
pub struct BasketPayload {
    /// Compressed container bytes (self-describing blocks).
    pub bytes: Vec<u8>,
    /// Decompressed length.
    pub raw_len: u32,
    /// Entries covered, relative to the start of this buffer.
    pub first_entry: u64,
    pub n_entries: u32,
    /// Compression settings the basket was written with; carried into
    /// the output directory when the buffer is merged.
    pub settings: crate::compress::Settings,
    /// Per-page zone map captured at seal time; carried through merges
    /// (raw-copy paths never decode, so the zone must travel with the
    /// payload to survive into the merged directory).
    pub zone: Option<crate::format::ZoneMap>,
}

/// Per-branch basket list.
#[derive(Clone, Debug, Default)]
pub struct BranchBuffer {
    pub baskets: Vec<BasketPayload>,
    /// Element pages of a paged variable-length branch, paired 1:1
    /// with `baskets` (`first_entry` counts buffer-relative elements).
    pub elems: Vec<BasketPayload>,
}

/// A complete in-memory tree: aligned per-branch baskets plus counts.
#[derive(Clone, Debug)]
pub struct TreeBuffer {
    pub schema: Schema,
    pub entries: u64,
    pub branches: Vec<BranchBuffer>,
    /// Cluster spans of a paged (v3) tree, buffer-relative.
    pub clusters: Vec<crate::format::directory::ClusterSpan>,
}

impl TreeBuffer {
    pub fn new(schema: Schema) -> Self {
        let n = schema.len();
        TreeBuffer {
            schema,
            entries: 0,
            branches: (0..n).map(|_| BranchBuffer::default()).collect(),
            clusters: Vec::new(),
        }
    }

    /// Total compressed payload bytes held.
    pub fn stored_bytes(&self) -> usize {
        self.branches
            .iter()
            .flat_map(|b| b.baskets.iter().chain(&b.elems))
            .map(|k| k.bytes.len())
            .sum()
    }

    /// Total uncompressed bytes represented.
    pub fn raw_bytes(&self) -> usize {
        self.branches
            .iter()
            .flat_map(|b| b.baskets.iter().chain(&b.elems))
            .map(|k| k.raw_len as usize)
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::schema::{ColumnType, Field};

    #[test]
    fn accounting() {
        let schema = Schema::new(vec![Field::new("x", ColumnType::F32)]);
        let mut b = TreeBuffer::new(schema);
        assert!(b.is_empty());
        b.branches[0].baskets.push(BasketPayload {
            bytes: vec![0; 50],
            raw_len: 400,
            first_entry: 0,
            n_entries: 100,
            settings: crate::compress::Settings::default_compressed(),
            zone: None,
        });
        b.entries = 100;
        assert_eq!(b.stored_bytes(), 50);
        assert_eq!(b.raw_bytes(), 400);
        assert!(!b.is_empty());
    }
}
