//! Basket destinations for the tree writer.

use std::sync::Mutex;

use crate::error::Result;
use crate::format::directory::{BasketInfo, BranchMeta, TreeMeta};
use crate::format::writer::FileWriter;
use crate::serial::schema::Schema;
use crate::storage::BackendRef;

use super::buffer::{BasketPayload, TreeBuffer};

/// Receives finished (compressed) baskets. Must be thread-safe: during
/// an IMT flush all branches land concurrently.
pub trait BasketSink: Send + Sync {
    /// Store one basket of `branch`; entries are buffer-relative.
    fn put_basket(
        &self,
        branch: usize,
        payload: Vec<u8>,
        raw_len: u32,
        first_entry: u64,
        n_entries: u32,
    ) -> Result<()>;
}

/// Sink writing straight into an open [`FileWriter`].
pub struct FileSink {
    file: std::sync::Arc<FileWriter>,
    baskets: Vec<Mutex<Vec<BasketInfo>>>,
}

impl FileSink {
    pub fn new(file: std::sync::Arc<FileWriter>, n_branches: usize) -> Self {
        FileSink { file, baskets: (0..n_branches).map(|_| Mutex::new(Vec::new())).collect() }
    }

    /// Drain collected metadata into a [`TreeMeta`].
    pub fn into_meta(self, name: String, schema: Schema, entries: u64) -> TreeMeta {
        let branches = self
            .baskets
            .into_iter()
            .zip(&schema.fields)
            .map(|(m, f)| {
                let mut baskets = m.into_inner().unwrap();
                baskets.sort_by_key(|b| b.first_entry);
                BranchMeta { name: f.name.clone(), ty: f.ty, baskets }
            })
            .collect();
        TreeMeta { name, schema, entries, branches }
    }
}

impl BasketSink for FileSink {
    fn put_basket(
        &self,
        branch: usize,
        payload: Vec<u8>,
        raw_len: u32,
        first_entry: u64,
        n_entries: u32,
    ) -> Result<()> {
        let (offset, crc) = self.file.append(&payload)?;
        self.baskets[branch].lock().unwrap().push(BasketInfo {
            offset,
            comp_len: payload.len() as u32,
            raw_len,
            first_entry,
            n_entries,
            crc,
        });
        Ok(())
    }
}

/// Sink accumulating into an in-memory [`TreeBuffer`].
pub struct BufferSink {
    branches: Vec<Mutex<Vec<BasketPayload>>>,
    schema: Schema,
}

impl BufferSink {
    pub fn new(schema: Schema) -> Self {
        let n = schema.len();
        BufferSink { branches: (0..n).map(|_| Mutex::new(Vec::new())).collect(), schema }
    }

    pub fn into_buffer(self, entries: u64) -> TreeBuffer {
        let mut buf = TreeBuffer::new(self.schema.clone());
        buf.entries = entries;
        for (dst, src) in buf.branches.iter_mut().zip(self.branches) {
            dst.baskets = src.into_inner().unwrap();
            dst.baskets.sort_by_key(|b| b.first_entry);
        }
        buf
    }
}

impl BasketSink for BufferSink {
    fn put_basket(
        &self,
        branch: usize,
        payload: Vec<u8>,
        raw_len: u32,
        first_entry: u64,
        n_entries: u32,
    ) -> Result<()> {
        self.branches[branch].lock().unwrap().push(BasketPayload {
            bytes: payload,
            raw_len,
            first_entry,
            n_entries,
        });
        Ok(())
    }
}

/// Open a fresh single-tree file writer on `backend` (helper used by
/// examples and benches).
pub fn file_writer(backend: BackendRef) -> Result<std::sync::Arc<FileWriter>> {
    Ok(std::sync::Arc::new(FileWriter::create(backend)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serial::schema::{ColumnType, Field};
    use crate::storage::mem::MemBackend;
    use std::sync::Arc;

    fn schema2() -> Schema {
        Schema::new(vec![Field::new("a", ColumnType::F32), Field::new("b", ColumnType::I32)])
    }

    #[test]
    fn file_sink_collects_sorted_meta() {
        let be = Arc::new(MemBackend::new());
        let fw = Arc::new(FileWriter::create(be).unwrap());
        let sink = FileSink::new(fw, 2);
        // out-of-order arrival (parallel flush)
        sink.put_basket(0, vec![1, 2, 3], 12, 100, 50).unwrap();
        sink.put_basket(0, vec![4, 5], 8, 0, 100).unwrap();
        sink.put_basket(1, vec![6], 4, 0, 150).unwrap();
        let meta = sink.into_meta("t".into(), schema2(), 150);
        assert_eq!(meta.branches[0].baskets[0].first_entry, 0);
        assert_eq!(meta.branches[0].baskets[1].first_entry, 100);
        meta.check().unwrap();
    }

    #[test]
    fn buffer_sink_builds_tree_buffer() {
        let sink = BufferSink::new(schema2());
        sink.put_basket(0, vec![9; 10], 40, 0, 10).unwrap();
        sink.put_basket(1, vec![8; 5], 40, 0, 10).unwrap();
        let buf = sink.into_buffer(10);
        assert_eq!(buf.entries, 10);
        assert_eq!(buf.branches[0].baskets.len(), 1);
        assert_eq!(buf.stored_bytes(), 15);
    }
}
